"""The static-analysis layer: lint rules, pragmas, baseline, CLI, and
the plancheck plan validator (+ its HistogramEngine.validate wiring).

Each rule gets a failing-then-passing fixture trio: a triggering
snippet, a clean snippet, and a suppressed-with-pragma snippet.
Plancheck gets golden verdicts for the two scenarios test_engine.py
already golden-tests (640x480/32-bin, §4.6 8192²/128-bin) and the
static rejections the ISSUE requires (budget-infeasible and
uint16-overflow plans that previously failed only at run time).
"""

import dataclasses
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro.analysis import (
    RULES,
    gate,
    lint_paths,
    lint_source,
    load_baseline,
    write_baseline,
)
from repro.analysis.__main__ import main as analysis_main

ROOT = Path(__file__).resolve().parent.parent


def _lint(src: str, relpath: str, rule: str):
    """Findings of one rule on one dedented snippet."""
    found = lint_source(textwrap.dedent(src), relpath)
    return [f for f in found if f.rule == rule]


# ---------------------------------------------------------------------------
# rule: sharded-concat
# ---------------------------------------------------------------------------
CORE = "src/repro/core"


def test_sharded_concat_triggers_in_assembly_module():
    bad = """\
        import jax.numpy as jnp
        def dense(pieces):
            return jnp.concatenate(pieces, axis=-2)
    """
    hits = _lint(bad, f"{CORE}/hsource.py", "sharded-concat")
    assert len(hits) == 1 and hits[0].line == 3 and not hits[0].suppressed


def test_sharded_concat_triggers_on_band_operands_anywhere_in_core():
    bad = """\
        import jax.numpy as jnp
        def f(bands):
            return jnp.stack([b.H for b in bands])
    """
    hits = _lint(bad, f"{CORE}/somewhere.py", "sharded-concat")
    assert len(hits) == 1
    # ...but a concat with no band/shard operand outside the assembly
    # modules is fine (zero-padding in region_query.py stays clean)
    ok = """\
        import jax.numpy as jnp
        def pad(H):
            return jnp.concatenate([H, H[..., :1]], axis=-1)
    """
    assert _lint(ok, f"{CORE}/somewhere.py", "sharded-concat") == []


def test_sharded_concat_clean_and_suppressed():
    ok = """\
        import numpy as np
        def dense(pieces):
            return np.concatenate(pieces, axis=-2)
    """
    assert _lint(ok, f"{CORE}/hsource.py", "sharded-concat") == []
    sup = """\
        import jax.numpy as jnp
        def dense(pieces):
            # analysis: allow-sharded-concat(single-device path, operands verified colocated)
            return jnp.concatenate(pieces, axis=-2)
    """
    hits = _lint(sup, f"{CORE}/hsource.py", "sharded-concat")
    assert len(hits) == 1 and hits[0].suppressed
    assert "colocated" in hits[0].suppression_reason


def test_sharded_concat_out_of_scope_elsewhere():
    src = """\
        import jax.numpy as jnp
        def f(bands):
            return jnp.concatenate(bands)
    """
    assert _lint(src, "src/repro/train/grad.py", "sharded-concat") == []


# ---------------------------------------------------------------------------
# rule: host-sync
# ---------------------------------------------------------------------------
def test_host_sync_triggers():
    bad = """\
        import jax, numpy as np
        def retire(out):
            out = jax.block_until_ready(out)
            n = out.sum().item()
            return np.asarray(out), n
    """
    hits = _lint(bad, "src/repro/core/runtime.py", "host-sync")
    assert sorted(h.line for h in hits) == [3, 4, 5]
    # kernel wrappers are in scope too
    assert len(_lint(bad, "src/repro/kernels/ops.py", "host-sync")) == 3


def test_host_sync_clean_suppressed_and_scoped():
    ok = """\
        import jax
        def dispatch(fn, chunk):
            return fn(chunk)
    """
    assert _lint(ok, "src/repro/core/runtime.py", "host-sync") == []
    sup = """\
        import jax
        def retire(out):
            # analysis: allow-host-sync(retire-time sync is the contract)
            return jax.block_until_ready(out)
    """
    hits = _lint(sup, "src/repro/core/runtime.py", "host-sync")
    assert len(hits) == 1 and hits[0].suppressed
    # outside the hot paths np.asarray is fine
    bad = """\
        import numpy as np
        def f(x): return np.asarray(x)
    """
    assert _lint(bad, "src/repro/core/hsource.py", "host-sync") == []


# ---------------------------------------------------------------------------
# rule: carry-contract
# ---------------------------------------------------------------------------
def test_carry_contract_triggers_on_malformed_step():
    one_arg = """\
        from repro.core.runtime import FrameRuntime
        rt = FrameRuntime(lambda chunk: chunk)
    """
    hits = _lint(one_arg, "src/repro/core/x.py", "carry-contract")
    assert len(hits) == 1
    no_pair = """\
        from repro.core.runtime import FrameRuntime
        def step(chunk, carry):
            return chunk
        rt = FrameRuntime(step)
    """
    hits = _lint(no_pair, "src/repro/core/x.py", "carry-contract")
    assert len(hits) == 1 and hits[0].line == 3


def test_carry_contract_clean_stateless_and_suppressed():
    ok = """\
        from repro.core.runtime import FrameRuntime
        def step(chunk, carry):
            return chunk * 2, carry
        rt = FrameRuntime(step)
        rt2 = FrameRuntime(lambda chunk, carry: (chunk, carry))
        rt3 = FrameRuntime(FrameRuntime.stateless(abs))
    """
    assert _lint(ok, "src/repro/core/x.py", "carry-contract") == []
    sup = """\
        from repro.core.runtime import FrameRuntime
        # analysis: allow-carry-contract(adapter normalizes the signature downstream)
        rt = FrameRuntime(lambda chunk: chunk)
    """
    hits = _lint(sup, "src/repro/core/x.py", "carry-contract")
    assert len(hits) == 1 and hits[0].suppressed


# ---------------------------------------------------------------------------
# rule: no-shim-use
# ---------------------------------------------------------------------------
def test_no_shim_use_triggers():
    imp = """\
        from repro.core.region_query import banded_region_histogram
    """
    assert len(_lint(imp, "src/repro/core/x.py", "no-shim-use")) == 1
    attr = """\
        from repro.core import region_query
        f = region_query.banded_likelihood_map
    """
    assert len(_lint(attr, "src/repro/core/x.py", "no-shim-use")) == 1


def test_no_shim_use_clean_defining_module_and_suppressed():
    ok = """\
        from repro.core.region_query import region_histogram
    """
    assert _lint(ok, "src/repro/core/x.py", "no-shim-use") == []
    # the defining module is exempt — it IS the shim
    definition = """\
        def banded_region_histogram(bands, rects):
            return banded_region_histogram
    """
    assert _lint(definition, "src/repro/core/region_query.py",
                 "no-shim-use") == []
    sup = """\
        from repro.core import region_query
        # analysis: allow-shim-use(public deprecated alias kept until 2.0)
        f = region_query.banded_region_histogram
    """
    hits = _lint(sup, "src/repro/core/x.py", "no-shim-use")
    assert len(hits) == 1 and hits[0].suppressed


# ---------------------------------------------------------------------------
# rule: overflow-policy
# ---------------------------------------------------------------------------
def test_overflow_policy_triggers():
    no_bound = """\
        import numpy as np
        STORAGE_POLICIES = {"uint16": np.uint16}
    """
    assert len(_lint(no_bound, "src/repro/core/bands.py",
                     "overflow-policy")) == 1
    dyn_bound = """\
        import numpy as np
        def limit(): return 65535
        STORAGE_POLICIES = {"uint16": (np.uint16, limit())}
    """
    assert len(_lint(dyn_bound, "src/repro/core/bands.py",
                     "overflow-policy")) == 1
    no_method = """\
        from repro.core.hsource import HSource
        class SpilledIH(HSource):
            storage: str
    """
    hits = _lint(no_method, "src/repro/core/bands.py", "overflow-policy")
    assert len(hits) == 1 and "exact_region_bound" in hits[0].message


def test_overflow_policy_clean_and_suppressed():
    ok = """\
        import numpy as np
        BITS = 16
        STORAGE_POLICIES = {"uint16": (np.uint16, (1 << BITS) - 1)}
        from repro.core.hsource import HSource
        class SpilledIH(HSource):
            storage: str
            def exact_region_bound(self):
                return STORAGE_POLICIES[self.storage][1]
    """
    assert _lint(ok, "src/repro/core/bands.py", "overflow-policy") == []
    sup = """\
        import numpy as np
        # analysis: allow-overflow-policy(prototype policy, bound enforced by caller)
        STORAGE_POLICIES = {"uint16": np.uint16}
    """
    hits = _lint(sup, "src/repro/core/bands.py", "overflow-policy")
    assert len(hits) == 1 and hits[0].suppressed


# ---------------------------------------------------------------------------
# rule: lock-discipline
# ---------------------------------------------------------------------------
LOCKED_CLASS = """\
    import threading
    class Svc:
        _LOCK_PROTECTED = ("_cache", "stats")
        _LOCK_PROTECTED_MUTATORS = ("observe",)
        def __init__(self):
            self._lock = threading.Lock()
            self._cache = {}     # __init__ is exempt
            self.stats = None
        def {body}
"""


def _locked(body: str):
    return textwrap.dedent(LOCKED_CLASS).replace(
        "def {body}", textwrap.dedent(body).replace("\n", "\n        ").rstrip()
    )


def test_lock_discipline_triggers():
    bad_write = _locked("""\
        def hit(self, k):
            self._cache[k] = 1
    """)
    hits = lint_source(bad_write, "src/repro/serve/service.py")
    assert [f.rule for f in hits] == ["lock-discipline"]
    bad_mutator = _locked("""\
        def note(self, dt):
            self.stats.observe(dt)
    """)
    hits = lint_source(bad_mutator, "src/repro/serve/service.py")
    assert [f.rule for f in hits] == ["lock-discipline"]
    bad_aug = _locked("""\
        def bump(self):
            self.stats.requests += 1
    """)
    hits = lint_source(bad_aug, "src/repro/serve/service.py")
    assert [f.rule for f in hits] == ["lock-discipline"]


def test_lock_discipline_clean_and_suppressed():
    ok = _locked("""\
        def hit(self, k):
            with self._lock:
                self._cache[k] = 1
                self.stats.observe(0.0)
            return self._cache.get(k)   # reads need no lock
    """)
    assert lint_source(ok, "src/repro/serve/service.py") == []
    sup = _locked("""\
        def hit(self, k):
            # analysis: allow-lock-discipline(single-threaded setup path)
            self._cache[k] = 1
    """)
    hits = lint_source(sup, "src/repro/serve/service.py")
    assert len(hits) == 1 and hits[0].suppressed
    # classes without a declaration are out of scope
    undeclared = """\
        class Free:
            def f(self):
                self._cache = {}
    """
    assert _lint(undeclared, "src/repro/serve/service.py",
                 "lock-discipline") == []


# ---------------------------------------------------------------------------
# rule: lock-order
# ---------------------------------------------------------------------------
SVC = "src/repro/serve/service.py"


def _lock_order(src: str):
    return _lint(src, SVC, "lock-order")


def test_lock_order_flags_acquisition_cycle():
    bad = """\
        import threading
        class S:
            def __init__(self):
                self._lock = threading.Lock()
                self._cache_lock = threading.Lock()
            def a(self):
                with self._lock:
                    with self._cache_lock:
                        pass
            def b(self):
                with self._cache_lock:
                    with self._lock:
                        pass
    """
    hits = _lock_order(bad)
    assert len(hits) == 1 and "lock-order cycle" in hits[0].message


def test_lock_order_flags_reacquisition_direct_and_via_call():
    direct = """\
        import threading
        class S:
            def __init__(self):
                self._lock = threading.Lock()
            def a(self):
                with self._lock:
                    with self._lock:
                        pass
    """
    hits = _lock_order(direct)
    assert len(hits) == 1 and "self-deadlock" in hits[0].message
    via_call = """\
        import threading
        class S:
            def __init__(self):
                self._lock = threading.Lock()
            def close(self):
                with self._lock:
                    self.flush()
            def flush(self):
                with self._lock:
                    pass
    """
    hits = _lock_order(via_call)
    assert len(hits) == 1
    assert "calls `self.flush()`, which acquires it again" \
        in hits[0].message
    # an RLock is reentrant: the same shape is legal
    rlock = via_call.replace("threading.Lock()", "threading.RLock()")
    assert _lock_order(rlock) == []


def test_lock_order_flags_blocking_under_lock():
    joins = """\
        import threading
        class S:
            def __init__(self):
                self._lock = threading.Lock()
                self._worker = threading.Thread()
            def close(self):
                with self._lock:
                    self._worker.join()
    """
    hits = _lock_order(joins)
    assert len(hits) == 1 and "join" in hits[0].message
    future_under_lock = """\
        import threading
        class S:
            def __init__(self):
                self._lock = threading.Lock()
            def done(self, fut, out):
                with self._lock:
                    fut.set_result(out)
    """
    hits = _lock_order(future_under_lock)
    assert len(hits) == 1 and "done-callbacks" in hits[0].message
    # the blocking call may hide behind a self.method() hop
    via_callee = """\
        import threading
        class S:
            def __init__(self):
                self._lock = threading.Lock()
                self._queue = None
            def drain(self):
                with self._lock:
                    self.take()
            def take(self):
                return self._queue.get(timeout=1)
    """
    hits = _lock_order(via_callee)
    assert len(hits) == 1 and "which blocks" in hits[0].message


def test_lock_order_clean_and_suppressed():
    # the shipped service's shape: lock only around state, blocking
    # calls (join / queue.get / set_result) all outside the lock
    ok = """\
        import threading
        class S:
            def __init__(self):
                self._lock = threading.Lock()
                self._queue = None
                self._worker = None
            def submit(self, p):
                self._queue.put(p, block=True)
                with self._lock:
                    self.n = 1
            def close(self):
                self._worker.join()
                p = self._queue.get_nowait()
                p.future.set_result(None)
    """
    assert _lock_order(ok) == []
    sup = """\
        import threading
        class S:
            def __init__(self):
                self._lock = threading.Lock()
                self._worker = threading.Thread()
            def close(self):
                with self._lock:
                    # analysis: allow-lock-order(worker never takes this lock)
                    self._worker.join()
    """
    found = _lint(sup, SVC, "lock-order")
    assert len(found) == 1 and found[0].suppressed
    # classes without locks are out of scope
    assert _lock_order("""\
        class Free:
            def f(self):
                self._worker.join()
    """) == []


# ---------------------------------------------------------------------------
# pragmas, baseline, CLI
# ---------------------------------------------------------------------------
def test_bad_pragmas_are_reported_and_do_not_suppress():
    empty_reason = """\
        import jax.numpy as jnp
        def dense(p):
            # analysis: allow-sharded-concat()
            return jnp.concatenate(p)
    """
    found = lint_source(textwrap.dedent(empty_reason), f"{CORE}/hsource.py")
    rules = sorted(f.rule for f in found)
    assert rules == ["pragma", "sharded-concat"]
    assert not [f for f in found if f.suppressed]
    unknown = """\
        x = 1  # analysis: allow-no-such-rule(whatever)
    """
    found = lint_source(textwrap.dedent(unknown), f"{CORE}/x.py")
    assert [f.rule for f in found] == ["pragma"]
    assert "no registered rule" in found[0].message


def test_baseline_roundtrip_and_gate(tmp_path):
    src = textwrap.dedent("""\
        import jax.numpy as jnp
        def dense(p):
            return jnp.concatenate(p)
    """)
    findings = lint_source(src, f"{CORE}/hsource.py")
    assert len(findings) == 1
    path = tmp_path / "baseline.json"
    assert write_baseline(findings, path) == 1
    baseline = load_baseline(path)
    assert gate(findings, baseline) == []
    assert gate(findings, set()) == findings
    # fingerprints survive the finding moving to another line
    moved = lint_source("\n\n" + src, f"{CORE}/hsource.py")
    assert gate(moved, baseline) == []


def test_cli_check_exit_codes(tmp_path, capsys):
    pkg = tmp_path / "src" / "repro" / "core"
    pkg.mkdir(parents=True)
    (pkg / "hsource.py").write_text(textwrap.dedent("""\
        import jax.numpy as jnp
        def dense(p):
            return jnp.concatenate(p)
    """))
    root = str(tmp_path)
    assert analysis_main(["--check", "--root", root]) == 1
    assert analysis_main(["--write-baseline", "--root", root]) == 0
    assert analysis_main(["--check", "--root", root]) == 0
    report = tmp_path / "report.json"
    assert analysis_main(["--check", "--root", root,
                          "--json", str(report)]) == 0
    data = json.loads(report.read_text())
    assert data["counts"]["gating"] == 0 and data["counts"]["total"] == 1
    assert set(data["rules"]) == set(RULES)
    capsys.readouterr()


BAD_CONCAT = """\
import jax.numpy as jnp
def dense(p):
    return jnp.concatenate(p)
"""


def test_write_baseline_is_a_ratchet(tmp_path):
    """Once a baseline exists, rewriting it can only prune: fixed debt
    drops out, NEW findings are refused (never laundered in)."""
    old = lint_source(BAD_CONCAT, f"{CORE}/hsource.py")
    path = tmp_path / "baseline.json"
    assert write_baseline(old, path) == 1          # seed: full write
    seeded = load_baseline(path)
    # the old finding is fixed; a new one appears elsewhere
    new = lint_source(BAD_CONCAT, f"{CORE}/bands.py")
    assert write_baseline(new, path) == 0          # old∩current = {}
    assert load_baseline(path) == set()
    assert seeded != set()
    # the new finding still gates — it was not written into the baseline
    assert gate(new, load_baseline(path)) == new


def test_stale_fingerprints_detects_fixed_debt():
    from repro.analysis import stale_fingerprints

    findings = lint_source(BAD_CONCAT, f"{CORE}/hsource.py")
    live = {f.fingerprint for f in findings}
    baseline = live | {"sharded-concat:src/repro/core/gone.py:abc123def456"}
    assert stale_fingerprints(findings, baseline) == baseline - live
    assert stale_fingerprints(findings, live) == set()


def _seed_repo(tmp_path) -> Path:
    pkg = tmp_path / "src" / "repro" / "core"
    pkg.mkdir(parents=True)
    (pkg / "hsource.py").write_text(BAD_CONCAT)
    return pkg / "hsource.py"


def test_cli_check_fails_on_stale_baseline(tmp_path, capsys):
    """The committed baseline may only shrink: once debt is fixed,
    --check forces the prune."""
    bad_file = _seed_repo(tmp_path)
    root = str(tmp_path)
    assert analysis_main(["--write-baseline", "--root", root]) == 0
    assert analysis_main(["--check", "--root", root]) == 0
    bad_file.write_text("def dense(p):\n    return p\n")   # debt fixed
    report = tmp_path / "report.json"
    assert analysis_main(["--check", "--root", root,
                          "--json", str(report)]) == 1
    out = capsys.readouterr().out
    assert "stale baseline entry" in out and "--write-baseline" in out
    data = json.loads(report.read_text())
    assert data["counts"]["stale_baseline"] == 1
    assert data["counts"]["gating"] == 0
    assert len(data["stale_baseline"]) == 1
    # pruning restores a clean --check, and the baseline shrank to empty
    assert analysis_main(["--write-baseline", "--root", root]) == 0
    assert analysis_main(["--check", "--root", root]) == 0
    assert load_baseline(tmp_path / "analysis-baseline.json") == set()
    capsys.readouterr()


def test_cli_usage_errors_exit_2(tmp_path, capsys):
    # conflicting modes
    assert analysis_main(["--check", "--write-baseline"]) == 2
    assert analysis_main(["--list-rules", "--check"]) == 2
    # no lintable paths under the given root
    assert analysis_main(["--check", "--root", str(tmp_path)]) == 2
    capsys.readouterr()


def test_cli_json_schema_roundtrip(tmp_path, capsys):
    """The JSON artifact carries everything the text render shows, keyed
    so CI tooling can diff runs: findings with fingerprints, the gating
    and stale sets, per-rule metadata."""
    _seed_repo(tmp_path)
    report = tmp_path / "report.json"
    assert analysis_main(["--root", str(tmp_path),
                          "--json", str(report)]) == 0
    data = json.loads(report.read_text())
    assert data["version"] == 1
    assert set(data["rules"]) == set(RULES)
    for meta in data["rules"].values():
        assert meta["pragma"].startswith("allow-") and meta["description"]
    (finding,) = data["findings"]
    assert finding["rule"] == "sharded-concat"
    assert finding["fingerprint"].startswith(
        "sharded-concat:src/repro/core/hsource.py:")
    assert data["gating"] == [finding["fingerprint"]]
    assert data["stale_baseline"] == []
    assert data["counts"] == {
        "total": 1, "suppressed": 0, "gating": 1, "stale_baseline": 0}
    capsys.readouterr()


def test_cli_pragma_suppression_end_to_end(tmp_path, capsys):
    """A pragma with a reason suppresses through the CLI; --check passes
    and the report records the suppression."""
    pkg = tmp_path / "src" / "repro" / "core"
    pkg.mkdir(parents=True)
    (pkg / "hsource.py").write_text(textwrap.dedent("""\
        import jax.numpy as jnp
        def dense(p):
            # analysis: allow-sharded-concat(single-device fast path)
            return jnp.concatenate(p)
    """))
    report = tmp_path / "report.json"
    assert analysis_main(["--check", "--root", str(tmp_path),
                          "--json", str(report)]) == 0
    data = json.loads(report.read_text())
    (finding,) = data["findings"]
    assert finding["suppressed"] is True
    assert finding["suppression_reason"] == "single-device fast path"
    assert data["counts"] == {
        "total": 1, "suppressed": 1, "gating": 0, "stale_baseline": 0}
    capsys.readouterr()


def test_tree_is_clean():
    """The acceptance gate: the repo's own tree lints clean."""
    findings = lint_paths(
        [p for p in ("src/repro", "benchmarks", "examples")
         if (ROOT / p).exists()],
        root=ROOT,
    )
    gating = gate(findings, load_baseline(ROOT / "analysis-baseline.json"))
    assert gating == [], "\n".join(f.render() for f in gating)


def test_cli_runs_without_jax_imported():
    """The CI analysis job runs the CLI on a bare interpreter; the lint
    layer must not drag jax in."""
    code = (
        "import sys; import repro.analysis; "
        "assert 'jax' not in sys.modules, 'lint layer imported jax'"
    )
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        cwd=str(ROOT), env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
    )
    assert proc.returncode == 0, proc.stderr


# ---------------------------------------------------------------------------
# plancheck: golden verdicts (the scenarios test_engine.py golden-tests)
# ---------------------------------------------------------------------------
GOLDEN_VERDICT_640 = """\
plan verdict    : OK (statically feasible)
  OK   representation  dense
  OK   h-shape         (32, 480, 640) float32 via wf_tis/jnp
  SKIP carry-chain     single-band plan has no carry
  SKIP memory-budget   no memory budget declared
  SKIP vmem-fit        jnp backend uses HBM
  OK   count-validity  307200-px frame within fp32 exact range"""

GOLDEN_VERDICT_64MB = """\
plan verdict    : OK (statically feasible)
  OK   representation  banded
  OK   h-shape         (128, 8192, 8192) float32 via wf_tis/jnp
  OK   carry-chain     128 bands (heights [64]) thread a (128, 8192) carry
  OK   memory-budget   largest band (64 rows): 268435456 B <= \
268435456 B budget
  SKIP vmem-fit        jnp backend uses HBM
  WARN count-validity  67108864-px frame exceeds the fp32 exact range \
16777216; only regions <= 16777215 px are exact (enforced per query)"""


def _plan(engine, shape):
    from repro.core.engine import plan

    return plan(engine.spec_for(shape))


def test_plancheck_golden_640x480():
    from repro.core.engine import HistogramEngine

    e = HistogramEngine(32, backend="jnp")
    v = e.validate(_plan(e, (480, 640)))
    assert v.ok and v.render() == GOLDEN_VERDICT_640


def test_plancheck_golden_8192_paper_scale():
    from repro.core.engine import HistogramEngine

    e = HistogramEngine(128, backend="jnp", memory_budget_bytes=256 << 20)
    v = e.validate(_plan(e, (8192, 8192)))
    assert v.ok and v.render() == GOLDEN_VERDICT_64MB
    # the warning is informational: the verdict still passes
    assert [c.status for c in v.checks].count("warn") == 1


# ---------------------------------------------------------------------------
# plancheck: static rejections (previously run-time failures)
# ---------------------------------------------------------------------------
def test_validate_rejects_budget_infeasible_plan():
    from repro.core.engine import HistogramEngine

    e = HistogramEngine(32, backend="jnp")
    p = _plan(e, (480, 640))
    bad = dataclasses.replace(
        p, microbatch=64,
        spec=dataclasses.replace(p.spec, memory_budget_bytes=1 << 20,
                                 num_frames=64),
    )
    v = e.validate(bad)
    assert not v.ok
    assert [c.name for c in v.failures] == ["memory-budget"]


def test_validate_rejects_uint16_overflow_query():
    from repro.core.engine import (
        HistogramEngine, PlanValidationError, RegionQuery,
    )

    e = HistogramEngine(16, backend="jnp", storage="uint16",
                        memory_budget_bytes=1 << 20)
    big = RegionQuery(np.array([[0, 0, 400, 400]]))   # 160801 px > 65535
    v = e.validate(_plan(e, (512, 512)), [big])
    assert not v.ok
    assert [c.name for c in v.failures] == ["query-validity"]
    # ...and run() refuses before any dispatch
    with pytest.raises(PlanValidationError, match="query-validity"):
        e.run(np.zeros((512, 512), np.uint8), [big])
    # the same plan with an in-bounds query sails through
    ok = e.validate(e.last_plan, [RegionQuery(np.array([[0, 0, 99, 99]]))])
    assert ok.ok


def test_validate_rejects_vmem_infeasible_pallas_plan():
    from repro.core.engine import HistogramEngine

    e = HistogramEngine(32, backend="pallas", tile=1024)
    v = e.validate(_plan(e, (2048, 2048)))
    assert not v.ok
    assert [c.name for c in v.failures] == ["vmem-fit"]
    # the default tile fits
    e2 = HistogramEngine(32, backend="pallas")
    assert e2.validate(_plan(e2, (2048, 2048))).ok


def test_validate_catches_carry_and_shape_breakage():
    from repro.core.engine import HistogramEngine

    e = HistogramEngine(128, backend="jnp", memory_budget_bytes=256 << 20)
    p = _plan(e, (8192, 8192))
    bad = dataclasses.replace(p, method="no_such_method")
    v = e.validate(bad)
    names = [c.name for c in v.failures]
    assert "h-shape" in names


def test_engine_run_validates_and_surfaces_verdict():
    from repro.core.engine import HistogramEngine, RegionQuery

    e = HistogramEngine(8, backend="jnp")
    out = e.run(np.zeros((32, 48), np.uint8),
                [RegionQuery(np.array([[0, 0, 7, 7]]))])
    assert e.last_verdict is not None and e.last_verdict.ok
    text = e.explain()
    assert "plan verdict    : OK" in text
    # plain plan.explain() output is unchanged (golden tests elsewhere)
    assert "plan verdict" not in out.plan.explain()
    assert out.plan.explain(e.last_verdict).endswith(
        e.last_verdict.render().replace("\n", "\n  "))


def test_map_frames_validates_before_first_dispatch():
    from repro.core.engine import HistogramEngine

    e = HistogramEngine(8, backend="jnp")
    frames = [np.zeros((16, 16), np.uint8)] * 2
    outs = list(e.map_frames(frames))
    assert len(outs) == 2 and e.last_verdict is not None


def test_validate_structural_verdict_is_cached():
    from repro.analysis.plancheck import _structural_checks
    from repro.core.engine import HistogramEngine

    e = HistogramEngine(8, backend="jnp")
    p = _plan(e, (64, 64))
    _structural_checks.cache_clear()
    e.validate(p)
    before = _structural_checks.cache_info().hits
    e.validate(p)
    assert _structural_checks.cache_info().hits == before + 1
