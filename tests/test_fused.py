"""Query-fused execution path (ISSUE 8 tentpole).

The fused kernel computes ONLY the requested corner rows of H straight
out of the WF-TiS scan — full H never reaches HBM.  Pinned here:

  * bit-exact parity vs the dense jnp oracle on uneven shapes, for both
    the jnp streaming fallback and the Pallas kernel (interpret mode);
  * the live ``pallas_call`` conforms to the declared ``fused_rows``
    KernelSpec (grid / blocks / index maps at every grid point);
  * the early exit: bands below the last requested row are never
    scanned, and the peak-memory proxy (``FusedRowsH.nbytes`` plus the
    ``rows_bytes``/``full_h_bytes`` stats) shows H was never stored;
  * the planner's compute-vs-store decision (Ehsan et al.'s tradeoff)
    and its ``explain()`` rendering, golden-snapshotted;
  * end-to-end wiring: engine.run, service cache fallback on
    ``MissingRowsError``, tracker ``step_fused``, autotuned priors,
    and the fused likelihood-map output mode.
"""

from __future__ import annotations

import json

import numpy as np
import pytest

from repro.analysis import kernelcheck as kc
from repro.core import autotune, distances
from repro.core.engine import (
    HistogramEngine,
    LikelihoodQuery,
    RegionQuery,
    SlidingWindowQuery,
    WorkloadSpec,
    plan,
)
from repro.core.hsource import DenseH, FusedRowsH, MissingRowsError
from repro.kernels import ops
from repro.kernels.fused_rows import fused_geometry, slot_plan
from repro.kernels.ref import integral_histogram_ref


def _oracle_rows(frames, num_bins, rows):
    """Dense-oracle corner rows: full ref H, then slice."""
    frames = np.asarray(frames)
    if frames.ndim == 2:
        H = integral_histogram_ref(frames, num_bins)
        return np.asarray(H)[:, rows, :]
    return np.stack([
        np.asarray(integral_histogram_ref(f, num_bins))[:, rows, :]
        for f in frames
    ])


# ---------------------------------------------------------------------------
# slot plan
# ---------------------------------------------------------------------------
def test_slot_plan_round_trip():
    rows = np.array([3, 7, 8, 30])
    slots, kp, pos = slot_plan(rows, tile=8, height=32)
    assert kp % 8 == 0 and slots.shape == (4, kp)
    # pos recovers request order from the (strip, kp) output layout
    flat = np.full(slots.shape, -1, np.int64)
    for s in range(slots.shape[0]):
        for j in range(kp):
            if slots[s, j] >= 0:
                flat[s, j] = s * 8 + slots[s, j]
    np.testing.assert_array_equal(flat.reshape(-1)[pos], rows)


@pytest.mark.parametrize("bad", [[5, 3], [2, 2], [-1], [40]])
def test_slot_plan_rejects_bad_rows(bad):
    with pytest.raises(ValueError):
        slot_plan(np.array(bad), tile=8, height=32)


# ---------------------------------------------------------------------------
# numeric parity vs the dense oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape,rows", [
    ((50, 70), (0, 7, 31, 49)),                  # 2D squeeze, h < tile
    ((2, 37, 53), (4, 36)),                      # batch + uneven
    ((3, 300, 41), (10, 150, 299)),              # multi-band stream
])
def test_fused_jnp_matches_dense_oracle(shape, rows, rng):
    frames = rng.integers(0, 256, shape, np.uint8)
    rows = np.asarray(rows)
    got = ops.fused_corner_rows(frames, 8, rows, backend="jnp")
    np.testing.assert_allclose(np.asarray(got), _oracle_rows(frames, 8, rows))


def test_fused_pallas_interpret_matches_dense_oracle(rng):
    frames = rng.integers(0, 256, (2, 20, 24), np.uint8)
    rows = np.asarray([1, 6, 7, 13, 19])         # crosses strip edges
    got = ops.fused_corner_rows(
        frames, 8, rows, backend="pallas", tile=8, bin_block=4,
        interpret=True)
    np.testing.assert_allclose(np.asarray(got), _oracle_rows(frames, 8, rows))


def test_fused_pallas_call_matches_spec(monkeypatch, rng):
    """The declared fused_rows KernelSpec cannot drift from the live
    pallas_call (same conformance contract as the full-H kernels)."""
    from jax.experimental import pallas as pl

    captured = []
    real = pl.pallas_call

    def spy(kernel, **kw):
        captured.append(kw)
        return real(kernel, **kw)

    monkeypatch.setattr(pl, "pallas_call", spy)

    frames = rng.integers(0, 256, (2, 20, 24), np.uint8)
    rows = np.asarray([1, 6, 7, 13, 19])
    got = ops.fused_corner_rows(
        frames, 8, rows, backend="pallas", tile=8, bin_block=4,
        interpret=True)
    np.testing.assert_allclose(np.asarray(got), _oracle_rows(frames, 8, rows))

    # h_cut covers every band up to the last requested row (19 -> 24)
    geom = fused_geometry(rows, n=2, h=24, w=24, num_bins=8,
                          tile=8, bin_block=4)
    (spec,) = ops.KERNEL_SPECS["fused_rows"](geom)
    assert len(captured) == 1
    call = captured[0]
    assert tuple(call["grid"]) == spec.grid_sizes
    outs = call["out_specs"]
    if not isinstance(outs, (list, tuple)):
        outs = [outs]
    live = list(call["in_specs"]) + list(outs)
    declared = spec.in_specs + spec.out_specs
    assert len(live) == len(declared)
    for op, bs in zip(declared, live):
        assert tuple(bs.block_shape) == op.block, f"{op.name} block"
        for g in kc.iter_grid(spec):
            key = tuple(g[d] for d in spec.dim_names)
            assert tuple(bs.index_map(*key)) == tuple(op.index_map(*key)), \
                f"{op.name} index map at {g}"
    assert tuple(call["out_shape"].shape) == spec.out_specs[0].shape
    live_scratch = [tuple(s.shape) for s in call["scratch_shapes"]]
    assert live_scratch == [s.shape for s in spec.scratch]


@pytest.mark.parametrize("geom", [
    fused_geometry((7, 100, 333), n=2, h=384, w=640, num_bins=32),
    fused_geometry((0,), n=1, h=128, w=128, num_bins=8),
])
def test_fused_spec_proves_all_four_properties(geom):
    verdict = kc.check_method("fused_rows", geom)
    assert verdict.ok, verdict.render()


# ---------------------------------------------------------------------------
# early exit + peak-memory proxy: H is never materialized
# ---------------------------------------------------------------------------
def test_early_exit_skips_bands_below_last_row(rng):
    frames = rng.integers(0, 256, (1, 1024, 64), np.uint8)
    stats: dict = {}
    rows = np.asarray([10, 100])                 # both in band 0 (tile 128)
    got = ops.fused_corner_rows(frames, 4, rows, backend="jnp", stats=stats)
    assert stats["bands_computed"] == 1
    assert stats["bands_total"] == 8
    # the rows slab is a tiny fraction of full H
    assert stats["rows_bytes"] * 100 < stats["full_h_bytes"]
    np.testing.assert_allclose(np.asarray(got), _oracle_rows(frames, 4, rows))


def test_fused_source_never_holds_full_h(rng):
    eng = HistogramEngine(8, backend="jnp")
    frame = rng.integers(0, 256, (256, 256), np.uint8)
    out = eng.run(frame, [RegionQuery([10, 10, 40, 40])])
    assert out.plan.representation == "fused"
    src = out.source
    assert isinstance(src, FusedRowsH)
    full_h = 4 * 8 * 256 * 256
    assert src.nbytes * 10 < full_h             # peak-memory proxy
    assert src.last_fused_stats["bands_computed"] \
        < src.last_fused_stats["bands_total"]


# ---------------------------------------------------------------------------
# FusedRowsH guards
# ---------------------------------------------------------------------------
def test_fused_rows_h_serves_only_its_rows(rng):
    R = rng.random((8, 3, 24), np.float32)
    src = FusedRowsH((2, 9, 15), R, height=32, width=24)
    np.testing.assert_array_equal(np.asarray(src.rows([9, 15])),
                                  np.asarray(R[:, 1:, :]))
    with pytest.raises(MissingRowsError):
        src.rows([2, 3])
    with pytest.raises(MissingRowsError):
        src.dense()
    with pytest.raises(ValueError):
        FusedRowsH((2, 9), R, height=32, width=24)   # 2 ids, 3 rows


# ---------------------------------------------------------------------------
# planner decision + golden explain
# ---------------------------------------------------------------------------
def _spec(**kw):
    base = dict(height=480, width=640, num_bins=32, num_frames=2,
                backend="jnp")
    base.update(kw)
    return WorkloadSpec(**base)


def test_plan_fuses_small_row_unions_only():
    assert plan(_spec(query_rows=(99, 239, 300))).representation == "fused"
    many = tuple(range(0, 480, 3))               # 160 > 480 // 4
    assert plan(_spec(query_rows=many)).representation == "dense"
    # a pinned storage policy or a too-small budget vetoes fusion
    pinned = plan(_spec(query_rows=(99,), storage="uint16"))
    assert pinned.representation != "fused"
    # 3-row slab is 491520 B; a budget below that (but above one band
    # row) forces the store path instead
    tight = plan(_spec(query_rows=(99, 239, 300),
                       memory_budget_bytes=200_000))
    assert tight.representation == "banded"
    with pytest.raises(ValueError):
        plan(_spec(query_rows=(300, 99)))        # unsorted


GOLDEN_FUSE = """\
ExecutionPlan
  workload        : 480x640 uint8 frames, 32 bins, 2 frame(s)/request
  full H          : 39321600 B/frame (37.5 MiB fp32)
  representation  : fused
  query fusion    : fuse — 3 corner row(s) (491520 B) << full H 39321600 B; H never stored
  method/backend  : wf_tis / jnp
  tile/bin_block  : 128 / 8
  microbatch      : 2 frame(s)/dispatch
  bands           : none (no memory budget)
  storage         : device fp32
  sharding        : none"""

GOLDEN_STORE_LINE = (
    "  query fusion    : store — 160 corner row(s) exceed the fuse "
    "bound (120 rows); fall back to dense"
)


def test_explain_golden_snapshots():
    assert plan(_spec(query_rows=(99, 239, 300))).explain() == GOLDEN_FUSE
    store = plan(_spec(query_rows=tuple(range(0, 480, 3)))).explain()
    assert GOLDEN_STORE_LINE in store.splitlines()
    # plans with no declared rows render no fusion line at all
    assert "query fusion" not in plan(_spec()).explain()


# ---------------------------------------------------------------------------
# end-to-end: engine, service, tracker, likelihood map
# ---------------------------------------------------------------------------
def test_engine_run_fused_bit_exact_vs_dense(rng):
    frame = rng.integers(0, 256, (64, 48), np.uint8)
    qs = [RegionQuery([[4, 4, 20, 20], [10, 2, 30, 40]]),
          SlidingWindowQuery((16, 16), 16)]
    fused_eng = HistogramEngine(8, backend="jnp")
    out = fused_eng.run(frame, qs)
    assert out.plan.representation == "fused"
    dense = DenseH(ops.integral_histogram(frame, 8, backend="jnp"))
    for got, q in zip(out.results, qs):
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(q.apply(dense)))


def test_service_fused_cache_falls_back_on_foreign_rows(rng):
    from repro.serve import AnalyticsService

    store = {0: rng.integers(0, 256, (64, 48), np.uint8)}
    eng = HistogramEngine(8, backend="jnp")
    svc = AnalyticsService(eng, store, cache_size=2)
    q1 = RegionQuery([4, 4, 20, 20])
    svc.process([(0, q1)])
    assert eng.last_plan.representation == "fused"
    # a hit inside the fused rows answers from the cache
    svc.process([(0, q1)])
    assert svc.stats.cache_hits == 1 and svc.stats.engine_runs == 1
    # a hit OUTSIDE them can't — MissingRowsError triggers a re-run
    q2 = RegionQuery([30, 8, 50, 40])
    res = svc.process([(0, q2)])
    assert svc.stats.engine_runs == 2
    dense = DenseH(ops.integral_histogram(store[0], 8, backend="jnp"))
    np.testing.assert_array_equal(np.asarray(res[0]),
                                  np.asarray(q2.apply(dense)))


def test_tracker_step_fused_bit_exact(rng):
    from repro.core.tracking import FragmentTracker, TrackerConfig

    frames = rng.integers(0, 256, (3, 96, 120), np.uint8)
    tr = FragmentTracker(TrackerConfig(num_bins=8, search_radius=2))
    state = tr.init(frames[0], np.array([20, 30, 43, 53]))
    ref = dict(state)
    for f in frames[1:]:
        state = tr.step_fused(state, f)
        ref = tr.step(ref, f)
        np.testing.assert_array_equal(np.asarray(state["bbox"]),
                                      np.asarray(ref["bbox"]))
    assert tr._step_engine.last_plan.representation == "fused"


def test_fused_likelihood_map_matches_dense(rng):
    frame = rng.integers(0, 256, (40, 56), np.uint8)
    model = np.ones(8, np.float32) * 3.0
    got = ops.fused_likelihood_map(
        frame, model, distances.intersection, window=(8, 8), stride=4,
        backend="jnp")
    dense = DenseH(ops.integral_histogram(frame, 8, backend="jnp"))
    want = dense.likelihood_map(model, (8, 8), distances.intersection, 4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


def test_likelihood_query_rides_the_fused_plan(rng):
    frame = rng.integers(0, 256, (64, 48), np.uint8)
    eng = HistogramEngine(8, backend="jnp")
    q = LikelihoodQuery(np.ones(8, np.float32), (16, 16),
                        distances.intersection, 16)
    out = eng.run(frame, [q])
    assert out.plan.representation == "fused"
    dense = DenseH(ops.integral_histogram(frame, 8, backend="jnp"))
    np.testing.assert_allclose(np.asarray(out.results[0]),
                               np.asarray(q.apply(dense)))


# ---------------------------------------------------------------------------
# autotuned priors
# ---------------------------------------------------------------------------
def test_priors_roundtrip_and_plan_pickup(tmp_path, monkeypatch):
    path = tmp_path / "tuned.json"
    key = autotune.config_key(480, 640, 32)
    autotune.save_priors(str(path), {
        key: {"tile": 256, "bin_block": 16, "seconds": 1e-3, "gbps": 40.0},
    })
    assert json.loads(path.read_text())["version"] == 1

    monkeypatch.setenv(autotune.ENV_VAR, str(path))
    p = plan(_spec(query_rows=(99, 239, 300)))
    assert (p.tile, p.bin_block, p.tuned) == (256, 16, key)
    assert f"(tuned prior {key})" in p.explain()
    # an explicit tile is a user decision the prior must not override
    q = plan(_spec(tile=64))
    assert (q.tile, q.tuned) == (64, None)
    # other geometries are untouched
    assert plan(_spec(height=240)).tuned is None


def test_autotune_measures_and_returns_winner():
    entry = autotune.autotune(
        64, 64, 8, backend="jnp", tiles=(64,), bin_blocks=(4, 8),
        repeats=1, memory_budget_bytes=4 * 8 * 16 * 64)
    assert entry["tile"] == 64 and entry["bin_block"] in (4, 8)
    assert entry["seconds"] > 0 and entry["gbps"] > 0
    assert 1 <= entry["band_h"] <= 16
