"""repro.compat: the jax version shims must work on whatever jax is
installed — these run single-device (shard_map over a 1-device mesh)."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import make_mesh, shard_map


def test_make_mesh_single_device():
    m = make_mesh((1,), ("data",))
    assert m.shape == {"data": 1}
    # axis_types explicitly passed is tolerated on every jax version
    m2 = make_mesh((1,), ("data",), axis_types=None)
    assert m2.shape == {"data": 1}


def test_shard_map_check_vma_translation():
    mesh = make_mesh((1,), ("x",))
    x = jnp.arange(8.0)
    out = shard_map(
        lambda v: v * 2.0, mesh=mesh, in_specs=P("x"), out_specs=P("x"),
        check_vma=False,
    )(x)
    np.testing.assert_allclose(np.asarray(out), np.arange(8.0) * 2.0)
    # default (None) must also work
    out = shard_map(
        lambda v: v + 1.0, mesh=mesh, in_specs=P("x"), out_specs=P("x"),
    )(x)
    np.testing.assert_allclose(np.asarray(out), np.arange(8.0) + 1.0)


def test_host_mesh_helper():
    from repro.launch.mesh import make_host_mesh

    m = make_host_mesh()
    assert m.shape == {"data": 1, "model": len(jax.devices())}
