"""Per-architecture smoke tests (reduced configs, real forward/train step
on CPU) + decode-vs-forward consistency + family invariants."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.models import api
from repro.models.ssm import ssd_chunked

RNG = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=32, with_labels=True):
    batch = {"tokens": jax.random.randint(RNG, (b, s), 0, cfg.vocab_size)}
    if with_labels:
        batch["labels"] = jax.random.randint(RNG, (b, s), 0, cfg.vocab_size)
    if cfg.family == "vlm":
        batch["prefix_embeds"] = 0.02 * jnp.ones(
            (b, cfg.num_prefix_embeds, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        batch["src_embeds"] = 0.02 * jnp.ones(
            (b, s, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_and_finiteness(arch):
    cfg = smoke_config(arch)
    params = api.init_params(RNG, cfg)
    batch = _batch(cfg)
    logits, aux, _ = api.forward(params, batch, cfg)
    s_total = 32 + (cfg.num_prefix_embeds if cfg.family == "vlm" else 0)
    assert logits.shape == (2, s_total, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step_runs_and_loss_finite(arch):
    from repro.train import init_state, make_optimizer, make_train_step

    cfg = smoke_config(arch)
    opt = make_optimizer(cfg, peak_lr=1e-3, warmup=2, total_steps=10)
    state = init_state(RNG, cfg, opt)
    step = jax.jit(make_train_step(cfg, opt))
    batch = _batch(cfg)
    state, metrics = step(state, batch)
    assert int(state["step"]) == 1
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0.0


@pytest.mark.parametrize("arch", ["qwen3-4b", "llama4-scout-17b-a16e",
                                  "mamba2-130m", "recurrentgemma-9b",
                                  "seamless-m4t-large-v2"])
def test_decode_matches_forward(arch):
    """Prefill+decode logits == full-forward logits (one per family).

    MoE uses a generous capacity factor: capacity drops legitimately
    differ between 16- and 17-token routing, so we remove drops to test
    the cache machinery itself.
    """
    cfg = smoke_config(arch)
    cfg = dataclasses.replace(cfg, capacity_factor=8.0, dtype="float32")
    params = api.init_params(RNG, cfg)
    B, S = 2, 16
    toks = jax.random.randint(RNG, (B, S + 1), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :S]}
    if cfg.family == "vlm":
        batch["prefix_embeds"] = 0.02 * jnp.ones(
            (B, cfg.num_prefix_embeds, cfg.d_model), jnp.float32)
    if cfg.family == "audio":
        batch["src_embeds"] = 0.02 * jnp.ones((B, S, cfg.d_model), jnp.float32)
    kw = {"src_len": S} if cfg.family == "audio" else {}
    # fp32 cache: bf16 KV rounding flips top-1 routing ties (scout),
    # which is quantization sensitivity, not cache-machinery error.
    cache = api.init_cache(cfg, B, 32, dtype=jnp.float32, **kw)
    lg_pre, cache = api.prefill(params, batch, cfg, cache)
    lg_dec, cache = api.decode_step(params, toks[:, S:S + 1], cfg, cache)
    full = dict(batch)
    full["tokens"] = toks
    lg_full, _, _ = api.forward(params, full, cfg)
    np.testing.assert_allclose(np.asarray(lg_dec),
                               np.asarray(lg_full[:, -1]), atol=0.02)


@pytest.mark.parametrize("arch", ["llama3-8b", "kimi-k2-1t-a32b",
                                  "recurrentgemma-9b", "mamba2-130m"])
def test_scan_vs_unroll(arch):
    cfg = smoke_config(arch)
    cfg = dataclasses.replace(cfg, dtype="float32", capacity_factor=8.0)
    params = api.init_params(RNG, cfg)
    batch = _batch(cfg, with_labels=False)
    lg1, _, _ = api.forward(params, batch, cfg)
    lg2, _, _ = api.forward(
        params, batch, dataclasses.replace(cfg, scan_layers=False))
    np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2), atol=0.02)


def test_chunked_attention_matches_dense():
    from repro.models.layers import attention, attention_chunked

    k1, k2, k3 = jax.random.split(RNG, 3)
    B, S, H, HKV, D = 2, 100, 8, 2, 16
    q = jax.random.normal(k1, (B, S, H, D))
    k = jax.random.normal(k2, (B, S, HKV, D))
    v = jax.random.normal(k3, (B, S, HKV, D))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    dense = attention(q, k, v, positions_q=pos, positions_kv=pos, causal=True)
    chunked = attention_chunked(q, k, v, positions_q=pos, positions_kv=pos,
                                causal=True, block_kv=32)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(chunked),
                               atol=2e-5)
    # sliding-window variant
    dense_w = attention(q, k, v, positions_q=pos, positions_kv=pos,
                        causal=True, sliding_window=17)
    chunk_w = attention_chunked(q, k, v, positions_q=pos, positions_kv=pos,
                                causal=True, sliding_window=17, block_kv=16)
    np.testing.assert_allclose(np.asarray(dense_w), np.asarray(chunk_w),
                               atol=2e-5)


def test_ssd_chunk_invariance():
    """SSD output must not depend on the chunk size (carry correctness)."""
    k1, k2, k3, k4 = jax.random.split(RNG, 4)
    B, S, H, P, G, N = 2, 50, 4, 8, 1, 16
    x = jax.random.normal(k1, (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(k2, (B, S, H)))
    A = -jnp.exp(jax.random.normal(k3, (H,)) * 0.2)
    Bm = jax.random.normal(k4, (B, S, G, N)) * 0.3
    Cm = jax.random.normal(k1, (B, S, G, N)) * 0.3
    y1, h1 = ssd_chunked(x, dt, A, Bm, Cm, chunk=5)
    y2, h2 = ssd_chunked(x, dt, A, Bm, Cm, chunk=50)
    y3, h3 = ssd_chunked(x, dt, A, Bm, Cm, chunk=16)   # padding path
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y3), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h3), atol=1e-4)


def test_ssd_matches_sequential_recurrence():
    """Chunked SSD == naive per-step recurrence (the definition)."""
    k1, k2, k3, k4 = jax.random.split(RNG, 4)
    B, S, H, P, N = 1, 20, 2, 4, 8
    x = jax.random.normal(k1, (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(k2, (B, S, H)))
    A = -jnp.exp(jax.random.normal(k3, (H,)) * 0.2)
    Bm = jax.random.normal(k4, (B, S, 1, N)) * 0.3
    Cm = jax.random.normal(k1, (B, S, 1, N)) * 0.3
    y, _ = ssd_chunked(x, dt, A, Bm, Cm, chunk=7)
    h = np.zeros((B, H, N, P))
    for t in range(S):
        a = np.exp(np.asarray(dt[:, t] * A))             # (B,H)
        outer = np.einsum("bn,bhp->bhnp", np.asarray(Bm[:, t, 0]),
                          np.asarray(x[:, t] * dt[:, t][..., None]))
        h = h * a[..., None, None] + outer
        want = np.einsum("bn,bhnp->bhp", np.asarray(Cm[:, t, 0]), h)
        np.testing.assert_allclose(np.asarray(y[:, t]), want, atol=1e-4)


def test_full_configs_param_counts():
    """Exact configs must hit published parameter scales (6ND sanity)."""
    n = {a: get_config(a).param_count() for a in ARCH_IDS}
    assert 7.5e9 < n["llama3-8b"] < 8.5e9
    assert 0.9e12 < n["kimi-k2-1t-a32b"] < 1.2e12
    assert 95e9 < n["llama4-scout-17b-a16e"] < 120e9
    assert 2.5e9 < n["qwen2.5-3b"] < 3.7e9
    assert 3.2e9 < n["qwen3-4b"] < 4.8e9
    assert 1.2e9 < n["qwen2-1.5b"] < 2.0e9
    assert 6.5e9 < n["llava-next-mistral-7b"] < 7.8e9
    assert 0.10e9 < n["mamba2-130m"] < 0.2e9
    assert 7.5e9 < n["recurrentgemma-9b"] < 11e9
    a = get_config("kimi-k2-1t-a32b").active_param_count()
    assert 25e9 < a < 45e9
    a = get_config("llama4-scout-17b-a16e").active_param_count()
    assert 14e9 < a < 22e9


def test_ring_cache_long_decode():
    """Sliding-window decode at positions far beyond the window."""
    cfg = smoke_config("recurrentgemma-9b")
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = api.init_params(RNG, cfg)
    B = 1
    cache = api.init_cache(cfg, B, 64)
    # prefill 48 tokens (window is 32), then decode: must stay finite
    toks = jax.random.randint(RNG, (B, 60), 0, cfg.vocab_size)
    _, cache = api.prefill(params, {"tokens": toks[:, :48]}, cfg, cache)
    for t in range(48, 52):
        lg, cache = api.decode_step(params, toks[:, t:t + 1], cfg, cache)
        assert bool(jnp.all(jnp.isfinite(lg)))
    assert int(cache["len"]) == 52


def test_rglru_matches_sequential_recurrence():
    """Chunked RG-LRU == naive per-step recurrence (the definition)."""
    from repro.models.griffin import _rglru_chunked, _rglru_gates

    k1, k2 = jax.random.split(RNG)
    B, S, W = 2, 23, 8
    u = jax.random.normal(k1, (B, S, W))
    p = {"lam": jnp.linspace(2.0, 6.0, W),
         "g_a": 0.3 * jax.random.normal(k2, (W,)),
         "b_a": jnp.zeros((W,)),
         "g_x": 0.1 * jnp.ones((W,)),
         "b_x": jnp.zeros((W,))}
    h0 = jax.random.normal(k2, (B, W)) * 0.1
    hs, h_last = _rglru_chunked(u, p, chunk=7, h0=h0)     # padding path
    log_a, bgate = _rglru_gates(u, p)
    h = np.asarray(h0)
    for t in range(S):
        h = np.exp(np.asarray(log_a[:, t])) * h + np.asarray(bgate[:, t])
        np.testing.assert_allclose(np.asarray(hs[:, t]), h, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_last), h, atol=1e-5)


def test_sp_ssd_matches_single_device():
    """Sequence-parallel SSD (ppermute carry wavefront) == local scan,
    run on 8 forced host devices in a subprocess."""
    import os
    import subprocess
    import sys
    import textwrap

    code = textwrap.dedent("""
        import warnings; warnings.filterwarnings("ignore")
        import dataclasses, jax, jax.numpy as jnp
        from repro.configs import smoke_config
        from repro.models import api
        from repro.sharding.rules import ShardingRules, sharding_context
        cfg = dataclasses.replace(smoke_config("mamba2-130m"),
                                  dtype="float32", ssm_seq_parallel=True)
        params = api.init_params(jax.random.PRNGKey(0), cfg)
        batch = {"tokens": jax.random.randint(
            jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab_size)}
        ref, _, _ = api.forward(
            params, batch, dataclasses.replace(cfg, ssm_seq_parallel=False))
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with mesh, sharding_context(mesh, ShardingRules()):
            sp, _, _ = jax.jit(lambda p, b: api.forward(p, b, cfg))(
                params, batch)
        err = float(jnp.max(jnp.abs(ref - sp)))
        assert err < 1e-3, err
        print("SP OK", err)
    """)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=420)
    assert proc.returncode == 0, proc.stderr[-1500:]
    assert "SP OK" in proc.stdout


def test_sp_rglru_matches_single_device():
    """Sequence-parallel RG-LRU == local scan (8 forced host devices)."""
    import os
    import subprocess
    import sys
    import textwrap

    code = textwrap.dedent("""
        import warnings; warnings.filterwarnings("ignore")
        import dataclasses, jax, jax.numpy as jnp
        from repro.configs import smoke_config
        from repro.models import api
        from repro.sharding.rules import ShardingRules, sharding_context
        cfg = dataclasses.replace(smoke_config("recurrentgemma-9b"),
                                  dtype="float32", rnn_seq_parallel=True)
        params = api.init_params(jax.random.PRNGKey(0), cfg)
        batch = {"tokens": jax.random.randint(
            jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab_size)}
        ref, _, _ = api.forward(
            params, batch, dataclasses.replace(cfg, rnn_seq_parallel=False))
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with mesh, sharding_context(mesh, ShardingRules()):
            sp, _, _ = jax.jit(lambda p, b: api.forward(p, b, cfg))(
                params, batch)
        err = float(jnp.max(jnp.abs(ref - sp)))
        assert err < 1e-3, err
        print("rglru SP OK", err)
    """)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=420)
    assert proc.returncode == 0, proc.stderr[-1500:]
    assert "rglru SP OK" in proc.stdout
