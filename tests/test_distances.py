"""Direct unit tests for core/distances.py (previously only covered
transitively through the analytics layer).

Pins: metric bounds, the identical/disjoint-histogram fixed points,
leading-axis broadcasting, and the PR 2 bhattacharyya eps-bias
regression (eps inside the sqrt pushed identical histograms above 1)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import distances
from repro.core.distances import (
    DISTANCES,
    SIMILARITIES,
    bhattacharyya,
    chi2,
    intersection,
    l1,
    l2,
    normalize,
)

ALL_METRICS = {**SIMILARITIES, **DISTANCES}


def _hists(rng, shape=(40,), bins=16):
    return jnp.asarray(
        rng.integers(0, 100, shape + (bins,)).astype(np.float32))


def test_normalize_sums_to_one(rng):
    h = _hists(rng)
    np.testing.assert_allclose(
        np.asarray(jnp.sum(normalize(h), axis=-1)), 1.0, atol=1e-5)


@pytest.mark.parametrize("name", sorted(ALL_METRICS))
def test_metric_bounds(rng, name):
    """intersection/bhattacharyya in [0, 1]; chi2 in [0, 1]; l1 in
    [0, 2]; l2 in [0, sqrt(2)] — on normalized inputs."""
    metric = ALL_METRICS[name]
    a, b = _hists(rng), _hists(rng)
    out = np.asarray(metric(a, b))
    hi = {"intersection": 1.0, "bhattacharyya": 1.0, "chi2": 1.0,
          "l1": 2.0, "l2": np.sqrt(2.0)}[name]
    assert out.shape == (40,)
    assert (out >= -1e-6).all()
    assert (out <= hi + 1e-5).all()


@pytest.mark.parametrize("name", sorted(ALL_METRICS))
def test_identical_histogram_fixed_point(rng, name):
    """Similarity of a histogram with itself is maximal (1); distance
    is 0 — including scale invariance (2h vs h)."""
    metric = ALL_METRICS[name]
    h = _hists(rng)
    for other in (h, 2.0 * h):
        out = np.asarray(metric(h, other))
        want = 1.0 if name in SIMILARITIES else 0.0
        np.testing.assert_allclose(out, want, atol=2e-3)


@pytest.mark.parametrize("name", sorted(ALL_METRICS))
def test_disjoint_histogram_fixed_point(name):
    """Non-overlapping histograms: similarity 0, distance maximal."""
    metric = ALL_METRICS[name]
    a = jnp.asarray([10.0, 20.0, 0.0, 0.0])
    b = jnp.asarray([0.0, 0.0, 5.0, 15.0])
    out = float(metric(a, b))
    want = {"intersection": 0.0, "bhattacharyya": 0.0, "chi2": 1.0,
            "l1": 2.0, "l2": None}[name]
    if name == "l2":
        assert out > 0.5
    else:
        np.testing.assert_allclose(out, want, atol=2e-3)


@pytest.mark.parametrize("name", sorted(ALL_METRICS))
def test_leading_axis_broadcasting(rng, name):
    """(n, m, b) vs (b,) -> (n, m), matching the scalar loop."""
    metric = ALL_METRICS[name]
    stack = _hists(rng, shape=(3, 5))
    target = _hists(rng, shape=())
    out = np.asarray(metric(stack, target))
    assert out.shape == (3, 5)
    for i in range(3):
        for j in range(5):
            np.testing.assert_allclose(
                out[i, j], float(metric(stack[i, j], target)), rtol=1e-5)


def test_bhattacharyya_eps_bias_regression():
    """PR 2: eps must stay OUT of the per-bin sqrt.  At 128 bins an
    in-sqrt eps scored identical histograms ~1.0127 and disjoint ones
    ~0.0128; the fixed metric pins both ends of [0, 1] tightly."""
    bins = 128
    h = jnp.zeros((bins,)).at[3].set(100.0)
    same = float(bhattacharyya(h, h))
    assert same <= 1.0 + 1e-6
    np.testing.assert_allclose(same, 1.0, atol=1e-4)
    a = jnp.zeros((bins,)).at[0].set(50.0)
    b = jnp.zeros((bins,)).at[1].set(50.0)
    disjoint = float(bhattacharyya(a, b))
    assert abs(disjoint) < 1e-5          # the buggy metric gave ~0.0128


def test_intersection_is_symmetric_and_monotone(rng):
    a, b = _hists(rng), _hists(rng)
    np.testing.assert_allclose(np.asarray(intersection(a, b)),
                               np.asarray(intersection(b, a)), rtol=1e-6)
    # mixing b toward a raises the intersection score
    mixed = 0.5 * (normalize(a) + normalize(b))
    closer = np.asarray(intersection(a, mixed))
    apart = np.asarray(intersection(a, b))
    assert (closer >= apart - 1e-5).all()


def test_chi2_l1_l2_metric_axioms(rng):
    a, b = _hists(rng), _hists(rng)
    for d in (chi2, l1, l2):
        np.testing.assert_allclose(np.asarray(d(a, b)),
                                   np.asarray(d(b, a)), rtol=1e-5)
        assert (np.asarray(d(a, b)) >= -1e-6).all()
    # l1/l2 triangle inequality through a third histogram
    c = _hists(rng)
    for d in (l1, l2):
        ab = np.asarray(d(a, b))
        thru = np.asarray(d(a, c)) + np.asarray(d(c, b))
        assert (ab <= thru + 1e-4).all()


def test_registries_are_consistent():
    assert set(SIMILARITIES) == {"intersection", "bhattacharyya"}
    assert set(DISTANCES) == {"chi2", "l1", "l2"}
    for name, fn in ALL_METRICS.items():
        assert getattr(distances, name) is fn
