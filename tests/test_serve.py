"""AnalyticsService (repro/serve): coalescing, caching, backpressure.

Acceptance (ISSUE 5): >= 2 same-frame queries coalesce into ONE engine
run (compute-count probe), results are bit-exact vs direct engine runs,
the HSource LRU behaves, and a full submit queue rejects loudly.
"""

import threading

import numpy as np
import pytest

from repro.core import distances
from repro.core.engine import (
    HistogramEngine,
    LikelihoodQuery,
    RegionQuery,
    SlidingWindowQuery,
)
from repro.serve import AnalyticsService, ServiceOverloaded


@pytest.fixture()
def store(rng):
    return {i: rng.integers(0, 256, (32, 24), dtype=np.uint8)
            for i in range(6)}


def _probed_engine(**kw):
    """Engine + a counter incremented on every H computation."""
    eng = HistogramEngine(8, backend="jnp", **kw)
    calls = []
    orig = eng.compute

    def probe(*a, **k):
        calls.append(1)
        return orig(*a, **k)

    eng.compute = probe
    return eng, calls


RECTS = np.array([2, 2, 10, 10])


def test_same_frame_queries_coalesce_into_one_run(store):
    eng, calls = _probed_engine()
    svc = AnalyticsService(eng, store)
    res = svc.process([
        (0, RegionQuery(RECTS)),
        (0, SlidingWindowQuery((8, 8), 4)),
        (0, LikelihoodQuery(np.ones(8, np.float32), (8, 8),
                            distances.intersection, 4)),
        (1, RegionQuery(RECTS)),
    ])
    assert len(calls) == 2              # frame 0 ONE run for 3 queries
    assert svc.stats.engine_runs == 2
    assert svc.stats.coalesced == 2
    # bit-exact vs direct engine runs
    direct0 = eng.run(store[0], [RegionQuery(RECTS),
                                 SlidingWindowQuery((8, 8), 4)])
    np.testing.assert_array_equal(np.asarray(res[0]),
                                  np.asarray(direct0.results[0]))
    np.testing.assert_array_equal(np.asarray(res[1]),
                                  np.asarray(direct0.results[1]))
    direct1 = eng.run(store[1], [RegionQuery(RECTS)])
    np.testing.assert_array_equal(np.asarray(res[3]),
                                  np.asarray(direct1.results[0]))


def test_cache_hit_skips_compute_and_lru_evicts(store):
    eng, calls = _probed_engine()
    svc = AnalyticsService(eng, store, cache_size=2)
    svc.process([(0, RegionQuery(RECTS))])
    svc.process([(0, RegionQuery(RECTS))])          # hit
    assert len(calls) == 1
    assert svc.stats.cache_hits == 1
    svc.process([(1, RegionQuery(RECTS))])
    svc.process([(2, RegionQuery(RECTS))])          # evicts 0 (LRU)
    assert svc.cached_frames == (1, 2)
    svc.process([(0, RegionQuery(RECTS))])          # miss again
    assert len(calls) == 4
    # hit results identical to miss results
    a = svc.process([(2, RegionQuery(RECTS))])[0]   # hit
    b = eng.run(store[2], [RegionQuery(RECTS)]).results[0]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_cache_disabled(store):
    eng, calls = _probed_engine()
    svc = AnalyticsService(eng, store, cache_size=0)
    svc.process([(0, RegionQuery(RECTS))])
    svc.process([(0, RegionQuery(RECTS))])
    assert len(calls) == 2 and svc.cached_frames == ()
    assert svc.stats.cache_hits == 0


def test_banded_engine_cache_hits_replay_the_stream(store):
    """A banded plan caches the replayable BandedH; hits re-stream with
    the multi-query corner-row union, results bit-exact vs dense."""
    budget = 4 * 8 * 24 * 8             # 8-row bands for 32x24 @ 8 bins
    eng, calls = _probed_engine(memory_budget_bytes=budget)
    svc = AnalyticsService(eng, store, cache_size=2)
    # stride 4 keeps the corner-row union above the query-fusion bound
    # (h // 4 rows) so the planner stays banded rather than fusing.
    qs = [RegionQuery(RECTS), SlidingWindowQuery((8, 8), 4)]
    first = svc.process([(3, q) for q in qs])
    assert eng.last_plan.representation == "banded"
    again = svc.process([(3, q) for q in qs])       # cache hit, 2 queries
    assert len(calls) == 1
    dense = HistogramEngine(8, backend="jnp").run(store[3], qs).results
    for got in (first, again):
        for g, want in zip(got, dense):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(want))


def test_threaded_submit_and_futures(store):
    eng, calls = _probed_engine()
    with AnalyticsService(eng, store, cache_size=4) as svc:
        futs = [svc.submit(i % 2, RegionQuery(RECTS), block=True)
                for i in range(10)]
        outs = [f.result(timeout=60) for f in futs]
    assert len(outs) == 10
    assert len(calls) <= 2              # 2 distinct frames
    want = eng.run(store[0], [RegionQuery(RECTS)]).results[0]
    np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(want))
    snap = svc.stats.snapshot()
    assert snap["completed"] == 10
    assert snap["requests"] == 10
    assert snap["requests_per_s"] > 0
    assert snap["latency_p95_s"] >= snap["latency_p50_s"] >= 0


def test_backpressure_rejects_when_queue_full(store):
    eng, _ = _probed_engine()
    svc = AnalyticsService(eng, store, max_pending=2)
    # not started: submit refuses outright
    with pytest.raises(RuntimeError, match="not started"):
        svc.submit(0, RegionQuery(RECTS))
    # fill the queue while the worker is blocked on a slow resolver
    gate = threading.Event()

    def slow_resolve(ref):
        gate.wait(timeout=60)
        return store[ref]

    svc2 = AnalyticsService(eng, slow_resolve, max_pending=2,
                            max_coalesce=1).start()
    try:
        futs = [svc2.submit(0, RegionQuery(RECTS))]   # worker takes this
        import time
        deadline = time.time() + 5
        overloaded = False
        while time.time() < deadline and not overloaded:
            try:
                futs.append(svc2.submit(1, RegionQuery(RECTS)))
            except ServiceOverloaded:
                overloaded = True
        assert overloaded
        assert svc2.stats.rejected >= 1
    finally:
        gate.set()
        svc2.close()
    for f in futs:
        f.result(timeout=60)


def test_close_fails_requests_that_raced_past_the_worker(store):
    """A submit landing on the queue after the worker's final drain must
    not hang forever — close() fails its future."""
    from repro.serve.service import _Pending
    from concurrent.futures import Future

    eng, _ = _probed_engine()
    svc = AnalyticsService(eng, store).start()
    svc.close()
    p = _Pending(0, RegionQuery(RECTS), 0.0, Future())
    svc._queue.put_nowait(p)             # the race, made deterministic
    svc.close()
    with pytest.raises(RuntimeError, match="closed before"):
        p.future.result(timeout=1)


def test_worker_failure_lands_on_the_future(store):
    eng, _ = _probed_engine()

    def resolve(ref):
        raise KeyError(f"no frame {ref}")

    with AnalyticsService(eng, resolve) as svc:
        fut = svc.submit(99, RegionQuery(RECTS), block=True)
        with pytest.raises(KeyError):
            fut.result(timeout=60)


def test_bad_config_rejected(store):
    eng, _ = _probed_engine()
    for kw in (dict(cache_size=-1), dict(max_pending=0),
               dict(max_coalesce=0), dict(cache_bytes=-1)):
        with pytest.raises(ValueError):
            AnalyticsService(eng, store, **kw)


# ---------------------------------------------------------------------------
# video-delta chaining + byte-aware cache bound (ISSUE 9)
# ---------------------------------------------------------------------------
def _video_store(rng, n=5, h=32, w=24):
    """Low-motion stream keyed by frame number: each frame rewrites a
    few rows of its predecessor."""
    frames = [rng.integers(0, 256, (h, w), dtype=np.uint8)]
    for _ in range(n - 1):
        nxt = frames[-1].copy()
        r = int(rng.integers(0, h - 3))
        nxt[r:r + 3] = rng.integers(0, 256, (3, w), dtype=np.uint8)
        frames.append(nxt)
    return {i: f for i, f in enumerate(frames)}


# 6 rects at distinct rows -> 12 corner rows > 32/4, so plans stay
# dense (a fused plan never stores H and cannot seed the chain).
DENSE_RECTS = np.array([[3 * i, 2, 3 * i + 1, 10] for i in range(6)])


def test_video_chain_updates_cached_h(rng):
    store = _video_store(rng)
    eng, calls = _probed_engine()
    svc = AnalyticsService(eng, store)
    res = svc.process([(i, RegionQuery(DENSE_RECTS))
                       for i in range(len(store))])
    snap = svc.stats.snapshot()
    # frame 0 recomputes; every successor updates its predecessor's H
    assert snap["recomputed"] == 1
    assert snap["updated"] == len(store) - 1
    assert snap["update_ratio"] == pytest.approx(
        (len(store) - 1) / len(store))
    assert len(calls) == 1              # compute() ran once; rest updated
    # bit-exact vs fresh engine runs per frame
    for i in range(len(store)):
        want = HistogramEngine(8, backend="jnp").run(
            store[i], [RegionQuery(DENSE_RECTS)]).results[0]
        np.testing.assert_array_equal(np.asarray(res[i]),
                                      np.asarray(want))


def test_video_chain_disabled_by_predecessor_resolver(rng):
    store = _video_store(rng, n=3)
    eng, _ = _probed_engine()
    svc = AnalyticsService(eng, store, predecessor=lambda ref: None)
    svc.process([(i, RegionQuery(DENSE_RECTS)) for i in range(3)])
    snap = svc.stats.snapshot()
    assert snap["updated"] == 0 and snap["recomputed"] == 3


def test_video_chain_survives_missing_predecessor_frame(rng):
    """Predecessor H cached but its frame gone from the store: the miss
    recomputes instead of failing."""
    store = _video_store(rng, n=2)
    eng, _ = _probed_engine()
    svc = AnalyticsService(eng, store)
    svc.process([(0, RegionQuery(DENSE_RECTS))])
    del store[0]
    out = svc.process([(1, RegionQuery(DENSE_RECTS))])
    snap = svc.stats.snapshot()
    assert snap["updated"] == 0 and snap["recomputed"] == 2
    want = HistogramEngine(8, backend="jnp").run(
        svc._resolve(1), [RegionQuery(DENSE_RECTS)]).results[0]
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(want))


def test_cache_bytes_bound_evicts_by_size(rng):
    store = _video_store(rng)
    one = 4 * 8 * 32 * 24               # dense H bytes per frame
    eng, _ = _probed_engine()
    svc = AnalyticsService(eng, store, cache_bytes=2 * one)
    svc.process([(i, RegionQuery(DENSE_RECTS)) for i in range(5)])
    assert svc.cached_frames == (3, 4)  # LRU-evicted down to 2 entries
    # an entry alone over the bound cannot stay cached
    svc2 = AnalyticsService(eng, store, cache_bytes=one - 1)
    svc2.process([(0, RegionQuery(DENSE_RECTS))])
    assert svc2.cached_frames == ()


def test_snapshot_counts_hits_beside_update_split(rng):
    store = _video_store(rng, n=2)
    eng, _ = _probed_engine()
    svc = AnalyticsService(eng, store)
    svc.process([(0, RegionQuery(DENSE_RECTS))])
    svc.process([(0, RegionQuery(DENSE_RECTS))])    # cache hit
    svc.process([(1, RegionQuery(DENSE_RECTS))])    # chained update
    snap = svc.stats.snapshot()
    assert snap["hit"] == 1 == snap["cache_hits"]
    assert snap["recomputed"] == 1 and snap["updated"] == 1


# ---------------------------------------------------------------------------
# DistributedAnalyticsService (mesh-scale serving; 8-device runs live in
# test_distributed.py's subprocess tests)
# ---------------------------------------------------------------------------
def _dist_factory():
    from repro.serve import sharded_engine_factory

    return sharded_engine_factory(8, backend="jnp")


def test_distributed_service_parity_and_chain_pinning(rng):
    """Routed traffic is bit-exact vs a single service on the same trace,
    and a PR 9 video chain routes to ONE replica so every incremental
    update stays local."""
    from repro.serve import DistributedAnalyticsService

    store = _video_store(rng)
    trace = [(i, RegionQuery(DENSE_RECTS)) for i in range(5)]
    trace += [(2, RegionQuery(DENSE_RECTS)), (4, SlidingWindowQuery((8, 8), 4))]
    dist = DistributedAnalyticsService(_dist_factory(), store, num_replicas=3)
    single = AnalyticsService(HistogramEngine(8, backend="jnp"), store)
    got = dist.process(list(trace))
    want = single.process(list(trace))
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    routes = [dist.replica_for(i) for i in range(5)]
    assert len(set(routes)) == 1
    snap = dist.snapshot()
    assert snap["requests"] == len(trace)
    assert snap["num_replicas"] == 3 and len(snap["replicas"]) == 3
    # the whole chain updated on one replica; the others ran nothing
    per_updated = [p["updated"] for p in snap["replicas"]]
    assert sum(per_updated) == 4
    assert sum(1 for u in per_updated if u) == 1


def test_distributed_routing_is_deterministic_across_instances(rng):
    """Consistent hashing: two independently built services route every
    ref identically (no salted/process-local hashing)."""
    from repro.serve import DistributedAnalyticsService

    store = _video_store(rng)
    kw = dict(num_replicas=4, predecessor=lambda r: None)
    a = DistributedAnalyticsService(_dist_factory(), store, **kw)
    b = DistributedAnalyticsService(_dist_factory(), store, **kw)
    refs = list(range(32)) + ["cam0/17", "cam1/17"]
    assert [a.replica_for(r) for r in refs] == [b.replica_for(r) for r in refs]
    # and the ring spreads refs over more than one replica
    assert len({a.replica_for(r) for r in refs}) > 1


def test_distributed_aggregate_backpressure(rng):
    """max_pending bounds TOTAL outstanding submits across replicas."""
    from repro.serve import DistributedAnalyticsService, ServiceOverloaded

    gate = threading.Event()
    frame = rng.integers(0, 256, (32, 24), dtype=np.uint8)

    def resolve(ref):
        gate.wait(timeout=10)
        return frame

    svc = DistributedAnalyticsService(
        _dist_factory(), resolve, num_replicas=2, max_pending=3,
        predecessor=lambda r: None)
    q = RegionQuery(RECTS)
    with svc:
        futs = [svc.submit(i, q) for i in range(3)]
        with pytest.raises(ServiceOverloaded):
            svc.submit(99, q)
        gate.set()
        outs = [f.result(timeout=30) for f in futs]
    assert all(o is not None for o in outs)
    snap = svc.snapshot()
    assert snap["rejected"] == 1 and snap["completed"] == 3
    # the in-flight window drained back to zero after the futures resolved
    assert svc._inflight == 0


def test_distributed_aggregate_cache_bytes_split(rng):
    """The aggregate byte budget splits across replicas, so the total
    cache residency stays bounded no matter how traffic skews."""
    from repro.serve import DistributedAnalyticsService

    store = _video_store(rng)
    one = 4 * 8 * 32 * 24               # dense H bytes per frame
    svc = DistributedAnalyticsService(
        _dist_factory(), store, num_replicas=2, cache_bytes=2 * one,
        predecessor=lambda r: None)
    svc.process([(i, RegionQuery(DENSE_RECTS)) for i in range(5)])
    assert all(r.cache_bytes == one for r in svc.replicas)
    cached = sum(len(c) for c in svc.cached_frames)
    assert cached <= 2                  # one H per replica fits the split


def test_sharded_h_nbytes_tracks_storage_dtype():
    """Satellite: ShardedH.nbytes reports the real array footprint (the
    inherited planner estimate assumed 4-byte elements, so byte-aware
    cache eviction mis-charged sharded sources)."""
    import jax
    import jax.numpy as jnp

    from repro.core.hsource import ShardedH

    mesh = jax.make_mesh((1,), ("model",))
    f32 = ShardedH(jnp.zeros((8, 16, 12), jnp.float32), mesh, kind="bin")
    assert f32.nbytes == 8 * 16 * 12 * 4
    u16 = ShardedH(jnp.zeros((8, 16, 12), jnp.uint16), mesh, kind="bin")
    assert u16.nbytes == 8 * 16 * 12 * 2
