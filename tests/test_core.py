"""Core integral-histogram semantics: the four methods, O(1) queries,
analytics — including the central hypothesis property (Eq. 2 == direct
histogram for arbitrary regions)."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import distances, scans
from repro.core.integral_histogram import IntegralHistogram
from repro.core.region_query import (
    likelihood_map, region_histogram, sliding_window_histograms,
)
from repro.core.tracking import FragmentTracker, TrackerConfig
from repro.kernels.ref import integral_histogram_ref, region_histogram_ref


@pytest.mark.parametrize("method", sorted(scans.METHODS))
def test_methods_match_oracle(rng, method):
    img = rng.integers(0, 256, (96, 64), dtype=np.uint8)
    ref = integral_histogram_ref(jnp.asarray(img), 16)
    out = scans.METHODS[method](jnp.asarray(img), 16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    r0=st.integers(0, 47), c0=st.integers(0, 63),
    bins=st.sampled_from([4, 16]),
)
def test_property_region_query_eq2(seed, r0, c0, bins):
    """Paper Eq. 2: 4-corner combination == direct region histogram."""
    r = np.random.default_rng(seed)
    img = r.integers(0, 256, (48, 64), dtype=np.uint8)
    r1 = r.integers(r0, 48)
    c1 = r.integers(c0, 64)
    H = integral_histogram_ref(jnp.asarray(img), bins)
    got = region_histogram(H, jnp.array([r0, c0, r1, c1]))
    want = region_histogram_ref(jnp.asarray(img), bins, r0, c0, r1, c1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3)


def test_sliding_windows_all_positions(rng):
    img = rng.integers(0, 256, (24, 30), dtype=np.uint8)
    H = integral_histogram_ref(jnp.asarray(img), 8)
    wins = sliding_window_histograms(H, (8, 10), stride=2)
    assert wins.shape == ((24 - 8) // 2 + 1, (30 - 10) // 2 + 1, 8)
    # each window histogram sums to window area
    np.testing.assert_allclose(np.asarray(jnp.sum(wins, -1)), 80.0)


def test_histogram_metrics_identities():
    h = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    assert float(distances.intersection(h, h)) == pytest.approx(1.0, abs=1e-5)
    assert float(distances.bhattacharyya(h, h)) == pytest.approx(1.0, abs=1e-2)
    assert float(distances.chi2(h, h)) == pytest.approx(0.0, abs=1e-6)
    g = jnp.asarray([4.0, 3.0, 2.0, 1.0])
    assert float(distances.intersection(h, g)) < 1.0
    assert float(distances.chi2(h, g)) > 0.0


def test_likelihood_map_peaks_on_target(rng):
    """A bright square on dark background: the map must peak on it."""
    img = np.zeros((64, 64), np.uint8)
    img[20:36, 30:46] = 250
    H = integral_histogram_ref(jnp.asarray(img), 16)
    target = region_histogram(H, jnp.array([20, 30, 35, 45]))
    smap = likelihood_map(H, target, (16, 16), distances.intersection)
    r, c = np.unravel_index(int(jnp.argmax(smap)), smap.shape)
    assert abs(r - 20) <= 2 and abs(c - 30) <= 2


def test_fragment_tracker_follows_blob():
    """Tracker must follow a moving bright blob across frames."""
    def frame(cy, cx):
        img = (10 * np.random.default_rng(0).random((96, 96))).astype(np.uint8)
        yy, xx = np.mgrid[0:96, 0:96]
        blob = 220 * np.exp(-((yy - cy) ** 2 + (xx - cx) ** 2) / 60.0)
        return np.clip(img + blob, 0, 255).astype(np.uint8)

    tracker = FragmentTracker(TrackerConfig(num_bins=16, search_radius=8))
    state = tracker.init(jnp.asarray(frame(40, 40)), [32, 32, 47, 47])
    for t in range(1, 6):
        state = tracker.step(state, jnp.asarray(frame(40 + 3 * t, 40 + 2 * t)))
    r0, c0 = int(state["bbox"][0]), int(state["bbox"][1])
    assert abs(r0 - (32 + 15)) <= 6          # tracked ~15px down
    assert abs(c0 - (32 + 10)) <= 6          # and ~10px right


def test_public_api_module():
    ih = IntegralHistogram(num_bins=8, method="wf_tis", backend="jnp")
    img = jnp.asarray(np.arange(64 * 64, dtype=np.uint8).reshape(64, 64))
    H = ih(img)
    assert H.shape == (8, 64, 64)
    q = ih.query(H, jnp.array([0, 0, 63, 63]))
    assert float(jnp.sum(q)) == 64 * 64
