"""Training substrate: optimizers, clipping, accumulation, compression,
checkpoint/restart, fault injection, data determinism."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.data import TokenStream, make_stream, video_frames
from repro.train import (
    CheckpointManager, FaultInjector, Watchdog, adafactor, adamw,
    init_state, make_optimizer, make_train_step, run_training,
)
from repro.train import grad as G

RNG = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("make_opt", [adamw, adafactor])
def test_optimizer_minimizes_quadratic(make_opt):
    opt = make_opt(0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0]), "b": jnp.asarray(5.0)}
    state = opt.init(params)
    def loss(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2
    for step in range(200):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params, jnp.asarray(step))
    assert float(loss(params)) < 1e-2


def test_adafactor_state_is_factored():
    opt = adafactor(0.1)
    params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((64,))}
    st = opt.init(params)
    assert st["w"]["vr"].shape == (64,)
    assert st["w"]["vc"].shape == (32,)
    assert st["b"]["v"].shape == (64,)


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, norm = G.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(90 + 160), rel=1e-5)
    _, norm2 = G.clip_by_global_norm(clipped, 1.0)
    assert float(norm2) == pytest.approx(1.0, rel=1e-5)


def test_grad_accumulation_equivalence():
    """sum of microbatch grads == full-batch grads (linear loss in batch)."""
    cfg = smoke_config("qwen2-1.5b")
    from repro.models import api
    params = api.init_params(RNG, cfg)
    batch = {"tokens": jax.random.randint(RNG, (4, 16), 0, cfg.vocab_size),
             "labels": jax.random.randint(RNG, (4, 16), 0, cfg.vocab_size)}
    def loss_fn(p, b):
        return api.loss_fn(p, b, cfg)
    _, _, g1 = G.accumulate_grads(loss_fn, params, batch, 1)
    _, _, g4 = G.accumulate_grads(loss_fn, params, batch, 4)
    diffs = [float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                   b.astype(jnp.float32))))
             for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g4))]
    assert max(diffs) < 5e-3


def test_int8_error_feedback_compression():
    """Quantization error must be carried, not lost: over many steps the
    summed dequantized grads converge to the summed true grads."""
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64,)) * 1e-3)}
    err = G.init_error_buffer(g)
    total_deq = jnp.zeros((64,))
    for _ in range(50):
        deq, err = G.compress_grads(g, err)
        total_deq = total_deq + deq["w"]
    np.testing.assert_allclose(np.asarray(total_deq),
                               np.asarray(g["w"] * 50), rtol=0.02, atol=1e-5)


# ---------------------------------------------------------------------------
# Checkpoint / fault tolerance
# ---------------------------------------------------------------------------
def _tiny_setup():
    cfg = smoke_config("qwen2-1.5b")
    opt = make_optimizer(cfg, peak_lr=1e-3, warmup=2, total_steps=40)
    step = jax.jit(make_train_step(cfg, opt))
    stream = make_stream(cfg, batch=2, seq_len=16)
    def init():
        return init_state(RNG, cfg, opt)
    return cfg, opt, step, stream, init


def test_checkpoint_roundtrip():
    _, _, _, _, init = _tiny_setup()
    state = init()
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(state, 7)
        assert mgr.latest_step() == 7
        restored = mgr.restore()
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keeps_last_k():
    _, _, _, _, init = _tiny_setup()
    state = init()
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep_last=2)
        for s in (1, 2, 3, 4):
            mgr.save(state, s)
        steps = sorted(x for x in os.listdir(d) if x.startswith("step_"))
        assert steps == ["step_00000003", "step_00000004"]


def test_restart_is_bit_exact():
    """Crash at steps 5 and 11 -> restart -> identical params to a clean run."""
    _, _, step, stream, init = _tiny_setup()
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        s_fault, _ = run_training(
            init_state_fn=init, train_step=step, stream=stream,
            ckpt=CheckpointManager(d1), num_steps=15, ckpt_every=5,
            injector=FaultInjector(fail_at_steps=(5, 11)))
        s_clean, _ = run_training(
            init_state_fn=init, train_step=step, stream=stream,
            ckpt=CheckpointManager(d2), num_steps=15, ckpt_every=100)
        for a, b in zip(jax.tree.leaves(s_fault["params"]),
                        jax.tree.leaves(s_clean["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_watchdog_flags_stragglers():
    wd = Watchdog(ratio=2.0)
    assert not wd.observe(0.1, 0)
    assert not wd.observe(0.11, 1)
    assert wd.observe(1.0, 2)          # 10x EMA -> straggler
    assert wd.slow_steps == 1


# ---------------------------------------------------------------------------
# Data determinism (the seekable-stream contract)
# ---------------------------------------------------------------------------
def test_token_stream_seekable_and_deterministic():
    s1 = TokenStream(1000, 4, 32, seed=3)
    s2 = TokenStream(1000, 4, 32, seed=3)
    b_a = s1.batch_at(17)
    b_b = s2.batch_at(17)
    np.testing.assert_array_equal(np.asarray(b_a["tokens"]),
                                  np.asarray(b_b["tokens"]))
    assert not np.array_equal(np.asarray(s1.batch_at(18)["tokens"]),
                              np.asarray(b_a["tokens"]))
    # labels are next-token shifted
    full = TokenStream(1000, 4, 32, seed=3).batch_at(17)
    np.testing.assert_array_equal(np.asarray(full["tokens"][:, 1:]),
                                  np.asarray(full["labels"][:, :-1]))


def test_video_frames_deterministic():
    f1 = video_frames(32, 48, 3, seed=5)
    f2 = video_frames(32, 48, 3, seed=5)
    np.testing.assert_array_equal(f1, f2)
    assert f1.shape == (3, 32, 48) and f1.dtype == np.uint8


def test_multimodal_stream_shapes():
    cfg = smoke_config("llava-next-mistral-7b")
    s = make_stream(cfg, batch=2, seq_len=32)
    b = s.batch_at(0)
    assert b["prefix_embeds"].shape == (2, cfg.num_prefix_embeds, cfg.d_model)
    assert b["tokens"].shape == (2, 32 - cfg.num_prefix_embeds)
