"""Multi-device correctness, run in subprocesses with 8 forced host devices
(the main pytest process must keep seeing 1 device).

Covers: bin/spatial-sharded integral histograms vs the oracle, expert-
parallel MoE vs single-device math, compressed all-reduce accuracy, and a
sharded train step vs the unsharded one."""

import os
import subprocess
import sys
import textwrap

_ENV = dict(os.environ,
            XLA_FLAGS="--xla_force_host_platform_device_count=8",
            PYTHONPATH="src")


def _run(body: str):
    code = textwrap.dedent(body)
    proc = subprocess.run([sys.executable, "-c", code], env=_ENV,
                          capture_output=True, text=True, cwd=os.getcwd(),
                          timeout=420)
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nERR:\n{proc.stderr}"
    return proc.stdout


def test_distributed_integral_histograms():
    out = _run("""
        import warnings; warnings.filterwarnings("ignore")
        import numpy as np, jax, jax.numpy as jnp
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        from repro.core.distributed import bin_sharded_ih, spatial_sharded_ih
        from repro.kernels.ref import integral_histogram_ref
        img = jnp.asarray(np.random.default_rng(1).integers(
            0, 256, (64, 128), dtype=np.uint8))
        ref = integral_histogram_ref(img, 16)
        assert np.allclose(bin_sharded_ih(img, 16, mesh), ref)
        assert np.allclose(
            spatial_sharded_ih(img, 16, mesh, scan_impl="allgather"), ref)
        assert np.allclose(
            spatial_sharded_ih(img, 16, mesh, scan_impl="ppermute"), ref)
        assert np.allclose(
            spatial_sharded_ih(img, 16, mesh, bin_axis="model"), ref)

        # batched analytics over the sharded H: (n, h, w) frame stacks and
        # rank-polymorphic distributed_region_query
        from repro.core.distributed import distributed_region_query
        from repro.core.region_query import region_histogram
        imgs = jnp.asarray(np.random.default_rng(2).integers(
            0, 256, (2, 64, 128), dtype=np.uint8))
        refs = jnp.stack([integral_histogram_ref(im, 16) for im in imgs])
        Hs = bin_sharded_ih(imgs, 16, mesh)
        assert Hs.shape == (2, 16, 64, 128)
        assert np.allclose(Hs, refs)
        rects = jnp.array([[0, 0, 63, 127], [3, 4, 30, 40]])
        got = distributed_region_query(Hs, rects, mesh)
        assert got.shape == (2, 2, 16)
        assert np.allclose(got, region_histogram(refs, rects))
        # unbatched query unchanged
        got1 = distributed_region_query(Hs[0], rects, mesh)
        assert np.allclose(got1, region_histogram(refs[0], rects))

        # band streaming composed with both sharding schemes: the band
        # carry rides on top of the intra-band device carries, bit-exact.
        # (Bands are assembled host-side: each band.H stays sharded.)
        from repro.core.distributed import iter_banded_sharded_ih
        got_bin = np.concatenate(
            [np.asarray(b.H) for b in iter_banded_sharded_ih(
                img, 16, mesh, sharding="bin", band_h=24)], axis=-2)
        assert np.array_equal(got_bin, np.asarray(ref))
        got_sp = np.concatenate(
            [np.asarray(b.H) for b in iter_banded_sharded_ih(
                img, 16, mesh, sharding="spatial", band_h=24)], axis=-2)
        assert np.array_equal(got_sp, np.asarray(ref))
        stack_bands = iter_banded_sharded_ih(imgs, 16, mesh, sharding="bin",
                                             memory_budget_bytes=2 * 16 * 16
                                             * 128 * 4 * 2)
        got_stack = np.concatenate(
            [np.asarray(b.H) for b in stack_bands], axis=-2)
        assert np.array_equal(got_stack, np.asarray(refs))
        print("dist-IH OK")
    """)
    assert "dist-IH OK" in out


def test_engine_sharded_parity_and_host_assembly():
    """The plan/execute engine on an 8-device mesh: bin- and
    spatial-sharded plans are bit-exact vs the monolithic oracle, and the
    banded+row-sharded band assembly goes through host-side np — NEVER
    ``jnp.concatenate`` over row-sharded bands, which silently
    mis-assembles on jax 0.4.37 (CHANGES.md, PR 3).

    The primary guard against a regression to device-side assembly is
    static now: the ``sharded-concat`` lint rule (repro.analysis) flags
    any ``jnp.concatenate``/``jnp.stack`` over band/shard operands in
    the core assembly paths, on every jax version, without running a
    mesh (tests/test_analysis.py pins the rule itself).  This test keeps
    the runtime parity story: sharded plans match the oracle and
    ``rows()`` hands back host arrays by construction."""
    out = _run("""
        import warnings; warnings.filterwarnings("ignore")
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.engine import HistogramEngine, RegionQuery, \\
            SlidingWindowQuery
        from repro.core.hsource import BandedH
        from repro.kernels.ops import integral_histogram

        img = np.random.default_rng(5).integers(
            0, 256, (64, 128), dtype=np.uint8)
        ref = np.asarray(integral_histogram(jnp.asarray(img), 16,
                                            backend="jnp"))
        rects = np.array([[0, 0, 63, 127], [3, 4, 30, 40]])
        from repro.core.region_query import (region_histogram,
                                             sliding_window_histograms)
        want_r = np.asarray(region_histogram(jnp.asarray(ref), rects))
        want_w = np.asarray(sliding_window_histograms(
            jnp.asarray(ref), (16, 24), 8))

        # bin-sharded plan (2x4 mesh, bins divide the model axis)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        eng = HistogramEngine(16, backend="jnp", mesh=mesh)
        out = eng.run(img, [RegionQuery(rects),
                            SlidingWindowQuery((16, 24), 8)])
        assert out.plan.representation == "sharded"
        assert out.plan.sharding == "bin"
        assert np.array_equal(np.asarray(out.results[0]), want_r)
        assert np.array_equal(np.asarray(out.results[1]), want_w)

        # spatial (row-strip) plan, forced explicitly
        eng_sp = HistogramEngine(16, backend="jnp", mesh=mesh,
                                 sharding="spatial")
        out_sp = eng_sp.run(img, [RegionQuery(rects)])
        assert out_sp.plan.sharding == "spatial"
        assert np.array_equal(np.asarray(out_sp.results[0]), want_r)
        assert np.array_equal(np.asarray(out_sp.source.dense()), ref)

        # banded + row-sharded: bands stream through the mesh, assembly
        # and corner-row slabs are host-side (the guard is live here)
        budget = 4 * 16 * 128 * 16                # 16-row bands
        eng_b = HistogramEngine(16, backend="jnp", mesh=mesh,
                                sharding="spatial",
                                memory_budget_bytes=budget)
        out_b = eng_b.run(img, [RegionQuery(rects),
                                SlidingWindowQuery((16, 24), 8)])
        assert out_b.plan.band_plan is not None
        assert out_b.plan.band_plan.num_bands >= 4
        assert isinstance(out_b.source, BandedH)
        assert np.array_equal(np.asarray(out_b.results[0]), want_r)
        assert np.array_equal(np.asarray(out_b.results[1]), want_w)
        rows = out_b.source.rows(np.array([0, 15, 16, 63]))
        assert type(rows) is np.ndarray          # host-side by construction
        assert np.array_equal(rows, ref[:, [0, 15, 16, 63], :])
        # banded + bin-sharded through the same engine path
        eng_bb = HistogramEngine(16, backend="jnp", mesh=mesh,
                                 memory_budget_bytes=budget)
        out_bb = eng_bb.run(img, [RegionQuery(rects)])
        assert out_bb.plan.sharding == "bin"
        assert out_bb.plan.band_plan is not None
        assert np.array_equal(np.asarray(out_bb.results[0]), want_r)
        print("engine-sharded OK")
    """)
    assert "engine-sharded OK" in out


def test_expert_parallel_moe_matches_local():
    out = _run("""
        import warnings; warnings.filterwarnings("ignore")
        import numpy as np, jax, jax.numpy as jnp, dataclasses
        from repro.configs import smoke_config
        from repro.models.moe import moe_block, moe_params
        from repro.sharding.rules import sharding_context
        cfg = smoke_config("kimi-k2-1t-a32b")
        cfg = dataclasses.replace(cfg, dtype="float32",
                                  capacity_factor=8.0, d_model=64)
        p = moe_params(jax.random.PRNGKey(1), cfg)
        x = jax.random.normal(jax.random.PRNGKey(2), (4, 8, 64)) * 0.1
        local, aux_l = moe_block(x, p, cfg)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with mesh, sharding_context(mesh):
            shard, aux_s = jax.jit(lambda x, p: moe_block(x, p, cfg))(x, p)
        err = float(jnp.max(jnp.abs(local - shard)))
        assert err < 1e-4, err
        # aux under DP is the mean of per-shard load-balance estimates
        # (nonlinear in token partition) — close but not bit-equal.
        assert abs(float(aux_l) - float(aux_s)) < 0.05
        print("EP-MoE OK", err)
    """)
    assert "EP-MoE OK" in out


def test_compressed_psum_accuracy():
    out = _run("""
        import warnings; warnings.filterwarnings("ignore")
        import numpy as np, jax, jax.numpy as jnp
        from repro.train.grad import compressed_psum
        mesh = jax.make_mesh((8,), ("pod",))
        parts = jnp.asarray(np.random.default_rng(0).normal(
            size=(8, 128)) * 1e-3)
        exact = jnp.sum(parts, 0)
        approx = compressed_psum(parts, mesh, "pod")
        rel = float(jnp.linalg.norm(approx - exact) / jnp.linalg.norm(exact))
        assert rel < 0.02, rel
        print("compressed psum OK", rel)
    """)
    assert "compressed psum OK" in out


def test_sharded_train_step_matches_unsharded():
    out = _run("""
        import warnings; warnings.filterwarnings("ignore")
        import numpy as np, jax, jax.numpy as jnp, dataclasses
        from repro.configs import smoke_config
        from repro.models import api
        from repro.sharding.rules import ShardingRules, sharding_context
        from repro.train import (init_state, make_optimizer, make_train_step,
                                 state_shardings, batch_shardings)
        cfg = smoke_config("qwen3-4b")
        cfg = dataclasses.replace(cfg, dtype="float32")
        opt = make_optimizer(cfg, peak_lr=1e-3, warmup=2, total_steps=10)
        step = make_train_step(cfg, opt)
        state = init_state(jax.random.PRNGKey(0), cfg, opt)
        batch = {"tokens": jax.random.randint(
                     jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab_size),
                 "labels": jax.random.randint(
                     jax.random.PRNGKey(2), (4, 16), 0, cfg.vocab_size)}
        _, m1 = jax.jit(step)(state, batch)

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = ShardingRules()
        with mesh, sharding_context(mesh, rules):
            st_shape = jax.eval_shape(lambda: init_state(
                jax.random.PRNGKey(0), cfg, opt))
            st_sh = state_shardings(st_shape, mesh, rules)
            b_sh = batch_shardings(jax.eval_shape(lambda: batch), mesh, rules)
            jitted = jax.jit(step, in_shardings=(st_sh, b_sh),
                             out_shardings=(st_sh, None))
            state_s = jax.device_put(state, st_sh)
            batch_s = jax.device_put(batch, b_sh)
            _, m2 = jitted(state_s, batch_s)
        d = abs(float(m1["loss"]) - float(m2["loss"]))
        assert d < 1e-4, d
        print("sharded train OK", d)
    """)
    assert "sharded train OK" in out


def test_production_mesh_shapes():
    out = _run("""
        import jax
        from repro.launch.mesh import make_host_mesh
        m = make_host_mesh((2, 4))
        assert m.shape == {"data": 2, "model": 4}
        print("mesh OK")
    """)
    assert "mesh OK" in out


def test_distributed_service_parity_8dev():
    """ISSUE 10 satellite: end-to-end mesh-serving parity on 8 fake
    devices.  A DistributedAnalyticsService answers a mixed trace —
    including a PR 9 low-motion video chain — bit-exact vs a
    single-device AnalyticsService fed the same trace, in BOTH layouts:
    8 replica groups x 1 device (chain pinned to one replica, updates
    local) and 2 replica groups x 4-way bin sharding.  Also pins the
    mesh-native plumbing underneath: explain() renders the replica x
    shard layout, sharded band slices stage with a NamedSharding and the
    between-band carry stays a device array, and ShardedH gathers corner
    rows device-side."""
    out = _run("""
        import warnings; warnings.filterwarnings("ignore")
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding
        from repro.core.engine import (HistogramEngine, RegionQuery,
                                       SlidingWindowQuery)
        from repro.serve import (AnalyticsService,
                                 DistributedAnalyticsService,
                                 sharded_engine_factory)

        rng = np.random.default_rng(11)
        h, w, bins = 64, 96, 16
        frames = [rng.integers(0, 256, (h, w), dtype=np.uint8)]
        for _ in range(4):                      # low-motion chain 0..4
            nxt = frames[-1].copy()
            r = int(rng.integers(0, h - 3))
            nxt[r:r + 3] = rng.integers(0, 256, (3, w), dtype=np.uint8)
            frames.append(nxt)
        for _ in range(3):                      # independent frames 5..7
            frames.append(rng.integers(0, 256, (h, w), dtype=np.uint8))
        store = {i: f for i, f in enumerate(frames)}
        # 20 corner rows > h/4: keeps plans dense (H stored) so the
        # video chain can actually update
        rects = np.array([[3 * i, 2, 3 * i + 1, 10] for i in range(10)])
        trace = [(i, RegionQuery(rects)) for i in range(5)]
        trace += [(i, RegionQuery(rects)) for i in (5, 6, 7, 2, 5)]
        trace.append((3, SlidingWindowQuery((16, 24), 8)))

        single = AnalyticsService(HistogramEngine(bins, backend="jnp"),
                                  store)
        want = single.process(list(trace))

        # layout 1: 8 replica groups x 1 device — chain-pinned updates
        mesh_r = jax.make_mesh((8,), ("data",))
        dist_r = DistributedAnalyticsService(
            sharded_engine_factory(bins, backend="jnp"), store,
            mesh=mesh_r, replica_axis="data")
        got_r = dist_r.process(list(trace))
        for g, wv in zip(got_r, want):
            assert np.array_equal(np.asarray(g), np.asarray(wv))
        assert len({dist_r.replica_for(i) for i in range(5)}) == 1
        snap = dist_r.snapshot()
        assert snap["num_replicas"] == 8
        per_updated = [p["updated"] for p in snap["replicas"]]
        assert sum(per_updated) == 4            # frames 1..4 updated...
        assert sum(1 for u in per_updated if u) == 1   # ...on ONE replica
        print("replica-parallel parity OK", per_updated)

        # layout 2: 2 replica groups x 4-way bin shard
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        dist_s = DistributedAnalyticsService(
            sharded_engine_factory(bins, backend="jnp"), store,
            mesh=mesh, replica_axis="data")
        got_s = dist_s.process(list(trace))
        for g, wv in zip(got_s, want):
            assert np.array_equal(np.asarray(g), np.asarray(wv))
        sub = dist_s.replicas[0]._engine.mesh
        assert dict(sub.shape) == {"model": 4}
        print("sharded-replica parity OK")

        # the planner's layout, rendered at mesh scale
        eng = HistogramEngine(bins, backend="jnp", mesh=mesh)
        out = eng.run(frames[5], [RegionQuery(rects)])
        text = eng.last_plan.explain()
        assert ("mesh layout     : 2 replica group(s) over 'data' x bin "
                "sharding over 'model' (4 device(s)/group)") in text

        # sharded carry rides the shard layout: band slices stage with a
        # NamedSharding and the between-band carry is a committed device
        # array, never a host round-trip
        from repro.core.distributed import iter_banded_sharded_ih
        from repro.kernels.ops import integral_histogram
        img = frames[5]
        ref = np.asarray(integral_histogram(jnp.asarray(img), bins,
                                            backend="jnp"))
        bands = list(iter_banded_sharded_ih(img, bins, mesh,
                                            sharding="spatial", band_h=16,
                                            prefetch=1))
        for b in bands:
            assert isinstance(b.carry.sharding, NamedSharding)
            assert isinstance(b.H.sharding, NamedSharding)
        got_b = np.concatenate([np.asarray(b.H) for b in bands], axis=-2)
        assert np.array_equal(got_b, ref)

        # ShardedH device-side corner-row gather, both kinds
        from repro.core.distributed import (bin_sharded_ih,
                                            spatial_sharded_ih)
        from repro.core.hsource import ShardedH
        rid = np.array([0, 15, 16, 63])
        for kind, H in (("bin", bin_sharded_ih(jnp.asarray(img), bins,
                                               mesh)),
                        ("spatial", spatial_sharded_ih(jnp.asarray(img),
                                                       bins, mesh))):
            src = ShardedH(H, mesh, kind=kind)
            rows = src.rows(rid)
            assert type(rows) is np.ndarray
            assert np.array_equal(rows, ref[:, rid, :]), kind
        print("mesh-serving OK")
    """)
    assert "replica-parallel parity OK" in out
    assert "sharded-replica parity OK" in out
    assert "mesh-serving OK" in out
