"""Optional-hypothesis shim for the property-based tests.

``hypothesis`` is a dev-only dependency (requirements-dev.txt).  When it
is absent the property tests must *skip*, not kill collection of the
whole module — tier-1 runs in containers without dev extras.

Import the decorators from here instead of from hypothesis directly:

    from _hypothesis_compat import given, settings, st

With hypothesis installed these are the real objects; without it they are
stand-ins whose wrapped test calls ``pytest.importorskip("hypothesis")``
at run time, producing a clean per-test skip.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without dev extras
    import pytest

    HAVE_HYPOTHESIS = False

    def _skipping_decorator(*_args, **_kwargs):
        def wrap(fn):
            # Zero-arg stub: hypothesis would inject the arguments, and
            # pytest must not mistake them for fixtures.  No __wrapped__,
            # or inspect.signature would surface the original params.
            def skipped():
                pytest.importorskip("hypothesis")

            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped

        return wrap

    given = settings = _skipping_decorator

    class _AnyStrategy:
        """st.integers(...) etc. — placeholders, never executed."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()
