"""Frame-batch axis correctness: (n, h, w) stacks must equal a Python loop
of single-frame calls — bit-exactly (all arithmetic is integer-valued
fp32) — for every method on both backends, including non-tile-multiple
shapes and bin counts that don't divide the kernel bin block.  Also covers
the microbatched pipeline and the `map_frames` streaming API."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import scans
from repro.core.integral_histogram import IntegralHistogram
from repro.core.pipeline import DoubleBufferedExecutor
from repro.kernels.ops import integral_histogram
from repro.kernels.ref import integral_histogram_ref


def _stack(rng, n, h, w):
    return rng.integers(0, 256, (n, h, w), dtype=np.uint8)


# ---------------------------------------------------------------------------
# jnp backend: all four methods
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("method", sorted(scans.METHODS))
@pytest.mark.parametrize("nhw,bins", [
    ((3, 45, 37), 12),      # non-tile-multiple spatial dims, odd bins
    ((2, 64, 64), 8),       # tile-friendly
])
def test_jnp_batched_equals_single_loop(rng, method, nhw, bins):
    imgs = _stack(rng, *nhw)
    batched = integral_histogram(
        jnp.asarray(imgs), bins, method=method, backend="jnp")
    singles = [
        integral_histogram(jnp.asarray(im), bins, method=method, backend="jnp")
        for im in imgs
    ]
    assert batched.shape == (nhw[0], bins, nhw[1], nhw[2])
    for i, s in enumerate(singles):
        np.testing.assert_array_equal(np.asarray(batched[i]), np.asarray(s))


@pytest.mark.parametrize("method", sorted(scans.METHODS))
def test_acceptance_8x240x320(rng, method):
    """The ISSUE's acceptance shape: (8, 240, 320) bit-exact vs 8 calls."""
    imgs = _stack(rng, 8, 240, 320)
    batched = integral_histogram(
        jnp.asarray(imgs), 16, method=method, backend="jnp")
    for i in range(8):
        single = integral_histogram(
            jnp.asarray(imgs[i]), 16, method=method, backend="jnp")
        np.testing.assert_array_equal(np.asarray(batched[i]), np.asarray(single))


# ---------------------------------------------------------------------------
# pallas backend (interpret mode): frame axis in the kernel grid
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("method", ["cw_tis", "wf_tis"])
@pytest.mark.parametrize("nhw,bins,bin_block", [
    ((3, 40, 56), 6, 4),    # padding path + num_bins % bin_block != 0
    ((2, 32, 32), 8, 8),    # exact tiling
])
def test_pallas_batched_equals_single_loop(rng, method, nhw, bins, bin_block):
    imgs = _stack(rng, *nhw)
    kw = dict(method=method, backend="pallas", tile=16,
              bin_block=bin_block, interpret=True)
    batched = integral_histogram(jnp.asarray(imgs), bins, **kw)
    assert batched.shape == (nhw[0], bins, nhw[1], nhw[2])
    for i in range(nhw[0]):
        single = integral_histogram(jnp.asarray(imgs[i]), bins, **kw)
        np.testing.assert_array_equal(np.asarray(batched[i]), np.asarray(single))
        ref = integral_histogram_ref(jnp.asarray(imgs[i]), bins)
        np.testing.assert_allclose(
            np.asarray(batched[i]), np.asarray(ref), atol=1e-3)


def test_pallas_batched_carry_reset(rng):
    """Frames must not leak carries into each other: a stack whose second
    frame is all-zero must produce a zero-bin-independent H for frame 2."""
    imgs = np.zeros((2, 32, 32), np.uint8)
    imgs[0] = 255  # frame 0 fills the last bin with h*w counts
    out = integral_histogram(jnp.asarray(imgs), 4, method="wf_tis",
                             backend="pallas", tile=16, interpret=True)
    # frame 1 is all zeros -> every pixel in bin 0; bins 1..3 empty
    assert float(out[1, 0, -1, -1]) == 32 * 32
    assert float(jnp.sum(out[1, 1:])) == 0.0
    # frame 0 unpolluted: all mass in the last bin
    assert float(out[0, 3, -1, -1]) == 32 * 32


# ---------------------------------------------------------------------------
# pipeline microbatching + public streaming API
# ---------------------------------------------------------------------------
def test_executor_microbatch_matches_per_frame(rng):
    frames = list(_stack(rng, 7, 48, 64))
    ih = IntegralHistogram(num_bins=8, backend="jnp")
    per_frame = [np.asarray(ih(jnp.asarray(f))) for f in frames]
    for batch_size in (1, 3, 16):  # 3 leaves a ragged tail; 16 > stream len
        ex = DoubleBufferedExecutor(ih, depth=2, batch_size=batch_size)
        outs = [np.asarray(o) for o in ex.map(frames)]
        assert len(outs) == len(frames)
        for got, want in zip(outs, per_frame):
            np.testing.assert_array_equal(got, want)


def test_map_frames_streaming(rng):
    frames = list(_stack(rng, 5, 40, 40))
    ih = IntegralHistogram(num_bins=8, backend="jnp")
    outs = list(ih.map_frames(frames, batch_size=2))
    assert len(outs) == 5
    for f, H in zip(frames, outs):
        assert H.shape == (8, 40, 40)
        # total count corner == number of pixels
        assert float(jnp.sum(H[:, -1, -1])) == 40 * 40
        np.testing.assert_array_equal(
            np.asarray(H), np.asarray(ih(jnp.asarray(f))))


def test_map_frames_auto_batch(rng):
    """batch_size="auto" batches deep on ROI-scale frames, shallow on big
    ones, and stays correct either way."""
    ih = IntegralHistogram(num_bins=16, backend="jnp")
    small = list(_stack(rng, 6, 64, 64))       # dispatch-bound: deep batch
    outs = list(ih.map_frames(small))          # default batch_size="auto"
    assert len(outs) == 6
    np.testing.assert_array_equal(
        np.asarray(outs[3]), np.asarray(ih(jnp.asarray(small[3]))))

    big = list(_stack(rng, 2, 256, 320))       # cache-bound: batch ~ 1
    outs = list(ih.map_frames(big))
    assert len(outs) == 2
    np.testing.assert_array_equal(
        np.asarray(outs[1]), np.asarray(ih(jnp.asarray(big[1]))))

    assert list(IntegralHistogram(num_bins=4).map_frames([])) == []


def test_executor_rejects_bad_config():
    ih = IntegralHistogram(num_bins=4, backend="jnp")
    with pytest.raises(ValueError):
        DoubleBufferedExecutor(ih, depth=0)
    with pytest.raises(ValueError):
        DoubleBufferedExecutor(ih, batch_size=0)
