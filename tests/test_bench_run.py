"""The benchmark driver's CLI contract: `--only` with an unknown name must
fail loudly (it used to select nothing and exit 0 — "all benches
complete"), and `--json` must serialize every bench's time_fn records
keyed by bench name."""

import json
import sys
import types

import pytest

from benchmarks import common
from benchmarks import run as bench_run


@pytest.fixture
def fake_bench(monkeypatch):
    """Swap BENCHES for a single stub module so main() runs in ~ms."""
    mod = types.ModuleType("_fake_bench")

    def run(quick=False):
        common.time_fn(lambda: 1, warmup=0, iters=1, label="stub")
        return "stub ok"

    mod.run = run
    monkeypatch.setitem(sys.modules, "_fake_bench", mod)
    monkeypatch.setattr(
        bench_run, "BENCHES", [("fake", "_fake_bench", "stub bench")])
    return mod


def test_list_prints_names_and_exits_zero(capsys):
    """--list prints every registered bench with its description and
    returns normally (exit 0) without importing or running any bench."""
    bench_run.main(["--list"])                # no SystemExit: exit code 0
    out = capsys.readouterr().out
    for name, _, desc in bench_run.BENCHES:
        assert name in out and desc in out
    assert "engine" in out                    # the plan/execute bench rides
    assert "all benches complete" not in out  # nothing actually ran


def test_only_unknown_name_fails(capsys):
    with pytest.raises(SystemExit) as e:
        bench_run.main(["--only", "nope"])
    assert e.value.code == 2
    err = capsys.readouterr().err
    assert "unknown bench name(s)" in err
    # the valid list is printed so the typo is one glance from fixed
    for name, _, _ in bench_run.BENCHES:
        assert name in err


def test_only_mixed_known_unknown_fails(fake_bench):
    with pytest.raises(SystemExit) as e:
        bench_run.main(["--only", "fake,typo"])
    assert e.value.code == 2


def test_only_known_name_runs(fake_bench, capsys):
    bench_run.main(["--only", "fake"])
    out = capsys.readouterr().out
    assert "stub ok" in out and "all benches complete" in out


def test_json_records_keyed_by_bench(fake_bench, tmp_path):
    path = tmp_path / "bench.json"
    bench_run.main(["--only", "fake", "--json", str(path)])
    payload = json.loads(path.read_text())
    assert payload["failures"] == []
    records = payload["benches"]["fake"]
    assert len(records) == 1
    assert records[0]["label"] == "stub"
    assert {"median_s", "min_s", "iters"} <= set(records[0])


def test_serve_bench_is_registered():
    """ISSUE 5: the serving bench rides the registry (and --list)."""
    names = [name for name, _, _ in bench_run.BENCHES]
    assert "serve" in names


def test_json_written_even_on_failure(monkeypatch, tmp_path):
    mod = types.ModuleType("_broken_bench")

    def run(quick=False):
        raise RuntimeError("boom")

    mod.run = run
    monkeypatch.setitem(sys.modules, "_broken_bench", mod)
    monkeypatch.setattr(
        bench_run, "BENCHES", [("broken", "_broken_bench", "boom")])
    path = tmp_path / "bench.json"
    with pytest.raises(SystemExit) as e:
        bench_run.main(["--json", str(path)])
    assert e.value.code == 1
    payload = json.loads(path.read_text())
    assert payload["failures"] == ["broken"]
    assert payload["benches"]["broken"] == []


# ---------------------------------------------------------------------------
# scripts/bench_compare.py — the CI perf-trajectory diff
# ---------------------------------------------------------------------------
def _payload(**benches):
    return {
        "smoke": True, "quick": True, "failures": [],
        "benches": {
            name: [{"median_s": t, "min_s": t, "iters": 1, "label": lbl}
                   for lbl, t in recs]
            for name, recs in benches.items()
        },
    }


def _bench_compare():
    import os

    scripts = os.path.join(os.path.dirname(__file__), "..", "scripts")
    sys.path.insert(0, scripts)
    try:
        import bench_compare
    finally:
        sys.path.pop(0)
    return bench_compare


def test_bench_compare_flags_regressions_only_past_threshold():
    bench_compare = _bench_compare()
    old = _payload(methods=[("a", 1.0), ("b", 2.0)], gone=[("x", 1.0)])
    new = _payload(methods=[("a", 1.9), ("b", 2.1)],
                   fresh=[("y", 0.5)])
    table, regressions = bench_compare.compare(old, new, threshold=1.5)
    assert regressions == 1                     # only a: 1.9x >= 1.5x
    assert "1.90x" in table and "slower" in table
    assert "1.05x" in table                     # b within threshold
    assert "(removed)" in table and "new" in table


def test_bench_compare_cli_roundtrip(tmp_path, capsys):
    bench_compare = _bench_compare()
    old_p, new_p = tmp_path / "old.json", tmp_path / "new.json"
    old_p.write_text(json.dumps(_payload(serve=[("d1", 1.0)])))
    new_p.write_text(json.dumps(_payload(serve=[("d1", 1.0)])))
    out_p = tmp_path / "summary.md"
    # regressions never fail the CLI (smoke noise must not gate merges)
    assert bench_compare.main(
        [str(old_p), str(new_p), "--output", str(out_p)]) == 0
    assert "Bench trajectory" in out_p.read_text()
    # unreadable NEW record exits 2
    assert bench_compare.main([str(old_p),
                               str(tmp_path / "nope.json")]) == 2


def test_bench_compare_missing_prior_seeds_trajectory(tmp_path, capsys):
    """First run of a fresh cache: no/empty/garbage OLD must not fail CI —
    the new record seeds the curve and every row reads 'new'."""
    bench_compare = _bench_compare()
    new_p = tmp_path / "new.json"
    new_p.write_text(json.dumps(_payload(serve=[("d1", 1.0)])))
    empty_p = tmp_path / "empty.json"
    empty_p.write_text("")
    garbage_p = tmp_path / "garbage.json"
    garbage_p.write_text("[1, 2]")
    for old in (tmp_path / "nope.json", empty_p, garbage_p):
        assert bench_compare.main([str(old), str(new_p)]) == 0
        out = capsys.readouterr().out
        assert "seeds the trajectory" in out
        assert "| serve | d1 | — | 1.0000 | new | |" in out
