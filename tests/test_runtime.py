"""The async frame runtime (core/runtime.py) and its five adapters.

Tentpole of ISSUE 5: every streaming loop in the repo —
DoubleBufferedExecutor, IntegralHistogram.map_frames / map_bands,
HistogramEngine.map_frames, bands.iter_banded_ih, FragmentTracker.track
— is a thin adapter over ONE scheduler.  These tests pin:

  * frame-for-frame parity of every adapter with the direct per-item
    computation (dense, banded, tracker workloads);
  * carry threading (band bottom-row carry, tracker state) through the
    in-flight window;
  * the adaptive microbatch controller (scripted latencies -> sizing
    decisions, and output parity no matter what sizes it picks);
  * the supported paths emit NO DeprecationWarning (the ``banded_*``
    shims do, with a removal version).
"""

import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bands import banded_integral_histogram, iter_banded_ih
from repro.core.engine import HistogramEngine, auto_batch_size
from repro.core.integral_histogram import IntegralHistogram
from repro.core.pipeline import DoubleBufferedExecutor, prefetch_to_device
from repro.core.runtime import (
    AdaptiveMicrobatch,
    FrameRuntime,
    iter_chunks,
    stack_chunks,
)
from repro.core.tracking import FragmentTracker, TrackerConfig
from repro.kernels.ops import integral_histogram


def _frames(rng, n=7, h=24, w=20):
    return [rng.integers(0, 256, (h, w), dtype=np.uint8) for _ in range(n)]


# ---------------------------------------------------------------------------
# the scheduler core
# ---------------------------------------------------------------------------
def test_runtime_order_and_stats(rng):
    log = []

    def step(chunk, carry):
        log.append(np.shape(chunk))
        return jnp.asarray(chunk) * 2, carry

    rt = FrameRuntime(step, depth=3, microbatch=3)
    items = [np.full((2,), i, np.float32) for i in range(8)]
    outs = list(rt.map_frames(items))
    assert len(outs) == 8                      # one result per item
    for i, o in enumerate(outs):
        np.testing.assert_array_equal(np.asarray(o), [2 * i, 2 * i])
    assert log == [(3, 2), (3, 2), (2, 2)]     # ragged tail
    assert rt.last_stats.items == 8
    assert rt.last_stats.dispatches == 3
    assert rt.last_stats.batch_sizes == [3, 3, 2]
    assert len(rt.last_stats.latencies_s) == 3
    assert rt.last_stats.items_per_s > 0


def test_runtime_carry_threading():
    """carry rides between dispatches: running sum across chunks."""
    def step(chunk, carry):
        s = carry + jnp.sum(jnp.asarray(chunk))
        return s, s

    rt = FrameRuntime(step, depth=2, microbatch=2,
                      carry_in=jnp.asarray(0.0))
    outs, last = rt.fold(
        [np.asarray(float(i)) for i in [1, 2, 3, 4, 5]], batched=True)
    np.testing.assert_allclose([float(o) for o in outs], [3.0, 10.0, 15.0])
    assert float(last) == 15.0
    assert float(rt.last_carry) == 15.0


def test_runtime_depth_one_is_synchronous_and_valid():
    rt = FrameRuntime(FrameRuntime.stateless(lambda x: x), depth=1)
    outs = list(rt.map_frames([np.zeros(3), np.ones(3)]))
    assert len(outs) == 2
    with pytest.raises(ValueError):
        FrameRuntime(lambda c, s: (c, s), depth=0)
    with pytest.raises(ValueError):
        FrameRuntime(lambda c, s: (c, s), microbatch=0)
    with pytest.raises(ValueError):
        FrameRuntime(lambda c, s: (c, s), adaptive=True, block=False)


def test_iter_chunks_array_vs_iterable(rng):
    clip = rng.integers(0, 9, (7, 4, 4), dtype=np.uint8)
    a = list(iter_chunks(clip, 3))
    b = list(iter_chunks(iter(list(clip)), 3))
    assert [x.shape for x in a] == [(3, 4, 4), (3, 4, 4), (1, 4, 4)]
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert [c.shape[0] for c in stack_chunks(iter(list(clip)), 4)] == [4, 3]


# ---------------------------------------------------------------------------
# adaptive microbatch controller (scripted latencies)
# ---------------------------------------------------------------------------
def test_adaptive_grows_when_batching_amortizes():
    """Per-dispatch latency ~constant (dispatch-bound): bigger batches
    win, controller climbs to max and locks."""
    c = AdaptiveMicrobatch(initial=1, max_size=8, settle=1)
    seen = []
    for _ in range(12):
        seen.append(c.size)
        c.observe(c.size, 0.010)       # 10 ms no matter the batch
    assert c.locked
    assert c.size == 8
    assert seen[0] == 1 and 8 in seen


def test_adaptive_backs_off_when_batching_hurts():
    """Latency superlinear in batch (cache-bound): stays small."""
    c = AdaptiveMicrobatch(initial=4, max_size=64, settle=1)
    for _ in range(12):
        c.observe(c.size, 0.001 * c.size**2)   # thr ~ 1/size: smaller wins
    assert c.locked
    assert c.size == 1


def test_adaptive_settles_at_interior_optimum():
    """Throughput peaks at 4: the probe ladder finds and locks it."""
    def latency(k):                     # thr(k) maximal at k=4
        return {1: 1.0, 2: 0.45, 4: 0.2, 8: 0.5, 16: 2.0}[k] / 10

    c = AdaptiveMicrobatch(initial=2, max_size=16, settle=1)
    for _ in range(16):
        c.observe(c.size, latency(c.size))
    assert c.locked
    assert c.size == 4


def test_adaptive_stale_samples_do_not_steer():
    """With a depth-k window, dispatches built at an old size retire
    after the controller moved; their samples are recorded under the
    size that BUILT them and never trigger a decision at the new size."""
    c = AdaptiveMicrobatch(initial=1, max_size=8, settle=1)
    c.observe(1, 0.010)                  # size 1 settles -> moves to 2
    assert c.size == 2
    # a lagged size-1 dispatch retires now: terrible throughput, but it
    # must be filed under size 1, not poison size 2's record
    c.observe(1, 10.0, size=1)
    assert not c.locked and c.size == 2  # no decision fired
    c.observe(2, 0.010)                  # genuine size-2 sample: climbs
    assert c.size == 4


def test_adaptive_runtime_output_parity(rng):
    """Whatever sizes the controller picks, results match per-frame."""
    ih = IntegralHistogram(num_bins=8, backend="jnp")
    frames = _frames(rng, n=9)
    want = [np.asarray(ih(jnp.asarray(f))) for f in frames]
    rt = FrameRuntime(FrameRuntime.stateless(ih), depth=2, microbatch=2,
                      adaptive=True, max_microbatch=4)
    got = list(rt.map_frames(frames))
    assert len(got) == 9
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, np.asarray(g))
    assert rt.controller is not None
    assert sum(rt.last_stats.batch_sizes) == 9


# ---------------------------------------------------------------------------
# adapter parity: the five legacy loops over the one runtime
# ---------------------------------------------------------------------------
def test_executor_adapter_parity(rng):
    ih = IntegralHistogram(num_bins=8, backend="jnp")
    frames = _frames(rng)
    want = [np.asarray(ih(jnp.asarray(f))) for f in frames]
    for depth, batch in [(1, 1), (2, 3), (3, 2)]:
        ex = DoubleBufferedExecutor(ih, depth=depth, batch_size=batch)
        got = list(ex.map(frames))
        assert len(got) == len(frames)
        for w, g in zip(want, got):
            np.testing.assert_array_equal(w, np.asarray(g))


def test_map_frames_adapter_parity(rng):
    ih = IntegralHistogram(num_bins=8, backend="jnp")
    frames = _frames(rng)
    want = [np.asarray(ih(jnp.asarray(f))) for f in frames]
    for kw in [dict(batch_size=2), dict(batch_size="auto"),
               dict(batch_size="adaptive")]:
        got = list(ih.map_frames(frames, **kw))
        assert len(got) == len(frames)
        for w, g in zip(want, got):
            np.testing.assert_array_equal(w, np.asarray(g))
    with pytest.raises(ValueError):
        list(ih.map_frames(frames, batch_size="bogus"))


def test_engine_map_frames_adapter_parity(rng):
    eng = HistogramEngine(8, backend="jnp")
    frames = _frames(rng)
    want = [np.asarray(eng.compute_dense(jnp.asarray(f))) for f in frames]
    got = list(eng.map_frames(frames))
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, np.asarray(g))
    assert eng.last_runtime is not None
    assert eng.last_runtime.last_stats.items == len(frames)
    # adaptive engine: same outputs, runtime carries a controller
    eng2 = HistogramEngine(8, backend="jnp", adaptive_microbatch=True)
    got2 = list(eng2.map_frames(frames))
    for w, g in zip(want, got2):
        np.testing.assert_array_equal(w, np.asarray(g))
    assert eng2.last_plan.microbatch_mode == "adaptive"
    assert eng2.last_runtime.controller is not None


def test_banded_adapter_parity_and_carry(rng):
    img = rng.integers(0, 256, (37, 16), dtype=np.uint8)
    full = np.asarray(integral_histogram(img, 8, backend="jnp"))
    for prefetch in (0, 2):
        np.testing.assert_array_equal(
            np.asarray(banded_integral_histogram(
                img, 8, band_h=10, backend="jnp", prefetch=prefetch)),
            full,
        )
    bands = list(iter_banded_ih(img, 8, band_h=10, backend="jnp"))
    assert [(b.r0, b.r1) for b in bands] == [
        (0, 10), (10, 20), (20, 30), (30, 37)]
    assert bands[0].num_bands == 4 and bands[-1].frame_h == 37
    for b in bands:
        np.testing.assert_array_equal(
            np.asarray(b.carry), np.asarray(b.H[..., -1, :]))
        np.testing.assert_array_equal(
            np.asarray(b.H), full[..., b.r0:b.r1, :])


def test_tracker_adapter_parity(rng):
    clip = np.stack(_frames(rng, n=6, h=32, w=32))
    tr = FragmentTracker(TrackerConfig(num_bins=8, search_radius=3))
    st0 = tr.init(jnp.asarray(clip[0]), [4, 4, 15, 15])
    want_state = dict(st0)
    want = []
    for f in clip:
        want_state = tr.step(want_state, jnp.asarray(f))
        want.append(np.asarray(want_state["bbox"]))
    for frames in (clip, iter(list(clip))):      # sliced and stacked paths
        st, boxes = tr.track(dict(st0), frames, batch_size=2)
        np.testing.assert_array_equal(np.asarray(boxes), np.stack(want))
        np.testing.assert_array_equal(
            np.asarray(st["bbox"]), np.asarray(want_state["bbox"]))
    # auto sizing comes from the planner now
    st, boxes = tr.track(dict(st0), clip)
    np.testing.assert_array_equal(np.asarray(boxes), np.stack(want))


def test_tracker_empty_and_bad_batch(rng):
    tr = FragmentTracker(TrackerConfig(num_bins=8))
    frame = rng.integers(0, 256, (16, 16), dtype=np.uint8)
    st = tr.init(jnp.asarray(frame), [2, 2, 9, 9])
    for empty in (np.zeros((0, 16, 16), np.uint8), iter(())):
        st2, boxes = tr.track(dict(st), empty)
        assert boxes.shape == (0, 4)
    with pytest.raises(ValueError):
        tr.track(dict(st), np.zeros((3, 16, 16), np.uint8), batch_size=0)


def test_prefetch_to_device_staging_window(rng):
    """Exactly `size` staged before the first yield (the PR 2 fix)."""
    staged = []

    def gen(n=5):
        for i in range(n):
            staged.append(i)
            yield np.full((2,), i, np.float32)

    it = prefetch_to_device(gen(), size=2)
    first = next(it)
    assert staged == [0, 1]                     # not size + 1
    np.testing.assert_array_equal(np.asarray(first), [0, 0])
    assert len(list(it)) == 4


def test_auto_batch_size_reexport_matches_planner():
    """Satellite: pipeline re-exports the planner's auto_batch_size."""
    from repro.core import pipeline

    assert pipeline.auto_batch_size is auto_batch_size
    assert auto_batch_size(8, 24, 20) == 16
    assert auto_batch_size(128, 2048, 2048) == 1


# ---------------------------------------------------------------------------
# deprecation hygiene
# ---------------------------------------------------------------------------
def test_runtime_adapters_emit_no_deprecation_warnings(rng):
    """The supported streaming paths are warning-free; only the
    ``banded_*`` shims warn (with a removal version)."""
    ih = IntegralHistogram(num_bins=8, backend="jnp")
    img = rng.integers(0, 256, (30, 16), dtype=np.uint8)
    frames = _frames(rng, n=4)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        list(ih.map_frames(frames, batch_size=2))
        list(ih.map_bands(img, band_h=10))
        list(DoubleBufferedExecutor(ih, depth=2).map(frames[:2]))
        list(HistogramEngine(8, backend="jnp").map_frames(frames[:2]))
        tr = FragmentTracker(TrackerConfig(num_bins=8, search_radius=2))
        st = tr.init(jnp.asarray(frames[0]), [2, 2, 9, 9])
        tr.track(st, np.stack(frames))


def test_banded_shims_name_a_removal_version(rng):
    from repro.core.region_query import banded_region_histogram

    img = rng.integers(0, 256, (20, 12), dtype=np.uint8)
    bands = iter_banded_ih(img, 4, band_h=8, backend="jnp")
    with pytest.warns(DeprecationWarning, match=r"removed in 2\.0"):
        banded_region_histogram(bands, np.array([1, 1, 8, 8]))


# ---------------------------------------------------------------------------
# Sharding-aware staging (mesh-scale serving)
# ---------------------------------------------------------------------------
def test_stage_stream_accepts_a_sharding():
    """`device=` takes any jax.device_put placement — a NamedSharding
    commits each staged item to the mesh layout instead of one device
    (what removed the sharded-plan staging carve-out in bands)."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.runtime import stage_stream

    mesh = jax.make_mesh((1,), ("data",))
    ns = NamedSharding(mesh, P())
    items = [np.full((4, 4), i, np.float32) for i in range(3)]
    staged = list(stage_stream(iter(items), size=2, device=ns))
    assert len(staged) == 3
    for i, x in enumerate(staged):
        assert x.sharding == ns
        np.testing.assert_array_equal(np.asarray(x), items[i])


def test_frame_runtime_stages_with_a_sharding(rng):
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = jax.make_mesh((1,), ("data",))
    ns = NamedSharding(mesh, P())
    seen = []

    def step(chunk, carry):
        seen.append(chunk.sharding)
        return chunk * 2, carry

    rt = FrameRuntime(step, depth=1, device=ns, stage_inputs=True)
    items = [np.full((2,), i, np.float32) for i in range(4)]
    outs = [d.out for d in rt.run(items, batched=False)]
    assert all(s == ns for s in seen)
    for i, o in enumerate(outs):
        np.testing.assert_array_equal(np.asarray(o), [2 * i, 2 * i])


def test_iter_banded_ih_stages_when_device_given(rng):
    """An explicit device placement turns staging on even at prefetch=0
    (the old carve-out skipped staging for sharded plans entirely)."""
    import jax

    img = rng.integers(0, 256, (24, 16), dtype=np.uint8)
    dev = jax.devices()[0]
    bands = list(iter_banded_ih(img, 8, band_h=8, backend="jnp", device=dev))
    full = np.concatenate([np.asarray(b.H) for b in bands], axis=-2)
    ref = np.asarray(integral_histogram(jnp.asarray(img), 8, backend="jnp"))
    np.testing.assert_array_equal(full, ref)
