"""Incremental video-delta H (ISSUE 9): dirty-band invalidation.

Acceptance: a delta-updated H is **bit-exact** against a monolithic
recompute — across dense / banded / spilled representations, every
storage policy (fp32 / uint32 / uint16 modular), uneven band plans, and
dirty-first / dirty-last / all-dirty frames.  The fused representation
never stores H, so a fused predecessor falls back to a full recompute.
"""

import dataclasses
import json

import numpy as np
import pytest

from repro.core import autotune
from repro.core import delta as delta_mod
from repro.core.bands import plan_bands
from repro.core.engine import (
    HistogramEngine,
    RegionQuery,
    WorkloadSpec,
    plan,
)
from repro.kernels import ops

H, W, BINS = 32, 24, 8


@pytest.fixture()
def f0(rng):
    return rng.integers(0, 256, (H, W), dtype=np.uint8)


def _mutate(frame, rng, r0, r1):
    """A low-motion successor: rows [r0, r1) rewritten, rest identical."""
    nxt = frame.copy()
    nxt[r0:r1] = rng.integers(0, 256, (r1 - r0, frame.shape[-1]),
                              dtype=np.uint8)
    return nxt


def _full(frame, **kw):
    return np.asarray(ops.integral_histogram(frame, BINS, backend="jnp",
                                             **kw))


# ---------------------------------------------------------------------------
# diff_bands: the detector
# ---------------------------------------------------------------------------
def test_diff_bands_report(rng, f0):
    bp = plan_bands(H, W, BINS, band_h=8)           # 4 bands of 8 rows
    f1 = _mutate(f0, rng, 5, 9)                     # straddles bands 0, 1
    rep = delta_mod.diff_bands(f0, f1, bp)
    assert rep.dirty == (True, True, False, False)
    assert rep.dirty_rows == 16 and rep.dirty_fraction == 0.5
    assert rep.num_dirty == 2 and not rep.all_clean

    clean = delta_mod.diff_bands(f0, f0, bp)
    assert clean.all_clean and clean.dirty_fraction == 0.0

    # bare span sequences work (a SpilledIH hands its own spans)
    rep2 = delta_mod.diff_bands(f0, f1, [(0, 5), (5, 16), (16, H)])
    assert rep2.dirty == (False, True, False)

    with pytest.raises(ValueError, match="shapes differ"):
        delta_mod.diff_bands(f0, f1[:-1], bp)
    with pytest.raises(ValueError, match="do not tile"):
        delta_mod.diff_bands(f0, f1, [(0, 5), (6, H)])     # gap
    with pytest.raises(ValueError, match="do not tile"):
        delta_mod.diff_bands(f0, f1, [(0, H - 1)])         # short


def test_diff_bands_frame_stacks(rng, f0):
    clip0 = np.stack([f0, f0])
    clip1 = clip0.copy()
    clip1[1, 20:22] = 0                             # dirty in ONE frame
    rep = delta_mod.diff_bands(clip0, clip1, plan_bands(H, W, BINS,
                                                        band_h=8))
    assert rep.dirty == (False, False, True, False)


# ---------------------------------------------------------------------------
# update_dense_ih: the direct walk, every dirty position
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("span", [
    (0, 4),          # dirty-first band
    (13, 18),        # dirty middle, straddling uneven bands
    (28, 32),        # dirty-last band
    (0, 32),         # all-dirty (the walk must still be exact)
])
def test_update_dense_ih_parity_uneven_bands(rng, f0, span):
    spans = [(0, 5), (5, 16), (16, 23), (23, H)]    # uneven on purpose
    f1 = _mutate(f0, rng, *span)
    rep = delta_mod.diff_bands(f0, f1, spans)

    def recompute(band_rows, carry):
        return ops.integral_histogram(band_rows, BINS, backend="jnp",
                                      carry_in=carry)

    got = delta_mod.update_dense_ih(_full(f0), f1, rep,
                                    recompute=recompute)
    np.testing.assert_array_equal(np.asarray(got), _full(f1))


def test_update_dense_ih_batched(rng):
    clip0 = rng.integers(0, 256, (2, H, W), dtype=np.uint8)
    clip1 = clip0.copy()
    clip1[:, 9:12] = rng.integers(0, 256, (2, 3, W), dtype=np.uint8)
    rep = delta_mod.diff_bands(clip0, clip1, plan_bands(H, W, BINS,
                                                        band_h=8))

    def recompute(band_rows, carry):
        return ops.integral_histogram(band_rows, BINS, backend="jnp",
                                      carry_in=carry)

    got = delta_mod.update_dense_ih(_full(clip0), clip1, rep,
                                    recompute=recompute)
    np.testing.assert_array_equal(np.asarray(got), _full(clip1))


# ---------------------------------------------------------------------------
# the engine path: plan decision + per-representation parity
# ---------------------------------------------------------------------------
def test_engine_dense_incremental_parity(rng, f0):
    eng = HistogramEngine(BINS, backend="jnp")
    f1 = _mutate(f0, rng, 6, 9)
    out0 = eng.run(f0)
    out1 = eng.run(f1, prev=(f0, out0))
    assert out1.plan.incremental
    assert "incremental" in out1.plan.explain()
    full = eng.run(f1)
    assert not full.plan.incremental
    a = np.asarray(out1.source.dense())
    b = np.asarray(full.source.dense())
    assert a.dtype == b.dtype
    np.testing.assert_array_equal(a, b)


def test_engine_high_motion_falls_back(rng, f0):
    eng = HistogramEngine(BINS, backend="jnp")
    f1 = rng.integers(0, 256, (H, W), dtype=np.uint8)   # wholly dirty
    out = eng.run(f1, prev=(f0, eng.run(f0)))
    assert not out.plan.incremental
    np.testing.assert_array_equal(np.asarray(out.source.dense()),
                                  _full(f1))


def test_engine_shape_mismatch_falls_back(rng, f0):
    eng = HistogramEngine(BINS, backend="jnp")
    prev = eng.run(f0)
    f1 = rng.integers(0, 256, (H + 8, W), dtype=np.uint8)
    out = eng.run(f1, prev=(f0, prev))
    assert not out.plan.incremental


@pytest.mark.parametrize("storage", ["float32", "uint32", "uint16"])
def test_engine_spilled_incremental_parity(rng, f0, storage):
    budget = 4 * BINS * W * 8                       # 8-row bands
    eng = HistogramEngine(BINS, backend="jnp", storage=storage,
                          memory_budget_bytes=budget)
    f1 = _mutate(f0, rng, 9, 12)
    out0 = eng.run(f0)
    assert out0.plan.representation == "spilled"
    out1 = eng.run(f1, prev=(f0, out0))
    assert out1.plan.incremental
    full = eng.run(f1)
    for got, want in zip(out1.source.bands, full.source.bands):
        assert got.dtype == want.dtype
        np.testing.assert_array_equal(got, want)
    for got, want in zip(out1.source.carries, full.source.carries):
        np.testing.assert_array_equal(got, want)
    # chain a second update off the updated source (carries stay live)
    f2 = _mutate(f1, rng, 25, 28)
    out2 = eng.run(f2, prev=(f1, out1))
    assert out2.plan.incremental
    for got, want in zip(out2.source.bands, eng.run(f2).source.bands):
        np.testing.assert_array_equal(got, want)


def test_engine_banded_incremental_parity(rng, f0):
    budget = 4 * BINS * W * 8
    eng = HistogramEngine(BINS, backend="jnp", memory_budget_bytes=budget)
    f1 = _mutate(f0, rng, 3, 6)
    out0 = eng.run(f0)
    assert out0.plan.representation == "banded"
    out1 = eng.run(f1, prev=(f0, out0))
    assert out1.plan.incremental
    np.testing.assert_array_equal(np.asarray(out1.source.dense()),
                                  _full(f1))


def test_fused_predecessor_falls_back_to_recompute(rng, f0):
    """A fused H never materializes, so it cannot seed an update."""
    eng = HistogramEngine(BINS, backend="jnp")
    q = RegionQuery(np.array([2, 2, 10, 10]))
    prev = eng.run(f0, [q])
    assert prev.plan.representation == "fused"
    f1 = _mutate(f0, rng, 6, 9)
    out = eng.run(f1, [q], prev=(f0, prev))
    assert not out.plan.incremental
    want = eng.run(f1, [q]).results[0]
    np.testing.assert_array_equal(np.asarray(out.results[0]),
                                  np.asarray(want))


def test_incremental_plan_answers_queries(rng, f0):
    """Queries ride an incremental plan (fusion is skipped: the slab
    must persist to seed the next frame)."""
    eng = HistogramEngine(BINS, backend="jnp")
    f1 = _mutate(f0, rng, 6, 9)
    q = RegionQuery(np.array([2, 2, 10, 10]))
    out = eng.run(f1, [q], prev=(f0, eng.run(f0)))
    assert out.plan.incremental and out.plan.representation == "dense"
    want = eng.run(f1, [q]).results[0]
    np.testing.assert_array_equal(np.asarray(out.results[0]),
                                  np.asarray(want))


# ---------------------------------------------------------------------------
# planner gate: threshold, validation, priors override
# ---------------------------------------------------------------------------
def test_plan_threshold_gate():
    base = WorkloadSpec(height=H, width=W, num_bins=BINS, backend="jnp")
    low = plan(dataclasses.replace(base, dirty_fraction=0.2))
    assert low.incremental
    high = plan(dataclasses.replace(base, dirty_fraction=0.5))
    assert not high.incremental
    none = plan(base)
    assert not none.incremental
    with pytest.raises(ValueError, match="dirty_fraction"):
        plan(dataclasses.replace(base, dirty_fraction=1.5))


def test_plan_threshold_prior_override(tmp_path, monkeypatch):
    priors = tmp_path / "tuned.json"
    priors.write_text(json.dumps({
        "version": 1,
        "configs": {f"{H}x{W}x{BINS}": {"tile": 128, "bin_block": 8,
                                        "delta_threshold": 0.6}},
    }))
    monkeypatch.setenv(autotune.ENV_VAR, str(priors))
    spec = WorkloadSpec(height=H, width=W, num_bins=BINS, backend="jnp",
                        dirty_fraction=0.5)
    assert plan(spec).incremental          # 0.5 <= tuned 0.6


def test_explain_prices_the_update(rng, f0):
    eng = HistogramEngine(BINS, backend="jnp")
    f1 = _mutate(f0, rng, 6, 9)
    text = eng.run(f1, prev=(f0, eng.run(f0))).plan.explain()
    line = [ln for ln in text.splitlines() if "incremental" in ln]
    assert len(line) == 1 and "reuse" in line[0]
    # non-incremental plans render no such line (golden safety)
    assert "incremental" not in eng.run(f1).plan.explain()


def test_plancheck_incremental_line(rng, f0):
    from repro.analysis import plancheck

    eng = HistogramEngine(BINS, backend="jnp")
    f1 = _mutate(f0, rng, 6, 9)
    p = eng.run(f1, prev=(f0, eng.run(f0))).plan
    v = plancheck.check_plan(p, deep=True)
    assert v.ok
    inc = [c for c in v.checks if c.name == "incremental"]
    assert len(inc) == 1 and inc[0].status == "ok"
    # and absent from a plain plan's verdict
    v2 = plancheck.check_plan(eng.run(f1).plan)
    assert not any(c.name == "incremental" for c in v2.checks)


# ---------------------------------------------------------------------------
# the delta_apply kernel
# ---------------------------------------------------------------------------
def test_delta_apply_jnp_vs_pallas_interpret(rng):
    slab = rng.integers(0, 1000, (BINS, 40, 56)).astype(np.float32)
    d = rng.integers(-50, 50, (BINS, 56)).astype(np.float32)
    a = np.asarray(ops.delta_apply(slab, d, backend="jnp"))
    b = np.asarray(ops.delta_apply(slab, d, backend="pallas",
                                   interpret=True))
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(a, slab + d[:, None, :])
    # batched (n, b, h, w) form
    slab4 = np.stack([slab, 2 * slab])
    d4 = np.stack([d, -d])
    a4 = np.asarray(ops.delta_apply(slab4, d4, backend="jnp"))
    b4 = np.asarray(ops.delta_apply(slab4, d4, backend="pallas",
                                    interpret=True))
    np.testing.assert_array_equal(a4, b4)


def test_delta_apply_validation(rng):
    slab = np.zeros((BINS, 8, 8), np.float32)
    with pytest.raises(ValueError):
        ops.delta_apply(slab, np.zeros((BINS + 1, 8), np.float32))
    with pytest.raises(ValueError):
        ops.delta_apply(np.zeros((8,), np.float32),
                        np.zeros((8, 8), np.float32))


def test_delta_apply_kernelspec_registered():
    from repro.analysis import kernelcheck

    assert "delta_apply" in ops.KERNEL_SPECS
    for geom in kernelcheck.DEFAULT_GEOMETRIES:
        verdict = kernelcheck.check_method("delta_apply", geom)
        assert verdict.ok, verdict.render()
    est = kernelcheck.vmem_required("delta_apply",
                                    kernelcheck.DEFAULT_GEOMETRIES[0])
    assert est is not None and est[0] <= kernelcheck.VMEM_LIMIT_BYTES


# ---------------------------------------------------------------------------
# spilled walk edges
# ---------------------------------------------------------------------------
def test_update_spilled_requires_carries_and_matching_spans(rng, f0):
    budget = 4 * BINS * W * 8
    eng = HistogramEngine(BINS, backend="jnp", storage="uint16",
                          memory_budget_bytes=budget)
    src = eng.run(f0).source
    f1 = _mutate(f0, rng, 9, 12)
    rep = delta_mod.diff_bands(f0, f1, src.spans)

    def recompute(band_rows, carry):
        return ops.integral_histogram(band_rows, BINS, backend="jnp",
                                      carry_in=carry)

    stale = dataclasses.replace(src, carries=None)
    with pytest.raises(ValueError, match="carr"):
        delta_mod.update_spilled_ih(stale, f1, rep, recompute=recompute)
    bad = delta_mod.diff_bands(f0, f1, [(0, H)])
    with pytest.raises(ValueError, match="spans"):
        delta_mod.update_spilled_ih(src, f1, bad, recompute=recompute)


def test_tracker_incremental_clip_parity(rng):
    from repro.core.tracking import FragmentTracker, TrackerConfig

    clip = [rng.integers(0, 256, (H, W), dtype=np.uint8)]
    for _ in range(3):
        clip.append(_mutate(clip[-1], rng, 9, 12))
    clip = np.stack(clip)
    tr = FragmentTracker(TrackerConfig(num_bins=BINS, search_radius=3))
    st = tr.init(clip[0], np.array([8, 6, 20, 18]))
    _, a = tr.track(st, clip)
    _, b = tr.track(st, clip, incremental=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
