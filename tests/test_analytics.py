"""Batched O(1) analytics + the five audited-bug regressions.

Property tests: every analytics entry point accepts (n, b, h, w) H stacks
bit-exactly equal to a per-frame Python loop; the strided-slice sliding
windows match the gather path bit-exactly; the batched multi-target
tracker matches per-target and per-frame loops bit-exactly.

Regression tests (each fails on the pre-PR code):
  * bhattacharyya stays in [0, 1] — no per-empty-bin sqrt(eps) bias
  * tracker bboxes never leave the frame, even for oversized templates
  * explicit backend="pallas" with a non-Pallas method raises (only
    backend="auto" may fall back to the jnp scans)
  * prefetch_to_device stages exactly `size` frames ahead, not size + 1
"""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import distances
from repro.core.pipeline import prefetch_to_device
from repro.core.region_query import (
    likelihood_map, multi_scale_search, region_histogram,
    sliding_window_histograms,
)
from repro.core.tracking import FragmentTracker, TrackerConfig
from repro.kernels.ops import integral_histogram
from repro.kernels.ref import integral_histogram_ref


def _h_stack(rng, n=3, h=24, w=30, bins=8):
    imgs = rng.integers(0, 256, (n, h, w), dtype=np.uint8)
    return jnp.stack(
        [integral_histogram_ref(jnp.asarray(im), bins) for im in imgs]
    )


# ---------------------------------------------------------------------------
# rank-polymorphic queries: (n, b, h, w) == per-frame loop, bit-exact
# ---------------------------------------------------------------------------
def test_batched_region_histogram_equals_loop(rng):
    Hs = _h_stack(rng)
    rects = jnp.array([[0, 0, 23, 29], [2, 3, 10, 12], [5, 5, 5, 5]])
    batched = region_histogram(Hs, rects)
    loop = jnp.stack([region_histogram(Hs[i], rects) for i in range(3)])
    assert batched.shape == (3, 3, 8)
    np.testing.assert_array_equal(np.asarray(batched), np.asarray(loop))
    # scalar rect keeps working, batched and single
    one = region_histogram(Hs, jnp.array([1, 2, 9, 11]))
    assert one.shape == (3, 8)
    np.testing.assert_array_equal(
        np.asarray(one[1]),
        np.asarray(region_histogram(Hs[1], jnp.array([1, 2, 9, 11]))))


@pytest.mark.parametrize("window,stride", [
    ((8, 10), 1), ((8, 10), 3), ((24, 30), 1), ((1, 1), 5), ((3, 7), 4),
])
def test_sliding_windows_slice_matches_gather(rng, window, stride):
    Hs = _h_stack(rng)
    sl = sliding_window_histograms(Hs, window, stride)
    ga = sliding_window_histograms(Hs, window, stride, impl="gather")
    np.testing.assert_array_equal(np.asarray(sl), np.asarray(ga))
    loop = jnp.stack([
        sliding_window_histograms(Hs[i], window, stride) for i in range(3)
    ])
    np.testing.assert_array_equal(np.asarray(sl), np.asarray(loop))
    # every window histogram sums to the window area
    np.testing.assert_array_equal(
        np.asarray(jnp.sum(sl, -1)), float(window[0] * window[1]))


def test_sliding_windows_oversized_window_is_empty(rng):
    """A window larger than the frame has no positions: both impls must
    return the same empty result instead of the slice path crashing."""
    Hs = _h_stack(rng)                       # frames are 24x30
    for impl in ("slice", "gather"):
        assert sliding_window_histograms(
            Hs, (30, 10), 2, impl=impl).shape == (3, 0, 11, 8)
        assert sliding_window_histograms(
            Hs[0], (30, 40), 1, impl=impl).shape == (0, 0, 8)


def test_sliding_windows_unknown_impl_raises(rng):
    with pytest.raises(ValueError, match="impl"):
        sliding_window_histograms(_h_stack(rng)[0], (4, 4), impl="scatter")


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    wh=st.integers(1, 20), ww=st.integers(1, 24),
    stride=st.integers(1, 5),
)
def test_property_slice_equals_gather(seed, wh, ww, stride):
    """The strided-slice path is the gather path, bit for bit."""
    r = np.random.default_rng(seed)
    img = r.integers(0, 256, (20, 24), dtype=np.uint8)
    H = integral_histogram_ref(jnp.asarray(img), 4)
    sl = sliding_window_histograms(H, (wh, ww), stride)
    ga = sliding_window_histograms(H, (wh, ww), stride, impl="gather")
    np.testing.assert_array_equal(np.asarray(sl), np.asarray(ga))


def test_batched_likelihood_map_and_search(rng):
    Hs = _h_stack(rng)
    shared = region_histogram(Hs[0], jnp.array([0, 0, 7, 9]))
    per_frame = region_histogram(Hs, jnp.array([0, 0, 7, 9]))    # (3, 8)
    for target in (shared, per_frame):
        got = likelihood_map(Hs, target, (8, 10), distances.intersection, 2)
        want = jnp.stack([
            likelihood_map(Hs[i], target if target.ndim == 1 else target[i],
                           (8, 10), distances.intersection, 2)
            for i in range(3)
        ])
        assert got.shape == (3, 9, 11)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    rect, score, maps = multi_scale_search(
        Hs, shared, ((8, 10), (6, 6)), distances.intersection, stride=2)
    assert rect.shape == (3, 4) and score.shape == (3,)
    # an oversized scale contributes an empty map but must not crash
    rect_o, score_o, maps_o = multi_scale_search(
        Hs, shared, ((8, 10), (30, 40)), distances.intersection, stride=2)
    assert maps_o[1].shape[-2:] == (0, 0)
    np.testing.assert_array_equal(np.asarray(rect_o[..., 2] - rect_o[..., 0]),
                                  7)        # best rect from the valid scale
    for i in range(3):
        r1, s1, m1 = multi_scale_search(
            Hs[i], shared, ((8, 10), (6, 6)), distances.intersection, 2)
        np.testing.assert_array_equal(np.asarray(rect[i]), np.asarray(r1))
        np.testing.assert_array_equal(np.asarray(score[i]), np.asarray(s1))
        for mb, ms in zip(maps, m1):
            np.testing.assert_array_equal(np.asarray(mb[i]), np.asarray(ms))


# ---------------------------------------------------------------------------
# batched tracker: multi-target == per-target, track() == step loop
# ---------------------------------------------------------------------------
def _blob_frames(n=5):
    base = (10 * np.random.default_rng(0).random((64, 64))).astype(np.uint8)
    yy, xx = np.mgrid[0:64, 0:64]

    def frame(t):
        b1 = 220 * np.exp(-((yy - 24 - 2 * t) ** 2 + (xx - 20 - t) ** 2) / 40.0)
        b2 = 140 * np.exp(-((yy - 44 + t) ** 2 + (xx - 44 - t) ** 2) / 40.0)
        return np.clip(base + b1 + b2, 0, 255).astype(np.uint8)

    return [frame(t) for t in range(n)]


@pytest.fixture(scope="module")
def tracker():
    return FragmentTracker(TrackerConfig(num_bins=8, search_radius=5))


def test_multi_target_equals_single_target_loop(tracker):
    frames = _blob_frames()
    bboxes = [[18, 14, 29, 25], [38, 38, 49, 49]]
    mstate = tracker.init(jnp.asarray(frames[0]), bboxes)
    sstates = [tracker.init(jnp.asarray(frames[0]), b) for b in bboxes]
    assert mstate["bbox"].shape == (2, 4)
    for f in frames[1:]:
        mstate = tracker.step(mstate, jnp.asarray(f))
        sstates = [tracker.step(s, jnp.asarray(f)) for s in sstates]
    np.testing.assert_array_equal(
        np.asarray(mstate["bbox"]),
        np.stack([np.asarray(s["bbox"]) for s in sstates]))


@pytest.mark.parametrize("bbox", [
    [18, 14, 29, 25],                       # single target
    [[18, 14, 29, 25], [38, 38, 49, 49]],   # two targets
])
def test_track_clip_equals_step_loop(tracker, bbox):
    frames = _blob_frames()
    st0 = tracker.init(jnp.asarray(frames[0]), bbox)
    # batch_size=3 leaves a ragged 3+1 tail on the 4-frame clip
    final, boxes = tracker.track(st0, frames[1:], batch_size=3)
    st = tracker.init(jnp.asarray(frames[0]), bbox)
    want = []
    for f in frames[1:]:
        st = tracker.step(st, jnp.asarray(f))
        want.append(np.asarray(st["bbox"]))
    np.testing.assert_array_equal(np.asarray(boxes), np.stack(want))
    np.testing.assert_array_equal(
        np.asarray(final["bbox"]), np.asarray(st["bbox"]))


def test_track_auto_batch_and_empty_clip(tracker):
    frames = _blob_frames()
    st0 = tracker.init(jnp.asarray(frames[0]), [18, 14, 29, 25])
    _, auto_boxes = tracker.track(st0, frames[1:])          # "auto"
    _, one_boxes = tracker.track(st0, frames[1:], batch_size=1)
    np.testing.assert_array_equal(np.asarray(auto_boxes), np.asarray(one_boxes))
    _, empty = tracker.track(st0, [])
    assert empty.shape == (0, 4)
    _, empty_auto = tracker.track(st0, iter([]))
    assert empty_auto.shape == (0, 4)
    with pytest.raises(ValueError, match="batch_size"):
        tracker.track(st0, frames[1:], batch_size=0)
    with pytest.raises(ValueError, match=r"\(n, h, w\) clip"):
        tracker.track(st0, jnp.asarray(frames[1]))      # single 2-D frame
    # device-array clips go through the slicing path, bit-exact with lists
    _, from_list = tracker.track(st0, frames[1:], batch_size=2)
    _, from_array = tracker.track(
        st0, jnp.asarray(np.stack(frames[1:])), batch_size=2)
    np.testing.assert_array_equal(np.asarray(from_list), np.asarray(from_array))


# ---------------------------------------------------------------------------
# regression: the five audited bugs
# ---------------------------------------------------------------------------
def test_bhattacharyya_bounded():
    """Empty bins must not contribute sqrt(eps): identical-support
    histograms score exactly ~1, disjoint-support exactly ~0, at any bin
    count (the old eps-inside-sqrt scored 1.0127 and 0.0128 at 128)."""
    h = np.zeros(128); h[3] = 5.0; h[70] = 2.0
    g = np.zeros(128); g[10] = 4.0
    same = float(distances.bhattacharyya(jnp.asarray(h), jnp.asarray(h)))
    disj = float(distances.bhattacharyya(jnp.asarray(h), jnp.asarray(g)))
    assert same <= 1.0 + 1e-6
    assert same == pytest.approx(1.0, abs=1e-5)
    assert 0.0 <= disj < 1e-6


def test_tracker_bbox_never_leaves_frame(tracker):
    frames = _blob_frames()
    # a template larger than the frame used to clamp to negative bounds
    # and emit candidate rects like [-3, -3, 15, 15]
    state = tracker.init(jnp.asarray(frames[0]), [-5, -5, 200, 200])
    b = np.asarray(state["bbox"])
    assert (b == [0, 0, 63, 63]).all()
    for f in frames[1:3]:
        state = tracker.step(state, jnp.asarray(f))
        b = np.asarray(state["bbox"])
        assert (b[:2] >= 0).all() and b[2] <= 63 and b[3] <= 63
    # a border-hugging target stays clamped inside as well
    state = tracker.init(jnp.asarray(frames[0]), [56, 56, 63, 63])
    for f in frames[1:3]:
        state = tracker.step(state, jnp.asarray(f))
        b = np.asarray(state["bbox"])
        assert (b[:2] >= 0).all() and b[2] <= 63 and b[3] <= 63


@pytest.mark.parametrize("method", ["cw_b", "cw_sts"])
def test_explicit_pallas_backend_raises_for_cross_weave(rng, method):
    img = jnp.asarray(rng.integers(0, 256, (16, 16), dtype=np.uint8))
    with pytest.raises(ValueError, match="no Pallas kernel"):
        integral_histogram(img, 4, method=method, backend="pallas")
    # backend="auto" may still fall back to the jnp scans silently
    out = integral_histogram(img, 4, method=method, backend="auto")
    assert out.shape == (4, 16, 16)
    with pytest.raises(ValueError, match="backend"):
        integral_histogram(img, 4, backend="cuda")


def test_prefetch_stages_exactly_size():
    pulled = []

    def gen(n=6):
        for i in range(n):
            pulled.append(i)
            yield np.full((2, 2), i, np.float32)

    it = prefetch_to_device(gen(), size=2)
    first = next(it)
    assert pulled == [0, 1]          # pre-fix: [0, 1, 2] (size + 1 staged)
    got = [int(first[0, 0])] + [int(a[0, 0]) for a in it]
    assert got == list(range(6))

    pulled.clear()
    it = prefetch_to_device(gen(4), size=1)
    next(it)
    assert pulled == [0]
    assert len(list(it)) == 3
