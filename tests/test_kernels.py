"""Pallas kernel validation: interpret-mode vs the pure-jnp oracle.

Every kernel x {tile, bin_block, mxu-mode} x {image size, dtype} sweep
asserts allclose against kernels/ref.py, exactly as the assignment
requires (CPU container: interpret=True executes the kernel body)."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.ops import integral_histogram
from repro.kernels.ref import integral_histogram_ref

SIZES = [(32, 32), (64, 96), (128, 128), (96, 160)]


def _img(rng, h, w, dtype=np.uint8):
    if dtype == np.uint8:
        return rng.integers(0, 256, (h, w), dtype=np.uint8)
    return rng.random((h, w), dtype=np.float32)


@pytest.mark.parametrize("method", ["cw_tis", "wf_tis"])
@pytest.mark.parametrize("hw", SIZES)
@pytest.mark.parametrize("bins", [8, 16, 32])
def test_pallas_matches_ref(rng, method, hw, bins):
    img = _img(rng, *hw)
    ref = integral_histogram_ref(jnp.asarray(img), bins)
    out = integral_histogram(jnp.asarray(img), bins, method=method,
                             backend="pallas", tile=32, bin_block=8,
                             interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-3)


@pytest.mark.parametrize("method", ["cw_tis", "wf_tis"])
@pytest.mark.parametrize("tile", [16, 32, 64])
def test_tile_size_invariance(rng, method, tile):
    img = _img(rng, 64, 64)
    ref = integral_histogram_ref(jnp.asarray(img), 16)
    out = integral_histogram(jnp.asarray(img), 16, method=method,
                             backend="pallas", tile=tile, bin_block=8,
                             interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-3)


@pytest.mark.parametrize("use_mxu", [True, False])
def test_mxu_vs_vpu_scan(rng, use_mxu):
    """The triangular-matmul (MXU) scan must equal the ladder cumsum."""
    img = _img(rng, 64, 64)
    ref = integral_histogram_ref(jnp.asarray(img), 8)
    out = integral_histogram(jnp.asarray(img), 8, method="wf_tis",
                             backend="pallas", tile=32, bin_block=8,
                             use_mxu=use_mxu, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-3)


def test_float_images(rng):
    img = _img(rng, 64, 64, np.float32)
    ref = integral_histogram_ref(jnp.asarray(img), 16)
    out = integral_histogram(jnp.asarray(img), 16, method="wf_tis",
                             backend="pallas", tile=32, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-3)


def test_nondivisible_bins(rng):
    """Bin padding: 12 bins with bin_block 8 pads to 16, crops back."""
    img = _img(rng, 32, 32)
    ref = integral_histogram_ref(jnp.asarray(img), 12)
    out = integral_histogram(jnp.asarray(img), 12, method="wf_tis",
                             backend="pallas", tile=32, bin_block=8,
                             interpret=True)
    assert out.shape == (12, 32, 32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(
    h=st.integers(8, 80), w=st.integers(8, 80),
    bins=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_pallas_random_shapes(h, w, bins, seed):
    """Hypothesis: arbitrary (h, w) images (padding path) match the oracle."""
    r = np.random.default_rng(seed)
    img = r.integers(0, 256, (h, w), dtype=np.uint8)
    ref = integral_histogram_ref(jnp.asarray(img), bins)
    out = integral_histogram(jnp.asarray(img), bins, method="wf_tis",
                             backend="pallas", tile=16, bin_block=4,
                             interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-3)


@pytest.mark.parametrize("chunk", [8, 16, 32])
def test_ssd_scan_pallas_matches_oracle(chunk):
    """The SSD Pallas kernel (WF-TiS carry pattern on the model zoo's hot
    spot) vs the pure-jnp chunked-scan oracle."""
    import jax
    from repro.kernels.ssd_scan import ssd_scan
    from repro.models.ssm import ssd_chunked

    k = jax.random.split(jax.random.PRNGKey(3), 5)
    B, S, H, P, N = 2, 64, 3, 8, 16
    x = jax.random.normal(k[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(k[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(k[2], (H,)) * 0.2)
    Bm = jax.random.normal(k[3], (B, S, 1, N)) * 0.3
    Cm = jax.random.normal(k[4], (B, S, 1, N)) * 0.3
    ref, _ = ssd_chunked(x.astype(jnp.float32), dt, A, Bm, Cm, chunk=16)
    out = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_last_corner_is_total_count(rng):
    """H[:, -1, -1] must equal h*w (every pixel in exactly one bin)."""
    img = _img(rng, 48, 80)
    out = integral_histogram(jnp.asarray(img), 16, method="wf_tis",
                             backend="pallas", tile=16, interpret=True)
    assert float(jnp.sum(out[:, -1, -1])) == 48 * 80
