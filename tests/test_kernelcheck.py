"""kernelcheck: the Pallas kernels' grid/carry/VMEM contracts.

Three layers of assurance:

  * the verifier PROVES all four properties (carry happens-before,
    exactly-once output coverage, in-bounds index maps, VMEM fit) for
    every shipped kernel pass — wf_tis and both cw_tis passes — at even
    and uneven geometries;
  * each check CATCHES its seeded violation class (reordered grid dims,
    overlapping out index map, off-by-one block index, oversized
    scratch) — a verifier that cannot fail proves nothing;
  * the declared KernelSpec CANNOT DRIFT from the live ``pallas_call``:
    a conformance test captures the real call's grid/BlockSpecs/scratch
    and compares them field by field (index maps at every grid point),
    while the same run checks numeric parity against the jnp oracle in
    interpret mode at uneven shapes.

Plus the wiring: plancheck's vmem-fit delegates to the same spec-derived
number, and ``HistogramEngine.validate(deep=True)`` rejects a pallas
plan whose spec fails.
"""

from __future__ import annotations

import dataclasses
import json

import numpy as np
import pytest

from repro.analysis import kernelcheck as kc
from repro.analysis.__main__ import main as analysis_main
from repro.kernels import ops
from repro.kernels.specs import KernelGeometry, Scratch

CHECK_NAMES = ("carry-order", "out-coverage", "in-bounds", "vmem-fit")

GEOMS = {
    "640x480": KernelGeometry(n=2, h=480, w=640, num_bins=32),
    "uneven": KernelGeometry(n=3, h=300, w=500, num_bins=20),
    "paper-8k": KernelGeometry(n=1, h=8192, w=8192, num_bins=128),
}

#: small interpret-runnable geometry with nth != ntw and padding on
#: every axis (h 20 -> 24, w uneven, bins exact).
SMALL = KernelGeometry(n=2, h=20, w=24, num_bins=8, tile=8, bin_block=4)


@pytest.fixture
def fresh_caches():
    """Tests that monkeypatch KERNEL_SPECS must not leave poisoned
    verdicts in the lru caches (keyed only by method+geometry/plan)."""
    from repro.analysis import plancheck

    kc.check_method.cache_clear()
    plancheck._kernel_checks.cache_clear()
    yield
    kc.check_method.cache_clear()
    plancheck._kernel_checks.cache_clear()


# ---------------------------------------------------------------------------
# the four properties hold for every shipped kernel pass
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("geom", GEOMS.values(), ids=GEOMS.keys())
@pytest.mark.parametrize("method", sorted(ops.KERNEL_SPECS))
def test_all_four_properties_prove(method, geom):
    verdict = kc.check_method(method, geom)
    assert verdict.ok, verdict.render()
    passes = ops.KERNEL_SPECS[method](geom)
    # every pass gets all four checks, all ok
    assert len(verdict.checks) == 4 * len(passes)
    for spec in passes:
        names = [c.name for c in verdict.checks if c.kernel == spec.name]
        assert names == list(CHECK_NAMES)
    assert all(c.status == "ok" for c in verdict.checks)


def test_cw_tis_declares_both_passes_with_swapped_grids():
    """The vscan contract IS the deliberate ntw/nth swap — the verifier
    proves that order rather than assuming pass 1's."""
    hscan, vscan = ops.KERNEL_SPECS["cw_tis"](GEOMS["640x480"])
    assert hscan.dim_names == ("f", "bb", "ih", "iw")
    assert vscan.dim_names == ("f", "bb", "iw", "ih")


def test_every_pallas_method_has_a_spec():
    # Every full-H Pallas method is spec-verified; the registry also
    # carries the query-fused dispatch (not a named method — it is the
    # kernel behind ops.fused_corner_rows).
    assert set(ops.PALLAS_METHODS) <= set(ops.KERNEL_SPECS)
    assert "fused_rows" in ops.KERNEL_SPECS


def test_canonical_geometry_clamps_and_floors():
    g = GEOMS["paper-8k"].canonical()
    assert (g.n, g.nth, g.ntw, g.nbb) == (2, 3, 3, 3)
    # a single-tile geometry is not inflated, but frames floor at 2
    tiny = KernelGeometry(n=1, h=100, w=100, num_bins=4).canonical()
    assert (tiny.n, tiny.nth, tiny.ntw, tiny.nbb) == (2, 1, 1, 1)


# ---------------------------------------------------------------------------
# each check catches its seeded violation
# ---------------------------------------------------------------------------
def _vscan():
    """The cw_tis vertical pass at the canonical small geometry — the
    richest spec (two inputs, single shared scratch cell)."""
    return ops.KERNEL_SPECS["cw_tis"](GEOMS["640x480"].canonical())[1]


def test_reordered_grid_dims_fail_carry_order():
    """Re-declaring vscan with hscan's (ih, iw) order: the shared
    column-carry cell's last writer is no longer the declared producer
    (it was overwritten by the interleaved strips) — the exact bug class
    'written earlier' would miss."""
    spec = _vscan()
    sizes = dict(spec.grid)
    bad = dataclasses.replace(spec, grid=(
        ("f", sizes["f"]), ("bb", sizes["bb"]),
        ("ih", sizes["ih"]), ("iw", sizes["iw"]),
    ))
    check = kc.check_carry_order(bad)
    assert check.status == "fail"
    assert "last write under this grid order" in check.detail
    # the declared order proves clean
    assert kc.check_carry_order(spec).status == "ok"


def test_unwritten_carry_cell_fails_carry_order():
    spec = _vscan()
    bad = dataclasses.replace(spec, carry_writes=lambda g: [])
    check = kc.check_carry_order(bad)
    assert check.status == "fail"
    assert "before any write" in check.detail


def test_overlapping_out_map_fails_coverage():
    """An out map that drops the bin-block index writes each spatial
    block once per bin block — a write race (and a gap elsewhere)."""
    spec = _vscan()
    op = spec.out_specs[0]
    bad_op = dataclasses.replace(
        op, index_map=lambda f, bb, iw, ih: (f, 0, ih, iw))
    check = kc.check_out_coverage(
        dataclasses.replace(spec, out_specs=(bad_op,)))
    assert check.status == "fail"
    assert "more than once" in check.detail
    assert "never written" in check.detail


def test_off_by_one_block_index_fails_bounds():
    spec = _vscan()
    op = spec.out_specs[0]
    bad_op = dataclasses.replace(
        op, index_map=lambda f, bb, iw, ih: (f, bb, ih, iw + 1))
    check = kc.check_in_bounds(
        dataclasses.replace(spec, out_specs=(bad_op,)))
    assert check.status == "fail"
    assert "outside the padded extent" in check.detail


def test_oversized_scratch_fails_vmem():
    spec = _vscan()
    bad = dataclasses.replace(
        spec, scratch=(Scratch("huge", (64, 1024, 1024)),))
    assert kc.check_vmem_fit(bad).status == "fail"
    # tile=1024 blows the block budget through the same spec arithmetic
    big = kc.check_method(
        "wf_tis", KernelGeometry(n=1, h=2048, w=2048, num_bins=32,
                                 tile=1024))
    assert [c.status for c in big.checks if c.name == "vmem-fit"] \
        == ["fail"]
    assert big.ok is False


# ---------------------------------------------------------------------------
# spec-vs-pallas_call conformance (interpret mode, uneven shapes)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("method", sorted(ops.PALLAS_METHODS))
def test_spec_matches_live_pallas_call(method, monkeypatch):
    """Capture the real ``pallas_call`` arguments and compare them field
    by field against the KernelSpec — grid, block shapes, index maps at
    EVERY grid point, out_shape, scratch shapes — while the same run
    checks numeric parity against the jnp oracle."""
    from jax.experimental import pallas as pl

    from repro.kernels.ref import integral_histogram_ref

    captured = []
    real = pl.pallas_call

    def spy(kernel, **kw):
        captured.append(kw)
        return real(kernel, **kw)

    # both kernel modules bind `pl` to this same module object
    monkeypatch.setattr(pl, "pallas_call", spy)

    rng = np.random.default_rng(7)
    frames = rng.integers(0, 256, (SMALL.n, SMALL.h, SMALL.w), np.uint8)
    out = ops.integral_histogram(
        frames, SMALL.num_bins, method=method, backend="pallas",
        tile=SMALL.tile, bin_block=SMALL.bin_block, interpret=True)
    for i in range(SMALL.n):
        ref = integral_histogram_ref(frames[i], SMALL.num_bins)
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(ref))

    specs = ops.KERNEL_SPECS[method](SMALL)
    assert len(captured) == len(specs), \
        f"{len(specs)} declared pass(es), {len(captured)} pallas_call(s)"
    for spec, call in zip(specs, captured):
        assert tuple(call["grid"]) == spec.grid_sizes, spec.name
        outs = call["out_specs"]
        if not isinstance(outs, (list, tuple)):
            outs = [outs]
        live = list(call["in_specs"]) + list(outs)
        declared = spec.in_specs + spec.out_specs
        assert len(live) == len(declared), spec.name
        for op, bs in zip(declared, live):
            assert tuple(bs.block_shape) == op.block, \
                f"{spec.name}:{op.name} block"
            for g in kc.iter_grid(spec):
                key = tuple(g[d] for d in spec.dim_names)
                assert tuple(bs.index_map(*key)) \
                    == tuple(op.index_map(*key)), \
                    f"{spec.name}:{op.name} index map at {g}"
        out_sds = call["out_shape"]
        assert tuple(out_sds.shape) == spec.out_specs[0].shape, spec.name
        live_scratch = [tuple(s.shape) for s in call["scratch_shapes"]]
        assert live_scratch == [s.shape for s in spec.scratch], spec.name


# ---------------------------------------------------------------------------
# plancheck/engine wiring
# ---------------------------------------------------------------------------
def _pallas_plan(shape=(480, 640), **kw):
    from repro.core.engine import HistogramEngine, plan

    e = HistogramEngine(32, backend="pallas", **kw)
    return e, plan(e.spec_for(shape, "uint8"))


def test_plancheck_vmem_delegates_to_kernelcheck():
    """One VMEM model: the plan-level estimate IS the spec-derived
    number (the duplicated hand formula is gone)."""
    from repro.analysis.plancheck import _vmem_estimate

    for method in sorted(ops.KERNEL_SPECS):
        e, p = _pallas_plan()
        p = dataclasses.replace(p, method=method)
        est = _vmem_estimate(p)
        assert est is not None
        geom = kc.plan_geometry(p)
        assert est == kc.vmem_required(method, geom)
        assert est[0] == max(
            s.vmem_bytes() for s in ops.KERNEL_SPECS[method](geom))


def test_vmem_estimate_none_for_non_pallas_methods():
    from repro.analysis.plancheck import _vmem_estimate

    e, p = _pallas_plan()
    assert _vmem_estimate(dataclasses.replace(p, method="cw_b")) is None


def test_validate_deep_merges_kernel_checks():
    e, p = _pallas_plan()
    shallow = e.validate(p)
    assert "kernel-carry" not in shallow.render()
    deep = e.validate(p, deep=True)
    assert deep.ok
    names = [c.name for c in deep.checks]
    for n in ("kernel-carry", "kernel-coverage", "kernel-bounds",
              "kernel-vmem"):
        assert n in names
    # explain() surfaces the deep verdict (last_verdict)
    e.last_plan = p
    assert "kernel-carry" in e.explain()


def test_validate_deep_skips_for_jnp_backend():
    from repro.core.engine import HistogramEngine, plan

    e = HistogramEngine(32, backend="jnp")
    p = plan(e.spec_for((480, 640), "uint8"))
    deep = e.validate(p, deep=True)
    assert deep.ok
    skip = [c for c in deep.checks if c.name == "kernel-checks"]
    assert len(skip) == 1 and skip[0].status == "skip"


def _broken_wf_specs(geom):
    """wf_tis re-declared with ih/iw swapped but carry edges kept — the
    row carry's happens-before no longer holds."""
    from repro.kernels import wf_tis

    (spec,) = wf_tis.kernel_specs(geom)
    sizes = dict(spec.grid)
    return (dataclasses.replace(spec, grid=(
        ("f", sizes["f"]), ("iw", sizes["iw"]),
        ("ih", sizes["ih"]), ("bb", sizes["bb"]),
    )),)


def test_engine_deep_validate_rejects_failing_spec(
        monkeypatch, fresh_caches):
    from repro.core.engine import PlanValidationError

    monkeypatch.setitem(ops.KERNEL_SPECS, "wf_tis", _broken_wf_specs)
    e, p = _pallas_plan()
    deep = e.validate(p, deep=True)
    assert not deep.ok
    assert {c.name for c in deep.failures} <= {
        "kernel-carry", "kernel-coverage", "kernel-bounds"}
    assert any(c.name == "kernel-carry" for c in deep.failures)
    # shallow validation still passes — the rejection is the deep gate's
    assert e.validate(p).ok
    # and run() refuses to dispatch (validate-or-raise runs deep)
    with pytest.raises(PlanValidationError, match="kernel-carry"):
        e.run(np.zeros((480, 640), np.uint8))


# ---------------------------------------------------------------------------
# CLI: python -m repro.analysis --check-kernels
# ---------------------------------------------------------------------------
def test_cli_check_kernels_clean(tmp_path, capsys):
    report = tmp_path / "kernelcheck.json"
    rc = analysis_main(["--check-kernels", "--json", str(report)])
    out = capsys.readouterr().out
    assert rc == 0
    assert "kernel verdict(s), 0 failed" in out
    data = json.loads(report.read_text())
    assert data["counts"]["failed"] == 0
    assert data["counts"]["total"] == len(data["verdicts"])
    methods = {v["method"] for v in data["verdicts"]}
    assert methods == set(ops.KERNEL_SPECS)
    for v in data["verdicts"]:
        assert v["ok"] is True
        assert {c["status"] for c in v["checks"]} == {"ok"}
        assert {c["name"] for c in v["checks"]} == set(CHECK_NAMES)


def test_cli_check_kernels_fails_on_bad_spec(
        monkeypatch, fresh_caches, capsys):
    monkeypatch.setitem(ops.KERNEL_SPECS, "wf_tis", _broken_wf_specs)
    rc = analysis_main(["--check-kernels"])
    out = capsys.readouterr().out
    assert rc == 1
    assert "REJECTED" in out


def test_cli_check_kernels_usage_errors(capsys):
    # modes are mutually exclusive
    assert analysis_main(["--check-kernels", "--check"]) == 2
    assert analysis_main(["--check-kernels", "--write-baseline"]) == 2
    # and the mode takes no lint paths
    assert analysis_main(["--check-kernels", "src/repro"]) == 2
    capsys.readouterr()
