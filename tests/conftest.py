"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests run on 1 CPU device;
multi-device tests spawn subprocesses with their own flags."""

import warnings

import numpy as np
import pytest

warnings.filterwarnings("ignore", category=DeprecationWarning)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
