"""Band streaming correctness: banded integral histograms must equal the
monolithic computation bit-exactly (all arithmetic is integer-valued fp32)
for every method, at uneven band heights, on single frames and (n, h, w)
stacks; banded O(1) queries must equal queries against the full H without
ever materializing it; storage policies enforce their count bounds."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import distances, scans
from repro.core.bands import (
    BandPlan,
    banded_integral_histogram,
    iter_banded_ih,
    plan_bands,
    reduce_banded_ih,
    spill_banded_ih,
    validate_storage_policy,
)
from repro.core.integral_histogram import IntegralHistogram
from repro.core.region_query import (
    banded_likelihood_map,
    banded_region_histogram,
    banded_sliding_window_histograms,
    likelihood_map,
    region_histogram,
    sliding_window_histograms,
)
from repro.kernels.ops import integral_histogram


def _img(rng, *shape):
    return rng.integers(0, 256, shape, dtype=np.uint8)


# ---------------------------------------------------------------------------
# band planning
# ---------------------------------------------------------------------------
def test_plan_bands_from_budget():
    # 8 bins x width 100 x fp32 = 3200 B/row; 10 kB budget -> 3-row bands
    plan = plan_bands(37, 100, 8, memory_budget_bytes=10_000)
    assert plan.band_h == 3
    assert plan.spans[0] == (0, 3)
    assert plan.spans[-1] == (36, 37)          # uneven tail band
    assert sum(r1 - r0 for r0, r1 in plan.spans) == 37
    assert plan.band_bytes <= 10_000
    assert plan.full_h_bytes == 4 * 8 * 37 * 100


def test_plan_bands_explicit_and_clipped():
    plan = plan_bands(20, 10, 4, band_h=64)
    assert plan.spans == ((0, 20),)            # band_h clipped to h
    plan = plan_bands(20, 10, 4, band_h=8, row_multiple=3)
    assert plan.band_h == 6                    # rounded down to multiple
    assert isinstance(plan, BandPlan) and plan.num_bands == 4


def test_plan_bands_budget_too_small():
    with pytest.raises(ValueError, match="below one"):
        plan_bands(37, 100, 8, memory_budget_bytes=100)  # < one row
    with pytest.raises(ValueError, match="below one"):
        plan_bands(64, 100, 8, memory_budget_bytes=4000, row_multiple=4)


# ---------------------------------------------------------------------------
# banded H parity — all four methods, uneven band heights, frames + stacks
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("method", sorted(scans.METHODS))
@pytest.mark.parametrize("shape", [(37, 23), (2, 37, 23)])
def test_banded_equals_monolithic_jnp(rng, method, shape):
    img = _img(rng, *shape)
    full = integral_histogram(
        jnp.asarray(img), 8, method=method, backend="jnp")
    for band_h in (5, 16, 37):                 # 5 and 16 leave uneven tails
        banded = banded_integral_histogram(
            img, 8, band_h=band_h, method=method, backend="jnp")
        np.testing.assert_array_equal(np.asarray(banded), np.asarray(full))


@pytest.mark.parametrize("method", ["cw_tis", "wf_tis"])
def test_banded_equals_monolithic_pallas(rng, method):
    """The carry-in threads through the Pallas kernels' VMEM carry chain
    (interpret mode on CPU)."""
    img = _img(rng, 40, 48)
    kw = dict(method=method, backend="pallas", tile=16, bin_block=4,
              interpret=True)
    full = integral_histogram(jnp.asarray(img), 6, **kw)
    banded = banded_integral_histogram(img, 6, band_h=24, **kw)  # 24 + 16
    np.testing.assert_array_equal(np.asarray(banded), np.asarray(full))


def test_carry_in_manual_chain(rng):
    """Two halves chained by carry_in == the whole frame, for a native-seed
    method (wf_tis), a post-add method (cw_sts), and the Pallas kernel."""
    img = _img(rng, 30, 17)
    for kw in (dict(method="wf_tis", backend="jnp"),
               dict(method="cw_sts", backend="jnp"),
               dict(method="wf_tis", backend="pallas", tile=16,
                    interpret=True)):
        full = integral_histogram(jnp.asarray(img), 8, **kw)
        top = integral_histogram(jnp.asarray(img[:13]), 8, **kw)
        bot = integral_histogram(
            jnp.asarray(img[13:]), 8, carry_in=top[..., -1, :], **kw)
        np.testing.assert_array_equal(
            np.asarray(jnp.concatenate([top, bot], axis=-2)),
            np.asarray(full))


def test_carry_in_bad_shape_raises(rng):
    img = _img(rng, 16, 16)
    with pytest.raises(ValueError, match="carry_in shape"):
        integral_histogram(jnp.asarray(img), 8, backend="jnp",
                           carry_in=jnp.zeros((8, 15)))


def test_budget_auto_banding(rng):
    """integral_histogram(memory_budget_bytes=...) computes band-by-band
    and still matches the unbudgeted result bit-exactly."""
    img = _img(rng, 37, 23)
    full = integral_histogram(jnp.asarray(img), 8, backend="jnp")
    budget = 6 * 8 * 23 * 4                    # six rows' worth of H
    auto = integral_histogram(jnp.asarray(img), 8, backend="jnp",
                              memory_budget_bytes=budget)
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(full))
    with pytest.raises(ValueError, match="below one"):
        integral_histogram(jnp.asarray(img), 8, backend="jnp",
                           memory_budget_bytes=10)


def test_band_stream_carries(rng):
    """The streamed BandH chain exposes consistent carries and spans."""
    img = _img(rng, 26, 11)
    full = integral_histogram(jnp.asarray(img), 4, backend="jnp")
    r = 0
    for band in iter_banded_ih(img, 4, band_h=7, backend="jnp"):
        assert band.r0 == r and band.frame_h == 26
        np.testing.assert_array_equal(
            np.asarray(band.carry), np.asarray(full[..., band.r1 - 1, :]))
        r = band.r1
    assert r == 26


def test_reduce_banded(rng):
    """Reduce-on-the-fly: the final carry is the full column aggregate."""
    img = _img(rng, 26, 11)
    full = integral_histogram(jnp.asarray(img), 4, backend="jnp")
    last = reduce_banded_ih(img, 4, lambda acc, band: band.carry,
                            band_h=7, backend="jnp")
    np.testing.assert_array_equal(np.asarray(last), np.asarray(full[:, -1, :]))


# ---------------------------------------------------------------------------
# banded O(1) queries — exact without materializing H
# ---------------------------------------------------------------------------
def test_banded_region_histogram(rng):
    img = _img(rng, 64, 48)
    full = integral_histogram(jnp.asarray(img), 8, backend="jnp")
    rects = np.array([[0, 0, 63, 47], [3, 4, 30, 40], [10, 0, 10, 0],
                      [16, 5, 17, 6], [63, 47, 63, 47]])
    got = banded_region_histogram(
        iter_banded_ih(img, 8, band_h=17, backend="jnp"), rects)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(region_histogram(full, rects)))


def test_banded_region_histogram_stack(rng):
    imgs = _img(rng, 2, 40, 32)
    full = integral_histogram(jnp.asarray(imgs), 6, backend="jnp")
    rects = np.array([[0, 0, 39, 31], [5, 5, 20, 20]])
    got = banded_region_histogram(
        iter_banded_ih(imgs, 6, band_h=13, backend="jnp"), rects)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(region_histogram(full, rects)))


@pytest.mark.parametrize("stride", [1, 4, 5])
def test_banded_sliding_windows(rng, stride):
    img = _img(rng, 52, 40)
    full = integral_histogram(jnp.asarray(img), 8, backend="jnp")
    mono = sliding_window_histograms(full, (12, 8), stride)
    band = banded_sliding_window_histograms(
        iter_banded_ih(img, 8, band_h=13, backend="jnp"), (12, 8), stride)
    np.testing.assert_array_equal(np.asarray(band), np.asarray(mono))


def test_banded_sliding_windows_stack_and_oversized(rng):
    imgs = _img(rng, 2, 36, 28)
    full = integral_histogram(jnp.asarray(imgs), 4, backend="jnp")
    mono = sliding_window_histograms(full, (9, 7), 3)
    band = banded_sliding_window_histograms(
        iter_banded_ih(imgs, 4, band_h=10, backend="jnp"), (9, 7), 3)
    np.testing.assert_array_equal(np.asarray(band), np.asarray(mono))
    # window taller than the frame: no positions, same as monolithic
    empty = banded_sliding_window_histograms(
        iter_banded_ih(imgs, 4, band_h=10, backend="jnp"), (50, 7), 3)
    assert empty.shape == (2, 0, 8, 4)


def test_banded_likelihood_map_budgeted(rng):
    """A budgeted run (full H bytes > budget) produces the exact
    likelihood map, and the peak-allocation proxy stays under the full-H
    footprint — the §4.6 large-frame scenario at test scale."""
    img = _img(rng, 96, 64)
    bins = 8
    full_bytes = 4 * bins * 96 * 64
    budget = full_bytes // 8
    full = integral_histogram(jnp.asarray(img), bins, backend="jnp")
    target = region_histogram(full, np.array([20, 10, 43, 33]))
    want = likelihood_map(full, target, (24, 24), distances.intersection,
                          stride=8)
    stats = {}
    got = banded_likelihood_map(
        iter_banded_ih(img, bins, memory_budget_bytes=budget, backend="jnp"),
        target, (24, 24), distances.intersection, stride=8, stats=stats)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert stats["num_bands"] >= 8
    assert stats["band_bytes"] <= budget
    assert stats["peak_bytes"] < stats["full_h_bytes"] == full_bytes


# ---------------------------------------------------------------------------
# storage policies
# ---------------------------------------------------------------------------
def test_storage_policy_validation():
    with pytest.raises(ValueError, match="unknown storage"):
        validate_storage_policy("float16", 10, 10)
    with pytest.raises(ValueError, match="2\\*\\*24"):
        validate_storage_policy("float32", 5000, 4000)   # 2e7 > 2**24
    with pytest.raises(ValueError, match="2\\*\\*24"):
        validate_storage_policy("uint16", 5000, 4000)    # compute inexact
    validate_storage_policy("uint16", 300, 300)          # wraps, but valid


@pytest.mark.parametrize("storage", ["float32", "uint32", "uint16"])
def test_spill_policies_exact(rng, storage):
    img = _img(rng, 60, 44)
    full = integral_histogram(jnp.asarray(img), 8, backend="jnp")
    sp = spill_banded_ih(img, 8, band_h=17, backend="jnp", storage=storage)
    rects = np.array([[0, 0, 59, 43], [7, 3, 41, 30], [59, 43, 59, 43]])
    got = sp.region_histogram(rects)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(region_histogram(full, rects)))
    np.testing.assert_array_equal(sp.assemble(), np.asarray(full))
    # band bytes + the retained fp32 bottom-row carries (4 bands) that
    # seed incremental video-delta updates (core/delta.py)
    assert sp.nbytes == (2 if storage == "uint16" else 4) * 8 * 60 * 44 \
        + 4 * len(sp.spans) * 8 * 44


def test_uint16_modular_wraparound_exact(rng):
    """The reduced-width accumulator trick (arXiv:1510.05142): uint16 H
    values wrap past 65535, yet any <= 65535-pixel region query is exact
    by modular arithmetic; oversized regions are rejected."""
    img = _img(rng, 300, 300)
    img[:250] = 0                       # bin 0 accumulates 75000 > 65535
    full = integral_histogram(jnp.asarray(img), 4, backend="jnp")
    assert float(full.max()) > 65535    # the wrap actually happens
    sp = spill_banded_ih(img, 4, band_h=64, backend="jnp", storage="uint16")
    assert int(max(b.max() for b in sp.bands)) <= 65535
    rects = np.array([[0, 0, 199, 299], [100, 100, 250, 250]])  # <= 60000 px
    np.testing.assert_array_equal(
        np.asarray(sp.region_histogram(rects)),
        np.asarray(region_histogram(full, rects)))
    with pytest.raises(ValueError, match="exceeds the uint16"):
        sp.region_histogram(np.array([[0, 0, 299, 299]]))   # 90000 px


# ---------------------------------------------------------------------------
# public API + prefetch + distributed composition
# ---------------------------------------------------------------------------
def test_map_bands_api_and_prefetch(rng):
    img = _img(rng, 48, 32)
    ih = IntegralHistogram(num_bins=8, backend="jnp")
    full = ih(jnp.asarray(img))
    for prefetch in (0, 2):             # 2 exercises prefetch_row_bands
        got = jnp.concatenate(
            [b.H for b in ih.map_bands(img, band_h=13, prefetch=prefetch)],
            axis=-2)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(full))
    rects = np.array([[0, 0, 47, 31], [5, 5, 30, 20]])
    got = ih.banded_query(ih.map_bands(img, band_h=13), rects)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ih.query(full, rects)))


def test_banded_sharded_single_device(rng):
    """iter_banded_sharded_ih parity on a 1-device mesh (the 8-device run
    lives in test_distributed.py's subprocess tests)."""
    import jax
    from repro.core.distributed import iter_banded_sharded_ih

    mesh = jax.make_mesh((1,), ("model",))
    img = _img(rng, 24, 16)
    full = integral_histogram(jnp.asarray(img), 8, backend="jnp")
    got = jnp.concatenate(
        [b.H for b in iter_banded_sharded_ih(img, 8, mesh, sharding="bin",
                                             band_h=7)],
        axis=-2)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(full))
    with pytest.raises(ValueError, match="unknown sharding"):
        list(iter_banded_sharded_ih(img, 8, mesh, sharding="rows"))
