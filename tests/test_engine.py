"""Plan/execute engine + HSource protocol correctness.

The acceptance bar (ISSUE 4): one ``HistogramEngine``/``plan()`` entry
point covers all four H representations — the parity grid below asserts
every plan-selected path is bit-exact against the monolithic jnp oracle
for dense, banded, spilled, and (single-device here; 8-device in
test_distributed.py) sharded H; ``plan.explain()`` is golden-snapshot
tested for the paper's 640x480/32-bin and 64 MB/128-bin scenarios; the
``banded_*`` analytics forks are deprecation shims over the unified
dispatch."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import distances
from repro.core.bands import iter_banded_ih
from repro.core.engine import (
    EngineResult,
    HistogramEngine,
    LikelihoodQuery,
    MultiScaleQuery,
    RegionQuery,
    SlidingWindowQuery,
    WorkloadSpec,
    plan,
)
from repro.core.hsource import BandedH, DenseH, ShardedH, as_hsource
from repro.core.integral_histogram import IntegralHistogram
from repro.core.pipeline import auto_batch_size
from repro.core.region_query import (
    banded_likelihood_map,
    banded_region_histogram,
    banded_sliding_window_histograms,
    likelihood_map,
    multi_scale_search,
    region_histogram,
    sliding_window_histograms,
)
from repro.kernels.ops import integral_histogram


def _img(rng, *shape):
    return rng.integers(0, 256, shape, dtype=np.uint8)


def _oracle(img, bins):
    """The monolithic jnp H — every planned path must match it bit-exactly."""
    return integral_histogram(jnp.asarray(img), bins, backend="jnp")


# ---------------------------------------------------------------------------
# planner decisions + parity grid: every selected path vs the oracle
# ---------------------------------------------------------------------------
# (h, w, bins, budget rows | None, batch, storage, expected representation)
GRID = [
    (37, 23, 8, None, 1, None, "dense"),
    (37, 23, 8, 6, 1, None, "banded"),           # 6-row bands, uneven tail
    (52, 40, 8, 52, 3, None, "dense"),           # budget fits in one band
    (52, 40, 8, 13, 1, "uint16", "spilled"),     # modular storage policy
    (40, 32, 6, 11, 2, None, "banded"),          # banded frame stack
    (30, 20, 4, None, 1, "uint32", "spilled"),   # spill without a budget
]


@pytest.mark.parametrize(
    "h, w, bins, budget_rows, batch, storage, expect", GRID
)
def test_plan_grid_parity(rng, h, w, bins, budget_rows, batch, storage,
                          expect):
    img = _img(rng, h, w) if batch == 1 else _img(rng, batch, h, w)
    budget = (
        None if budget_rows is None
        else 4 * (batch if batch > 1 else 1) * bins * w * budget_rows
    )
    eng = HistogramEngine(
        bins, backend="jnp", memory_budget_bytes=budget, storage=storage
    )
    full = _oracle(img, bins)
    rects = np.array([[0, 0, h - 1, w - 1], [3, 4, h // 2, w - 2],
                      [5, 5, 5, 5]])
    out = eng.run(img, [RegionQuery(rects), SlidingWindowQuery((9, 7), 4)])
    assert out.plan.representation == expect
    assert eng.last_plan is out.plan
    np.testing.assert_array_equal(
        np.asarray(out.results[0]), np.asarray(region_histogram(full, rects))
    )
    np.testing.assert_array_equal(
        np.asarray(out.results[1]),
        np.asarray(sliding_window_histograms(full, (9, 7), 4)),
    )


@pytest.mark.parametrize("axis, expect_kind", [("model", "bin"),
                                               ("data", "spatial")])
def test_plan_grid_parity_sharded_single_device(rng, axis, expect_kind):
    """The sharded representations on a 1-device mesh (the 8-device runs
    live in test_distributed.py's subprocess tests)."""
    mesh = jax.make_mesh((1,), (axis,))
    img = _img(rng, 24, 16)
    eng = HistogramEngine(8, backend="jnp", mesh=mesh)
    full = _oracle(img, 8)
    rects = np.array([[0, 0, 23, 15], [3, 2, 20, 10]])
    out = eng.run(img, [RegionQuery(rects)])
    assert out.plan.representation == "sharded"
    assert out.plan.sharding == expect_kind
    np.testing.assert_array_equal(
        np.asarray(out.results[0]), np.asarray(region_histogram(full, rects))
    )
    # banded + sharded: budget forces a band plan on top of the mesh
    eng_b = HistogramEngine(8, backend="jnp", mesh=mesh,
                            memory_budget_bytes=4 * 8 * 16 * 7)
    out_b = eng_b.run(img, [RegionQuery(rects),
                            SlidingWindowQuery((9, 7), 3)])
    assert out_b.plan.representation == "sharded"
    assert out_b.plan.band_plan is not None
    np.testing.assert_array_equal(
        np.asarray(out_b.results[0]), np.asarray(region_histogram(full, rects))
    )
    np.testing.assert_array_equal(
        np.asarray(out_b.results[1]),
        np.asarray(sliding_window_histograms(full, (9, 7), 3)),
    )


def test_multi_scale_and_likelihood_unified(rng):
    """likelihood_map / multi_scale_search through every representation:
    one rows() pass serves all scales of a banded search."""
    img = _img(rng, 48, 36)
    bins = 8
    full = _oracle(img, bins)
    target = region_histogram(full, np.array([10, 8, 29, 23]))
    windows = ((20, 16), (12, 10), (50, 50))     # last exceeds the frame
    want = multi_scale_search(full, target, windows, distances.intersection,
                              stride=4)
    for source in (
        DenseH(full),
        BandedH(lambda: iter_banded_ih(img, bins, band_h=13, backend="jnp")),
        HistogramEngine(bins, backend="jnp", storage="uint16").compute(img),
    ):
        got = multi_scale_search(source, target, windows,
                                 distances.intersection, stride=4)
        np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
        np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))
        for m_want, m_got in zip(want[2], got[2]):
            np.testing.assert_array_equal(
                np.asarray(m_got), np.asarray(m_want))
    lm_want = likelihood_map(full, target, (20, 16),
                             distances.intersection, 4)
    lm_got = likelihood_map(
        BandedH(lambda: iter_banded_ih(img, bins, band_h=13, backend="jnp")),
        target, (20, 16), distances.intersection, 4)
    np.testing.assert_array_equal(np.asarray(lm_got), np.asarray(lm_want))


# ---------------------------------------------------------------------------
# plan object: determinism, explain() golden snapshots, absorbed decisions
# ---------------------------------------------------------------------------
def test_plan_is_deterministic_and_inspectable():
    spec = WorkloadSpec(height=96, width=64, num_bins=8,
                        memory_budget_bytes=4 * 8 * 64 * 12, backend="jnp")
    p1, p2 = plan(spec), plan(spec)
    assert p1 == p2                      # frozen dataclasses: value equality
    assert p1.band_plan == p2.band_plan
    assert "banded" in p1.explain()


GOLDEN_640x480_32 = """\
ExecutionPlan
  workload        : 480x640 uint8 frames, 32 bins, 1 frame(s)/request
  full H          : 39321600 B/frame (37.5 MiB fp32)
  representation  : dense
  method/backend  : wf_tis / jnp
  tile/bin_block  : 128 / 8
  microbatch      : 1 frame(s)/dispatch
  bands           : none (no memory budget)
  storage         : device fp32
  sharding        : none"""

# The paper's §4.6 scale scenario: a 64 MB (8192x8192 uint8) frame at 128
# bins whose H is 32 GiB, planned under a 256 MiB budget.
GOLDEN_64MB_128 = """\
ExecutionPlan
  workload        : 8192x8192 uint8 frames, 128 bins, 1 frame(s)/request
  full H          : 34359738368 B/frame (32768.0 MiB fp32)
  representation  : banded
  method/backend  : wf_tis / jnp
  tile/bin_block  : 128 / 8
  microbatch      : 1 frame(s)/dispatch
  bands           : 128 x 64 rows (268435456 B/band <= 268435456 B budget)
  storage         : device fp32
  sharding        : none"""


def test_plan_explain_golden_paper_scenarios():
    p = plan(WorkloadSpec(height=480, width=640, num_bins=32, backend="jnp"))
    assert p.explain() == GOLDEN_640x480_32
    p = plan(WorkloadSpec(height=8192, width=8192, num_bins=128,
                          memory_budget_bytes=256 << 20, backend="jnp"))
    assert p.explain() == GOLDEN_64MB_128


def test_plan_absorbs_auto_batch_size():
    """The open-stream microbatch is exactly pipeline.auto_batch_size —
    map_frames' "auto" now asks the planner."""
    for h, w, bins in [(64, 64, 16), (480, 640, 32)]:
        p = plan(WorkloadSpec(height=h, width=w, num_bins=bins,
                              num_frames=None, backend="jnp"))
        assert p.microbatch == auto_batch_size(bins, h, w)
    # capped by the request arity
    p = plan(WorkloadSpec(height=64, width=64, num_bins=4, num_frames=2,
                          backend="jnp"))
    assert p.microbatch == 2


def test_plan_validation_errors():
    mesh = jax.make_mesh((1,), ("model",))
    with pytest.raises(ValueError, match="storage"):
        plan(WorkloadSpec(height=16, width=16, num_bins=4, mesh=mesh,
                          storage="uint16", backend="jnp"))
    with pytest.raises(ValueError, match="unknown sharding"):
        plan(WorkloadSpec(height=16, width=16, num_bins=4, mesh=mesh,
                          sharding="rows", backend="jnp"))
    with pytest.raises(ValueError, match="unknown backend"):
        plan(WorkloadSpec(height=16, width=16, num_bins=4, backend="cuda"))
    with pytest.raises(ValueError, match="no Pallas kernel"):
        plan(WorkloadSpec(height=16, width=16, num_bins=4, method="cw_b",
                          backend="pallas"))
    # spatial sharding is single-frame: a stack must be rejected, not
    # silently row-sharded along the frame axis
    with pytest.raises(ValueError, match="single-frame"):
        plan(WorkloadSpec(height=16, width=16, num_bins=4, num_frames=3,
                          mesh=mesh, sharding="spatial", backend="jnp"))


def test_dense_budget_caps_microbatch():
    """A budget that fits one frame but not the auto microbatch shrinks
    the dispatch instead of overrunning the budget."""
    # 64x64x4 bins: per-frame H = 64 KiB, auto microbatch would be 16
    per_frame = 4 * 4 * 64 * 64
    p = plan(WorkloadSpec(height=64, width=64, num_bins=4, num_frames=None,
                          memory_budget_bytes=3 * per_frame, backend="jnp"))
    assert p.representation == "dense"
    assert p.microbatch == 3


def test_engine_map_frames_rejects_non_dense_plans(rng):
    """map_frames streams dense H's: a plan the engine cannot honour on
    that path (banded/spilled/sharded) must raise, not silently ignore
    the configured budget/mesh/storage."""
    frames = _img(rng, 3, 32, 24)
    tiny = HistogramEngine(8, backend="jnp",
                           memory_budget_bytes=4 * 8 * 24 * 4)   # 4-row bands
    with pytest.raises(ValueError, match="banded"):
        list(tiny.map_frames(list(frames)))
    spilled = HistogramEngine(8, backend="jnp", storage="uint16")
    with pytest.raises(ValueError, match="spilled"):
        list(spilled.map_frames(list(frames)))


def test_multi_query_run_streams_bands_once(rng):
    """engine.run with k queries on a banded plan must not recompute the
    band stream k times: the row union is prefetched in ONE pass."""
    from repro.core.engine import prefetch_rows
    from repro.core.hsource import PrefetchedRowsH

    img = _img(rng, 52, 40)
    bins = 8
    full = _oracle(img, bins)
    rects = np.array([[0, 0, 51, 39], [5, 5, 30, 30]])
    target = region_histogram(full, rects[1])
    streams = {"n": 0}

    def counting_factory():
        streams["n"] += 1
        return iter_banded_ih(img, bins, band_h=13, backend="jnp")

    src = BandedH(counting_factory)
    queries = [
        RegionQuery(rects),
        SlidingWindowQuery((12, 8), 4),
        LikelihoodQuery(target, (12, 8), distances.intersection, 4),
        MultiScaleQuery(target, ((12, 8), (20, 16)), stride=4),
    ]
    pf = prefetch_rows(src, queries)
    assert isinstance(pf, PrefetchedRowsH)
    results = [q.apply(pf) for q in queries]
    assert streams["n"] == 1                  # one stream served everything
    np.testing.assert_array_equal(
        np.asarray(results[0]), np.asarray(region_histogram(full, rects)))
    np.testing.assert_array_equal(
        np.asarray(results[1]),
        np.asarray(sliding_window_histograms(full, (12, 8), 4)))
    np.testing.assert_array_equal(
        np.asarray(results[3][0]),
        np.asarray(multi_scale_search(full, target, ((12, 8), (20, 16)),
                                      distances.intersection, 4)[0]))
    with pytest.raises(KeyError, match="not prefetched"):
        pf.rows(np.array([2]))                # not in any query's union
    # the engine wires the same path: a 2-query banded run is bit-exact
    eng = HistogramEngine(bins, backend="jnp",
                          memory_budget_bytes=4 * bins * 40 * 13)
    out = eng.run(img, queries[:2])
    assert out.plan.representation == "banded"
    np.testing.assert_array_equal(
        np.asarray(out.results[0]), np.asarray(region_histogram(full, rects)))


def test_multi_scale_oversized_window_on_spilled(rng):
    """A scale larger than the frame is skipped (empty map) on a
    policy-bounded source, exactly like the dense path — it must not trip
    the storage bound check."""
    img = _img(rng, 48, 36)
    full = _oracle(img, 8)
    target = region_histogram(full, np.array([10, 8, 29, 23]))
    sp = HistogramEngine(8, backend="jnp", storage="uint16").compute(img)
    windows = ((20, 16), (400, 400))          # second: 160000 px > 65535
    want = multi_scale_search(full, target, windows,
                              distances.intersection, 4)
    got = multi_scale_search(sp, target, windows,
                             distances.intersection, 4)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    assert got[2][1].shape == want[2][1].shape == (0, 0)


def test_spatial_open_stream_plans_but_map_frames_rejects(rng):
    """num_frames=None (open stream) is frames one at a time, so a
    spatial plan is legal; map_frames still rejects it with its own
    'streams dense' error rather than the stack message."""
    mesh = jax.make_mesh((1,), ("data",))
    p = plan(WorkloadSpec(height=16, width=16, num_bins=4, num_frames=None,
                          mesh=mesh, sharding="spatial", backend="jnp"))
    assert p.representation == "sharded"
    eng = HistogramEngine(4, backend="jnp", mesh=mesh, sharding="spatial")
    with pytest.raises(ValueError, match="streams dense"):
        list(eng.map_frames([_img(rng, 16, 16)]))


def test_raw_path_fills_stats(rng):
    """The dense raw-array path populates the same stats keys as every
    HSource path (migrating callers keep reading stats['peak_bytes'])."""
    img = _img(rng, 40, 28)
    full = _oracle(img, 8)
    keys = {"num_bands", "band_bytes", "slab_bytes", "peak_bytes",
            "full_h_bytes"}
    stats_raw: dict = {}
    sliding_window_histograms(full, (9, 7), 3, stats=stats_raw)
    assert keys <= set(stats_raw) and stats_raw["num_bands"] == 1
    stats_dense: dict = {}
    DenseH(full).sliding_window_histograms((9, 7), 3, stats=stats_dense)
    assert stats_dense == stats_raw
    stats_banded: dict = {}
    sliding_window_histograms(
        BandedH(lambda: iter_banded_ih(img, 8, band_h=11, backend="jnp")),
        (9, 7), 3, stats=stats_banded)
    assert keys <= set(stats_banded) and stats_banded["num_bands"] == 4


# ---------------------------------------------------------------------------
# HSource protocol mechanics
# ---------------------------------------------------------------------------
def test_banded_single_shot_and_factory(rng):
    img = _img(rng, 26, 11)
    full = _oracle(img, 4)
    rects = np.array([[0, 0, 25, 10]])
    one_shot = BandedH(iter_banded_ih(img, 4, band_h=7, backend="jnp"))
    np.testing.assert_array_equal(
        np.asarray(one_shot.region_histogram(rects)),
        np.asarray(region_histogram(full, rects)))
    with pytest.raises(RuntimeError, match="factory"):
        one_shot.region_histogram(rects)
    # a factory replays: two queries, two streams
    fac = BandedH(lambda: iter_banded_ih(img, 4, band_h=7, backend="jnp"))
    for _ in range(2):
        np.testing.assert_array_equal(
            np.asarray(fac.region_histogram(rects)),
            np.asarray(region_histogram(full, rects)))


def test_as_hsource_coercions(rng):
    img = _img(rng, 16, 12)
    full = _oracle(img, 4)
    assert isinstance(as_hsource(full), DenseH)
    assert isinstance(
        as_hsource(iter_banded_ih(img, 4, band_h=5, backend="jnp")), BandedH)
    assert isinstance(
        as_hsource(lambda: iter_banded_ih(img, 4, band_h=5, backend="jnp")),
        BandedH)
    src = as_hsource(full)
    assert as_hsource(src) is src
    with pytest.raises(TypeError, match="cannot interpret"):
        as_hsource(42)
    with pytest.raises(ValueError, match="unknown sharding kind"):
        ShardedH(full, None, kind="rows")


def test_hsource_metadata_and_dense(rng):
    img = _img(rng, 2, 20, 14)
    full = _oracle(img, 4)
    src = BandedH(lambda: iter_banded_ih(img, 4, band_h=6, backend="jnp"))
    assert (src.num_bins, src.height, src.width, src.lead) == (4, 20, 14, (2,))
    np.testing.assert_array_equal(np.asarray(src.dense()), np.asarray(full))
    d = DenseH(full)
    assert (d.num_bins, d.height, d.width, d.lead) == (4, 20, 14, (2,))
    assert d.dense() is full


# ---------------------------------------------------------------------------
# deprecation shims (satellite 1)
# ---------------------------------------------------------------------------
def test_banded_shims_warn_and_forward(rng):
    img = _img(rng, 40, 28)
    bins = 8
    full = _oracle(img, bins)
    rects = np.array([[0, 0, 39, 27], [5, 5, 20, 20]])
    target = region_histogram(full, rects[1])

    def bands():
        return iter_banded_ih(img, bins, band_h=11, backend="jnp")

    with pytest.warns(DeprecationWarning, match="banded_region_histogram"):
        got = banded_region_histogram(bands(), rects)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(region_histogram(full, rects)))

    with pytest.warns(DeprecationWarning,
                      match="banded_sliding_window_histograms"):
        got = banded_sliding_window_histograms(bands(), (9, 7), 3)
    np.testing.assert_array_equal(
        np.asarray(got),
        np.asarray(sliding_window_histograms(full, (9, 7), 3)))

    with pytest.warns(DeprecationWarning, match="banded_likelihood_map"):
        got = banded_likelihood_map(bands(), target, (9, 7),
                                    distances.intersection, 3)
    np.testing.assert_array_equal(
        np.asarray(got),
        np.asarray(likelihood_map(full, target, (9, 7),
                                  distances.intersection, 3)))


# ---------------------------------------------------------------------------
# engine facade
# ---------------------------------------------------------------------------
def test_engine_run_result_shape(rng):
    img = _img(rng, 32, 24)
    eng = HistogramEngine(8, backend="jnp")
    out = eng.run(img)
    assert isinstance(out, EngineResult) and out.results == []
    full = _oracle(img, 8)
    target = region_histogram(full, np.array([4, 4, 19, 15]))
    out = eng.run(img, [
        RegionQuery(np.array([[0, 0, 31, 23]])),
        LikelihoodQuery(target, (16, 12), stride=4),
        MultiScaleQuery(target, ((16, 12), (8, 6)), stride=4),
    ])
    assert len(out.results) == 3
    want = multi_scale_search(full, target, ((16, 12), (8, 6)),
                              distances.intersection, 4)
    np.testing.assert_array_equal(
        np.asarray(out.results[2][0]), np.asarray(want[0]))


def test_engine_map_frames_matches_legacy(rng):
    frames = _img(rng, 5, 24, 20)
    ih = IntegralHistogram(num_bins=8, backend="jnp")
    eng = ih.engine()
    got = [np.asarray(H) for H in eng.map_frames(list(frames))]
    want = [np.asarray(H) for H in ih.map_frames(list(frames),
                                                 batch_size="auto")]
    assert eng.last_plan.microbatch == auto_batch_size(8, 24, 20)
    assert len(got) == len(want) == 5
    for g, w_ in zip(got, want):
        np.testing.assert_array_equal(g, w_)
    assert list(eng.map_frames(iter(()))) == []


def test_integral_histogram_engine_helper():
    ih = IntegralHistogram(num_bins=16, method="cw_sts", backend="jnp",
                           tile=64)
    eng = ih.engine(memory_budget_bytes=1 << 20)
    assert (eng.num_bins, eng.method, eng.backend, eng.tile) == (
        16, "cw_sts", "jnp", 64)
    assert eng.memory_budget_bytes == 1 << 20


def test_tracker_rides_the_engine(rng):
    """FragmentTracker accepts an engine for its H computation and an
    HSource in step_on_h — same boxes as the hand-routed path."""
    from repro.core.tracking import FragmentTracker, TrackerConfig

    frames = _img(rng, 4, 40, 32)
    cfg = TrackerConfig(num_bins=8, search_radius=4, backend="jnp")
    bbox = np.array([10, 8, 25, 23])
    legacy = FragmentTracker(cfg)
    st_l = legacy.init(jnp.asarray(frames[0]), bbox)
    eng = HistogramEngine(8, backend="jnp")
    routed = FragmentTracker(cfg, engine=eng)
    st_r = routed.init(jnp.asarray(frames[0]), bbox)
    for f in frames[1:]:
        st_l = legacy.step(st_l, jnp.asarray(f))
        st_r = routed.step_on_h(st_r, DenseH(eng.compute_dense(jnp.asarray(f))))
        np.testing.assert_array_equal(
            np.asarray(st_l["bbox"]), np.asarray(st_r["bbox"]))
    with pytest.raises(ValueError, match="num_bins"):
        FragmentTracker(cfg, engine=HistogramEngine(4, backend="jnp"))


# ---------------------------------------------------------------------------
# mesh layout (replica x shard serving layout)
# ---------------------------------------------------------------------------
def test_plan_mesh_layout_rendered_and_validated(rng):
    """Sharded plans carry the 2-D replica x shard MeshLayout: explain()
    renders it, plancheck validates it, non-mesh plans never grow one
    (the golden snapshots above pin the absence)."""
    import dataclasses

    from repro.analysis import plancheck
    from repro.core.engine import MeshLayout, choose_layout

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    p = plan(WorkloadSpec(height=24, width=16, num_bins=8, num_frames=1,
                          backend="jnp", mesh=mesh))
    assert p.sharding == "bin"
    lay = p.layout
    assert isinstance(lay, MeshLayout)
    assert lay.kind == "bin" and lay.shard_axis == "model"
    assert lay.replica_axes == ("data",)
    assert lay.num_groups * lay.shards_per_group == 1
    text = p.explain()
    assert "mesh layout     : " in text
    assert "replica group(s) over 'data'" in text
    assert "bin sharding over 'model'" in text
    verdict = plancheck.check_plan(p)
    assert verdict.ok
    assert any(c.name == "mesh-layout" and c.status == "ok"
               for c in verdict.checks)
    # spatial flips the axes: 'data' shards rows, 'model' replicates
    sp = plan(WorkloadSpec(height=24, width=16, num_bins=7, num_frames=1,
                           backend="jnp", mesh=mesh, sharding="spatial"))
    assert sp.sharding == "spatial"
    assert sp.layout.shard_axis == "data"
    assert sp.layout.replica_axes == ("model",)
    # non-mesh plans carry no layout
    assert plan(WorkloadSpec(height=24, width=16, num_bins=8,
                             backend="jnp")).layout is None
    # a corrupted layout fails the check loudly
    bad = dataclasses.replace(
        p, layout=choose_layout(mesh, "bin", bin_axis="nope"))
    v_bad = plancheck.check_plan(bad)
    assert not v_bad.ok
    assert any(c.name == "mesh-layout" and c.status == "fail"
               for c in v_bad.checks)
