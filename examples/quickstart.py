"""Quickstart: integral histogram -> O(1) region queries -> search.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import distances
from repro.core.integral_histogram import IntegralHistogram
from repro.data import video_frames


def main():
    # one synthetic 480p frame
    frame = jnp.asarray(video_frames(480, 640, 1, seed=7)[0])

    # 1. the paper's data structure: H(b, x, y), here via the WF-TiS method
    ih = IntegralHistogram(num_bins=32, method="wf_tis", backend="auto")
    H = ih(frame)
    print(f"integral histogram: {H.shape}  ({H.nbytes/2**20:.1f} MiB)")

    # 2. O(1) region histogram (paper Eq. 2) — any rectangle, constant time
    hist = ih.query(H, jnp.array([100, 150, 199, 279]))
    print(f"region [100:200, 150:280] histogram sum = {float(hist.sum())} "
          f"(area = {100*130})")

    # 3. constant-time exhaustive search: find the window most similar to a
    #    template histogram at every stride-8 position
    target = ih.query(H, jnp.array([200, 300, 263, 363]))     # 64x64 patch
    rect, score, _ = ih.multi_scale_search(
        H, target, windows=((64, 64), (80, 80)),
        metric=distances.intersection, stride=8)
    print(f"best match rect={np.asarray(rect)} score={float(score):.3f}")


if __name__ == "__main__":
    main()
