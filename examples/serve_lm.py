"""Serving example: batched prefill + greedy decode over the family API.

    PYTHONPATH=src python examples/serve_lm.py --arch qwen2-1.5b
    PYTHONPATH=src python examples/serve_lm.py --arch mamba2-130m --gen 32

Uses the reduced (smoke) configs so it runs on CPU; the identical code
path is what launch/dryrun.py lowers for the full configs at 256/512
chips (prefill_32k / decode_32k / long_500k cells).
"""

import sys

from repro.launch import serve


def main():
    argv = sys.argv[1:]
    if "--smoke" not in argv:
        argv.append("--smoke")
    serve.main(argv)


if __name__ == "__main__":
    main()
