"""End-to-end real-time video analytics driver (the paper's use case).

Pipeline per frame (all on-accelerator once the frame is staged):
  1. WF-TiS integral histogram (double-buffered across frames, paper §4.4)
  2. fragments-based tracker update (paper ref. [13]) — O(1) histogram
     queries for every candidate window
  3. likelihood map for the tracked target (abstract: "feature likelihood
     maps ... play a critical role")

    PYTHONPATH=src python examples/video_analytics.py [--frames 40]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distances
from repro.core.pipeline import DoubleBufferedExecutor
from repro.core.region_query import likelihood_map, region_histogram
from repro.core.tracking import FragmentTracker, TrackerConfig
from repro.data import video_frames
from repro.kernels.ops import integral_histogram


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=40)
    ap.add_argument("--hw", type=int, nargs=2, default=(480, 640))
    ap.add_argument("--bins", type=int, default=16)
    args = ap.parse_args(argv)
    h, w = args.hw

    frames = video_frames(h, w, args.frames, seed=3)
    print(f"{args.frames} frames of {h}x{w}, {args.bins} bins")

    # --- stage 1: double-buffered integral histograms over the stream ----
    ih_fn = jax.jit(lambda f: integral_histogram(
        f, args.bins, method="wf_tis", backend="auto"))
    executor = DoubleBufferedExecutor(ih_fn, depth=2)

    # --- stage 2+3: tracker + likelihood map consume H ------------------
    tracker = FragmentTracker(TrackerConfig(num_bins=args.bins,
                                            search_radius=10))
    state = tracker.init(jnp.asarray(frames[0]), [h // 3, w // 3,
                                                  h // 3 + 47, w // 3 + 47])
    target_hist = region_histogram(
        ih_fn(jnp.asarray(frames[0])), state["bbox"])

    t0 = time.perf_counter()
    boxes = []
    for i, H in enumerate(executor.map(frames)):
        state = tracker.step(state, jnp.asarray(frames[i]))
        boxes.append(np.asarray(state["bbox"]))
        if i == args.frames - 1:
            lmap = likelihood_map(H, target_hist, (48, 48),
                                  distances.intersection, stride=16)
    dt = time.perf_counter() - t0
    jax.block_until_ready(lmap)

    print(f"pipeline: {args.frames/dt:.2f} frames/sec "
          f"({dt/args.frames*1e3:.1f} ms/frame) on {jax.devices()[0]}")
    print(f"track: start {boxes[0][:2]} -> end {boxes[-1][:2]}")
    print(f"likelihood map {lmap.shape}, peak={float(lmap.max()):.3f} at "
          f"{np.unravel_index(int(jnp.argmax(lmap)), lmap.shape)}")


if __name__ == "__main__":
    main()
