"""End-to-end real-time video analytics driver (the paper's use case).

Pipeline per frame (all on-accelerator once the frame is staged):
  1. WF-TiS integral histogram, streamed through the batched frame path —
     `IntegralHistogram.map_frames` microbatches frames per dispatch and
     keeps dispatches in flight (paper §4.4 dual-buffering + the
     frame-batch axis of arXiv:1011.0235)
  2. multi-target fragments tracker update (paper ref. [13]) consuming
     the streamed H via `step_on_h` — the frame's integral histogram is
     computed ONCE and shared by every target's O(1) candidate queries
  3. batched likelihood maps (abstract: "feature likelihood maps ... play
     a critical role"): the last `--map-frames` H's are stacked and ONE
     rank-polymorphic `likelihood_map` call scores every window of every
     frame
  4. the large-frame regime (paper §4.6): a frame `--large-scale`x the
     stream size is scored under a memory budget an eighth of its full H
     footprint — row bands stream through the carry-aware kernels
     (core/bands.py) and the likelihood map is exact without the
     (b, h, w) H ever existing

For offline clips, `FragmentTracker.track` runs the same math as one
batched-H + `lax.scan` loop per chunk (see benchmarks/bench_analytics.py
for the frames/sec delta vs the per-frame loop).

    PYTHONPATH=src python examples/video_analytics.py [--frames 40]
                   [--batch auto|N] [--targets 2] [--large-scale 2]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distances
from repro.core.integral_histogram import IntegralHistogram
from repro.core.region_query import likelihood_map, region_histogram
from repro.core.tracking import FragmentTracker, TrackerConfig
from repro.data import video_frames


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=40)
    ap.add_argument("--hw", type=int, nargs=2, default=(480, 640))
    ap.add_argument("--bins", type=int, default=16)
    ap.add_argument("--batch", default="auto",
                    help='frames per dispatch: "auto" or an int')
    ap.add_argument("--depth", type=int, default=2,
                    help="dispatches kept in flight (1 = synchronous)")
    ap.add_argument("--targets", type=int, default=2,
                    help="simultaneously tracked targets")
    ap.add_argument("--map-frames", type=int, default=4,
                    help="trailing frames scored by one batched "
                         "likelihood_map call")
    ap.add_argument("--large-scale", type=int, default=2,
                    help="stage-4 frame is this multiple of --hw "
                         "(0 skips the banded large-frame demo)")
    args = ap.parse_args(argv)
    h, w = args.hw
    batch = args.batch if args.batch == "auto" else int(args.batch)

    frames = video_frames(h, w, args.frames, seed=3)
    print(f"{args.frames} frames of {h}x{w}, {args.bins} bins, "
          f"batch={batch}, depth={args.depth}, targets={args.targets}")

    # --- stage 1: batched + double-buffered integral histograms ----------
    ih = IntegralHistogram(num_bins=args.bins, method="wf_tis",
                           backend="auto")

    # --- stage 2: multi-target tracker rides the streamed H --------------
    tracker = FragmentTracker(TrackerConfig(num_bins=args.bins,
                                            search_radius=10))
    size = 48
    bboxes = np.stack([
        [r, c, r + size - 1, c + size - 1]
        for r, c in zip(
            np.linspace(h // 4, 3 * h // 4 - size, args.targets).astype(int),
            np.linspace(w // 4, 3 * w // 4 - size, args.targets).astype(int))
    ])
    state = tracker.init(jnp.asarray(frames[0]), bboxes)
    target_hists = region_histogram(ih(jnp.asarray(frames[0])),
                                    state["bbox"])          # (t, bins)

    t0 = time.perf_counter()
    boxes, tail_H = [], []
    for H in ih.map_frames(frames, batch_size=batch, depth=args.depth):
        state = tracker.step_on_h(state, H)     # H shared across targets
        boxes.append(np.asarray(state["bbox"]))
        tail_H.append(H)
        if len(tail_H) > args.map_frames:
            tail_H.pop(0)
    dt = time.perf_counter() - t0

    # --- stage 3: one batched likelihood_map over the trailing frames ----
    Hs = jnp.stack(tail_H)                      # (k, bins, h, w)
    lmap = likelihood_map(Hs, target_hists[0], (size, size),
                          distances.intersection, stride=16)
    jax.block_until_ready(lmap)

    print(f"pipeline: {args.frames/dt:.2f} frames/sec "
          f"({dt/args.frames*1e3:.1f} ms/frame) on {jax.devices()[0]}")
    for t in range(args.targets):
        print(f"track[{t}]: start {boxes[0][t][:2]} -> end {boxes[-1][t][:2]}")
    peak = tuple(
        int(i) for i in np.unravel_index(int(jnp.argmax(lmap[-1])),
                                         lmap.shape[1:]))
    print(f"likelihood maps {lmap.shape} (batched over {lmap.shape[0]} "
          f"frames), last-frame peak={float(lmap[-1].max()):.3f} at {peak}")

    # --- stage 4: band-streamed large frame under a memory budget --------
    if args.large_scale:
        big_h, big_w = h * args.large_scale, w * args.large_scale
        big = np.tile(frames[-1], (args.large_scale, args.large_scale))
        full_bytes = 4 * args.bins * big_h * big_w
        budget = full_bytes // 8
        stats = {}
        t0 = time.perf_counter()
        blmap = ih.banded_likelihood_map(
            ih.map_bands(big, memory_budget_bytes=budget),
            target_hists[0], (size, size), distances.intersection,
            stride=16, stats=stats)
        jax.block_until_ready(blmap)
        dt = time.perf_counter() - t0
        print(f"banded {big_h}x{big_w}: budget {budget / 2**20:.0f} MB "
              f"(full H {full_bytes / 2**20:.0f} MB), "
              f"{stats['num_bands']} bands, peak proxy "
              f"{stats['peak_bytes'] / 2**20:.0f} MB, "
              f"map {tuple(blmap.shape)} in {dt:.2f}s")


if __name__ == "__main__":
    main()
