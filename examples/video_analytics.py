"""End-to-end real-time video analytics driver (the paper's use case).

Pipeline per frame (all on-accelerator once the frame is staged):
  1. WF-TiS integral histogram, streamed through the batched frame path —
     `IntegralHistogram.map_frames` microbatches frames per dispatch and
     keeps dispatches in flight (paper §4.4 dual-buffering + the
     frame-batch axis of arXiv:1011.0235)
  2. fragments-based tracker update (paper ref. [13]) — O(1) histogram
     queries for every candidate window
  3. likelihood map for the tracked target (abstract: "feature likelihood
     maps ... play a critical role")

    PYTHONPATH=src python examples/video_analytics.py [--frames 40]
                   [--batch auto|N]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distances
from repro.core.integral_histogram import IntegralHistogram
from repro.core.region_query import likelihood_map, region_histogram
from repro.core.tracking import FragmentTracker, TrackerConfig
from repro.data import video_frames


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=40)
    ap.add_argument("--hw", type=int, nargs=2, default=(480, 640))
    ap.add_argument("--bins", type=int, default=16)
    ap.add_argument("--batch", default="auto",
                    help='frames per dispatch: "auto" or an int')
    ap.add_argument("--depth", type=int, default=2,
                    help="dispatches kept in flight (1 = synchronous)")
    args = ap.parse_args(argv)
    h, w = args.hw
    batch = args.batch if args.batch == "auto" else int(args.batch)

    frames = video_frames(h, w, args.frames, seed=3)
    print(f"{args.frames} frames of {h}x{w}, {args.bins} bins, "
          f"batch={batch}, depth={args.depth}")

    # --- stage 1: batched + double-buffered integral histograms ----------
    ih = IntegralHistogram(num_bins=args.bins, method="wf_tis",
                           backend="auto")

    # --- stage 2+3: tracker + likelihood map consume H ------------------
    tracker = FragmentTracker(TrackerConfig(num_bins=args.bins,
                                            search_radius=10))
    state = tracker.init(jnp.asarray(frames[0]), [h // 3, w // 3,
                                                  h // 3 + 47, w // 3 + 47])
    target_hist = region_histogram(ih(jnp.asarray(frames[0])), state["bbox"])

    t0 = time.perf_counter()
    boxes = []
    stream = ih.map_frames(frames, batch_size=batch, depth=args.depth)
    for i, H in enumerate(stream):
        state = tracker.step(state, jnp.asarray(frames[i]))
        boxes.append(np.asarray(state["bbox"]))
        if i == args.frames - 1:
            lmap = likelihood_map(H, target_hist, (48, 48),
                                  distances.intersection, stride=16)
    dt = time.perf_counter() - t0
    jax.block_until_ready(lmap)

    print(f"pipeline: {args.frames/dt:.2f} frames/sec "
          f"({dt/args.frames*1e3:.1f} ms/frame) on {jax.devices()[0]}")
    print(f"track: start {boxes[0][:2]} -> end {boxes[-1][:2]}")
    print(f"likelihood map {lmap.shape}, peak={float(lmap.max()):.3f} at "
          f"{np.unravel_index(int(jnp.argmax(lmap)), lmap.shape)}")


if __name__ == "__main__":
    main()
