"""End-to-end real-time video analytics driver (the paper's use case),
driven through the plan/execute engine (core/engine.py).

Pipeline per frame (all on-accelerator once the frame is staged):
  1. WF-TiS integral histograms streamed by `HistogramEngine.map_frames`
     — the planner sizes the microbatch (arXiv:1011.0235 adaptive
     batching) and keeps dispatches in flight (paper §4.4 dual-buffering)
  2. multi-target fragments tracker update (paper ref. [13]) riding the
     same engine via `step_on_h` — the frame's integral histogram is
     computed ONCE and shared by every target's O(1) candidate queries
  3. batched likelihood maps (abstract: "feature likelihood maps ... play
     a critical role"): the last `--map-frames` H's are stacked and ONE
     rank-polymorphic `likelihood_map` call scores every window of every
     frame
  4. the large-frame regime (paper §4.6): a frame `--large-scale`x the
     stream size is scored under a memory budget an eighth of its full H
     footprint.  A second engine plans it — `plan.explain()` shows the
     banded representation it picked — and the exact likelihood map is
     computed without the (b, h, w) H ever existing.
  5. serving (`repro/serve`): an `AnalyticsService` over the same engine
     answers a burst of concurrent `(frame, query)` requests — same-frame
     queries coalesce into one engine run, hot frames answer from the
     HSource LRU cache, and the stats line shows the requests/sec the
     front-end adds on top of raw engine throughput.

Every stage goes through ONE entry point (`engine.run` / `map_frames`);
the dense / banded / spilled / sharded representation behind a request
is the planner's choice, not hand-routed (the pre-engine forks survive
as deprecation shims; see README "Migration").

    PYTHONPATH=src python examples/video_analytics.py [--frames 40]
                   [--batch auto|N] [--targets 2] [--large-scale 2]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distances
from repro.core.engine import HistogramEngine, LikelihoodQuery
from repro.core.region_query import likelihood_map, region_histogram
from repro.core.tracking import FragmentTracker, TrackerConfig
from repro.data import video_frames


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=40)
    ap.add_argument("--hw", type=int, nargs=2, default=(480, 640))
    ap.add_argument("--bins", type=int, default=16)
    ap.add_argument("--batch", default="auto",
                    help='frames per dispatch: "auto" (planner) or an int')
    ap.add_argument("--depth", type=int, default=2,
                    help="dispatches kept in flight (1 = synchronous)")
    ap.add_argument("--targets", type=int, default=2,
                    help="simultaneously tracked targets")
    ap.add_argument("--map-frames", type=int, default=4,
                    help="trailing frames scored by one batched "
                         "likelihood_map call")
    ap.add_argument("--large-scale", type=int, default=2,
                    help="stage-4 frame is this multiple of --hw "
                         "(0 skips the banded large-frame demo)")
    ap.add_argument("--serve-requests", type=int, default=60,
                    help="stage-5 query burst against the AnalyticsService "
                         "(0 skips the serving demo)")
    args = ap.parse_args(argv)
    h, w = args.hw

    frames = video_frames(h, w, args.frames, seed=3)
    print(f"{args.frames} frames of {h}x{w}, {args.bins} bins, "
          f"batch={args.batch}, depth={args.depth}, "
          f"targets={args.targets}")

    # --- stage 1: one engine plans + streams the integral histograms ------
    engine = HistogramEngine(args.bins, method="wf_tis", backend="auto")

    # --- stage 2: multi-target tracker rides the same engine ---------------
    tracker = FragmentTracker(
        TrackerConfig(num_bins=args.bins, search_radius=10), engine=engine)
    size = 48
    bboxes = np.stack([
        [r, c, r + size - 1, c + size - 1]
        for r, c in zip(
            np.linspace(h // 4, 3 * h // 4 - size, args.targets).astype(int),
            np.linspace(w // 4, 3 * w // 4 - size, args.targets).astype(int))
    ])
    state = tracker.init(jnp.asarray(frames[0]), bboxes)
    target_hists = region_histogram(
        engine.compute_dense(jnp.asarray(frames[0])), state["bbox"])

    t0 = time.perf_counter()
    boxes, tail_H = [], []
    if args.batch == "auto":
        stream = engine.map_frames(frames, depth=args.depth)
    else:
        # explicit microbatch: bypass the planner's choice for comparison
        # (map_frames is eager — it plans off the first frame — so only
        # ONE of the two streams may ever be constructed)
        from repro.core.integral_histogram import IntegralHistogram

        stream = IntegralHistogram(
            num_bins=args.bins, method="wf_tis", backend="auto"
        ).map_frames(frames, batch_size=int(args.batch), depth=args.depth)
    for H in stream:
        state = tracker.step_on_h(state, H)     # H shared across targets
        boxes.append(np.asarray(state["bbox"]))
        tail_H.append(H)
        if len(tail_H) > args.map_frames:
            tail_H.pop(0)
    dt = time.perf_counter() - t0
    if args.batch == "auto" and engine.last_plan is not None:
        print(f"planned microbatch: {engine.last_plan.microbatch} "
              f"frame(s)/dispatch ({engine.last_plan.representation})")

    # --- stage 3: one batched likelihood_map over the trailing frames ----
    Hs = jnp.stack(tail_H)                      # (k, bins, h, w)
    lmap = likelihood_map(Hs, target_hists[0], (size, size),
                          distances.intersection, stride=16)
    jax.block_until_ready(lmap)

    print(f"pipeline: {args.frames/dt:.2f} frames/sec "
          f"({dt/args.frames*1e3:.1f} ms/frame) on {jax.devices()[0]}")
    for t in range(args.targets):
        print(f"track[{t}]: start {boxes[0][t][:2]} -> end {boxes[-1][t][:2]}")
    peak = tuple(
        int(i) for i in np.unravel_index(int(jnp.argmax(lmap[-1])),
                                         lmap.shape[1:]))
    print(f"likelihood maps {lmap.shape} (batched over {lmap.shape[0]} "
          f"frames), last-frame peak={float(lmap[-1].max()):.3f} at {peak}")

    # --- stage 4: the large-frame regime, planned under a budget ----------
    if args.large_scale:
        big_h, big_w = h * args.large_scale, w * args.large_scale
        big = np.tile(frames[-1], (args.large_scale, args.large_scale))
        full_bytes = 4 * args.bins * big_h * big_w
        budget = full_bytes // 8
        big_engine = HistogramEngine(args.bins, method="wf_tis",
                                     backend="auto",
                                     memory_budget_bytes=budget)
        t0 = time.perf_counter()
        out = big_engine.run(big, [LikelihoodQuery(
            target_hists[0], (size, size), distances.intersection,
            stride=16)])
        blmap = jax.block_until_ready(out.results[0])
        dt = time.perf_counter() - t0
        print(f"\nlarge-frame plan ({big_h}x{big_w}, budget "
              f"{budget / 2**20:.0f} MB vs full H "
              f"{full_bytes / 2**20:.0f} MB):")
        print(out.plan.explain())
        print(f"banded likelihood map {tuple(blmap.shape)} in {dt:.2f}s — "
              "full H never materialized")

    # --- stage 5: serving front-end over the engine -----------------------
    if args.serve_requests:
        from repro.core.engine import RegionQuery
        from repro.serve import AnalyticsService

        store = {i: f for i, f in enumerate(frames)}
        svc = AnalyticsService(engine, store, cache_size=8,
                               max_pending=args.serve_requests)
        rng = np.random.default_rng(11)
        burst = []
        hot = min(4, args.frames)
        for i in range(args.serve_requests):
            # hot-set traffic: most queries land on the newest `hot` frames
            ref = (args.frames - 1 - int(rng.integers(0, hot))
                   if rng.random() < 0.8
                   else int(rng.integers(0, args.frames)))
            if i % 2:
                burst.append((ref, RegionQuery(state["bbox"])))
            else:
                burst.append((ref, LikelihoodQuery(
                    target_hists[0], (size, size), distances.intersection,
                    stride=32)))
        t0 = time.perf_counter()
        with svc:
            # two waves: the first computes (coalescing same-frame
            # queries), the second mostly answers from the HSource cache
            half = len(burst) // 2
            for wave in (burst[:half], burst[half:]):
                futs = [svc.submit(ref, q, block=True) for ref, q in wave]
                for f in futs:
                    f.result()
        dt = time.perf_counter() - t0
        s = svc.stats.snapshot()
        print(f"\nserving: {len(burst)} concurrent requests in {dt:.2f}s "
              f"({len(burst) / dt:.1f} req/s)")
        print(f"  engine runs {s['engine_runs']} "
              f"(coalesced {s['coalesced']}, "
              f"cache hit rate {100 * s['cache_hit_rate']:.0f}%), "
              f"p95 latency {1e3 * s['latency_p95_s']:.1f} ms")


if __name__ == "__main__":
    main()
