"""End-to-end LM training driver: a ~100M-param dense model through the
full substrate — seekable data, AdamW, checkpointing, fault injection.

    PYTHONPATH=src python examples/train_lm.py --steps 40
    PYTHONPATH=src python examples/train_lm.py --steps 300 --d-model 640 \
        --layers 10       # the full ~100M run (CPU: ~lunch break)

The default config is a 8-layer / d=512 (~64M with embeddings) member of
the llama family; --d-model 640 --layers 10 reaches ~100M.

NOTE: this driver (and the repro.{configs,models,train,launch} packages
it exercises) is untouched seed substrate, unrelated to the
integral-histogram paper this repo reproduces — see docs/module-map.md.
It is kept runnable as a substrate smoke test; there are no "assigned
full configs" or production meshes behind it.
"""

import argparse
import dataclasses
import tempfile

import jax

from repro.config import ModelConfig
from repro.data import make_stream
from repro.train import (
    CheckpointManager, FaultInjector, init_state, make_optimizer,
    make_train_step, run_training,
)


def small_lm(d_model: int, layers: int) -> ModelConfig:
    return ModelConfig(
        name=f"demo-{d_model}x{layers}",
        family="dense",
        num_layers=layers,
        d_model=d_model,
        num_heads=8,
        num_kv_heads=4,
        head_dim=d_model // 8,
        d_ff=4 * d_model,
        vocab_size=32768,
        tie_embeddings=True,
        remat="none",
        flash_min_seq=1 << 30,
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--fail-at", type=int, nargs="*", default=())
    args = ap.parse_args(argv)

    cfg = small_lm(args.d_model, args.layers)
    n = cfg.param_count()
    print(f"model: {cfg.name}  ~{n/1e6:.0f}M params")

    opt = make_optimizer(cfg, peak_lr=args.lr,
                         warmup=max(args.steps // 10, 5),
                         total_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, opt), donate_argnums=(0,))
    stream = make_stream(cfg, args.batch, args.seq, seed=0)

    with tempfile.TemporaryDirectory() as ckpt_dir:
        state, history = run_training(
            init_state_fn=lambda: init_state(jax.random.PRNGKey(0), cfg, opt),
            train_step=step_fn,
            stream=stream,
            ckpt=CheckpointManager(ckpt_dir, keep_last=2),
            num_steps=args.steps,
            ckpt_every=max(args.steps // 4, 10),
            injector=(FaultInjector(tuple(args.fail_at))
                      if args.fail_at else None),
            log_every=max(args.steps // 10, 1),
        )
    first, last = history[0], history[-1]
    print(f"steps {first['step']}..{last['step']}: "
          f"loss {first['loss']:.3f} -> {last['loss']:.3f} "
          f"({last['dt']*1e3:.0f} ms/step at the end)")
    k = max(len(history) // 4, 1)
    early = sum(h["loss"] for h in history[:k]) / k
    late = sum(h["loss"] for h in history[-k:]) / k
    assert late < early, f"loss must trend down ({early:.3f} -> {late:.3f})"
    print("training loss decreased; checkpoint/restart exercised" +
          (" with injected failures" if args.fail_at else ""))


if __name__ == "__main__":
    main()
