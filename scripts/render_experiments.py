"""Render §Dry-run / §Roofline sections of EXPERIMENTS.md from
results/dryrun/*.json (and §Perf variant tables from results/perf/).

Usage: PYTHONPATH=src python scripts/render_experiments.py
"""

from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, "src")
from repro.configs import get_config  # noqa: E402


def load(d):
    out = []
    for p in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(p) as f:
            out.append(json.load(f))
    return out


def note(r) -> str:
    """One sentence: what would move the dominant term down."""
    cfg = get_config(r["arch"])
    dom, kind = r["terms"]["dominant"], r["kind"]
    if r["arch"].startswith("mamba2"):
        return ("model axis idle (24 heads !% 16); sequence-parallel SSD "
                "scan spreads the chunk scan over it (Perf C)")
    if kind == "decode" and dom == "collective":
        return ("KV cache heads/head_dim-sharded forces per-step cache "
                "all-gathers; seq-sharded flash-decode layout removes "
                "them (Perf B)")
    if kind == "train" and dom == "memory":
        return ("remat=full re-reads every layer's weights+activations in "
                "the bwd pass; dots policy / microbatching cut HLO bytes "
                "and live memory (Perf A)")
    if kind == "prefill" and dom == "collective":
        return ("TP all-reduce of (B,S,d) activations twice per layer; "
                "1D seq-sharding between TP regions (RS+AG) halves live "
                "bytes and enables overlap")
    if kind == "prefill" and dom == "memory":
        return ("bf16 weight copies + attention intermediates; fusing "
                "cast into the gathers and flash-block retuning")
    if dom == "memory" and kind == "decode":
        return "cache/state streaming bound — expected for decode"
    return "balanced; overlap compute/comm via latency-hiding scheduler"


def table(results, mesh):
    hdr = ("| arch | shape | status | bound | compute ms | memory ms | "
           "collective ms | roofline frac | 6ND/HLO | GiB/dev | "
           "what moves the dominant term |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|")
    rows = []
    for r in results:
        if r.get("mesh") != mesh:
            continue
        if r.get("status") == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | skipped | - | - | "
                        f"- | - | - | - | - | {r['reason'][:60]}... |")
            continue
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR | - | - | - "
                        f"| - | - | - | - | {r.get('error', '')[:60]} |")
            continue
        t = r["terms"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | ok | {t['dominant']} "
            f"| {t['compute_s']*1e3:.2f} | {t['memory_s']*1e3:.2f} "
            f"| {t['collective_s']*1e3:.2f} "
            f"| {t['roofline_fraction']:.3f} "
            f"| {r['useful_flops_ratio']:.2f} "
            f"| {r['memory']['per_device_total_gb']:.2f} "
            f"| {note(r)} |")
    return "\n".join([hdr] + rows)


def dryrun_summary(results):
    ok = [r for r in results if r["status"] == "ok"]
    sk = [r for r in results if r["status"] == "skipped"]
    er = [r for r in results if r["status"] == "error"]
    comp = [r["compile_s"] for r in ok]
    fits = [r for r in ok if r["mesh"] == "pod"
            and r["memory"]["per_device_total_gb"] <= 16.0]
    lines = [
        f"**Result: {len(ok)} cells compiled OK, {len(sk)} skipped "
        f"(assignment rules), {len(er)} errors** — every runnable "
        f"(arch x shape) lowers and compiles on both meshes.",
        "",
        f"- compile time: median "
        f"{sorted(comp)[len(comp)//2]:.1f}s, max {max(comp):.1f}s per cell",
        f"- {len(fits)}/{sum(1 for r in ok if r['mesh']=='pod')} single-pod "
        "cells fit 16 GiB/chip as-baselined; the big train cells "
        "(kimi/scout/llama3 train_4k) exceed it with remat=full fp32-Adam "
        "— §Perf A shows the knobs that bring llama3 under; kimi-1T "
        "training structurally needs >=4 pods (or Adafactor+bf16 "
        "master) at 16 GiB/chip, as expected for 1T params on 256 chips.",
        "- multi-pod cells: pod axis joins DP/FSDP; collectives pick up "
        "the DCN hop (terms are trip-count-uncorrected there; the "
        "roofline is scored single-pod per the assignment).",
    ]
    return "\n".join(lines)


def perf_tables():
    res = load("results/perf")
    if not res:
        return ""
    base = {(r["arch"], r["shape"], r["mesh"]): r
            for r in load("results/dryrun")}
    hdr = ("| cell | variant | compute ms | memory ms | collective ms | "
           "GiB/dev | dominant |\n|---|---|---|---|---|---|---|")
    rows = []
    for r in sorted(res, key=lambda x: x.get("variant", {}).get("tag", "")):
        if r.get("status") != "ok":
            rows.append(f"| {r.get('arch')}x{r.get('shape')} | "
                        f"{r.get('variant', {}).get('tag', '?')} | ERROR "
                        f"{r.get('error', '')[:50]} | | | | |")
            continue
        b = base.get((r["arch"], r["shape"], r["mesh"]))
        t, bt = r["terms"], b["terms"]
        def delta(new, old):
            return f"{new*1e3:.2f} ({new/old:.2f}x)" if old else f"{new*1e3:.2f}"
        rows.append(
            f"| {r['arch']} x {r['shape']} "
            f"| {r['variant']['tag']} "
            f"| {delta(t['compute_s'], bt['compute_s'])} "
            f"| {delta(t['memory_s'], bt['memory_s'])} "
            f"| {delta(t['collective_s'], bt['collective_s'])} "
            f"| {r['memory']['per_device_total_gb']:.2f} "
            f"(base {b['memory']['per_device_total_gb']:.2f}) "
            f"| {t['dominant']} |")
    return "\n".join([hdr] + rows)


def main():
    results = load("results/dryrun")
    with open("EXPERIMENTS.md") as f:
        doc = f.read()
    doc = doc.replace("<!-- DRYRUN-SUMMARY -->", dryrun_summary(results))
    roof = ("### Single-pod (16x16, 256 chips) — scored table\n\n"
            + table(results, "pod")
            + "\n\n### Multi-pod (2x16x16, 512 chips) — compile + memory "
              "proof (terms uncorrected)\n\n" + table(results, "multipod"))
    doc = doc.replace("<!-- ROOFLINE-TABLE -->", roof)
    pt = perf_tables()
    if pt and "<!-- PERF-VARIANTS -->" in doc:
        doc = doc.replace("<!-- PERF-VARIANTS -->", pt)
    with open("EXPERIMENTS.md", "w") as f:
        f.write(doc)
    print("EXPERIMENTS.md rendered;",
          len([r for r in results if r['status'] == 'ok']), "ok cells")


if __name__ == "__main__":
    main()
