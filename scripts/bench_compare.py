"""Compare two BENCH_<sha>.json perf-trajectory records.

CI (bench-smoke on main) keeps the previous run's record in the actions
cache; this script diffs the new record against it and emits a markdown
table for $GITHUB_STEP_SUMMARY — the per-commit perf trajectory made
visible instead of rotting as unread artifacts.

    python scripts/bench_compare.py OLD.json NEW.json [--threshold 1.5]
                                    [--output summary.md]

Exit code is always 0 on a successful comparison (smoke timings are
single-iteration and noisy — the table *surfaces* regressions, marking
anything slower than ``threshold``x with a warning row; gating merges on
smoke noise would only train people to ignore CI).  Exit 2 on an
unreadable NEW record.  A missing, empty, or unparseable OLD record is
NOT an error — the first run of a fresh cache has no predecessor, so the
new record seeds the trajectory (every row "new") and the exit is 0.
"""

from __future__ import annotations

import argparse
import json
import sys


def _records(payload: dict) -> dict:
    """bench -> list of (label, median_s), labels defaulted by position."""
    out = {}
    for bench, recs in payload.get("benches", {}).items():
        out[bench] = [
            (r.get("label") or f"#{i}", float(r["median_s"]))
            for i, r in enumerate(recs)
        ]
    return out


def compare(old: dict, new: dict, threshold: float = 1.5) -> tuple[str, int]:
    """Markdown table of per-record deltas; returns (table, regressions).

    Records are matched by (bench, label).  A record slower than
    ``threshold``x its predecessor counts as a regression and its row is
    flagged; benches that appeared/disappeared are listed but never
    flagged (renames are not regressions).
    """
    old_r, new_r = _records(old), _records(new)
    lines = [
        "| bench | record | prev (s) | now (s) | ratio | |",
        "|---|---|---|---|---|---|",
    ]
    regressions = 0
    for bench in sorted(set(old_r) | set(new_r)):
        if bench not in new_r:
            lines.append(f"| {bench} | *(removed)* | | | | |")
            continue
        if bench not in old_r:
            for label, t in new_r[bench]:
                lines.append(f"| {bench} | {label} | — | {t:.4f} | new | |")
            continue
        prev = dict(old_r[bench])
        for label, t in new_r[bench]:
            p = prev.get(label)
            if p is None:
                lines.append(f"| {bench} | {label} | — | {t:.4f} | new | |")
                continue
            ratio = t / p if p > 0 else float("inf")
            flag = ""
            if ratio >= threshold:
                flag = f"⚠️ ≥ {threshold:g}x slower"
                regressions += 1
            lines.append(
                f"| {bench} | {label} | {p:.4f} | {t:.4f} | "
                f"{ratio:.2f}x | {flag} |"
            )
    failures = new.get("failures") or []
    if failures:
        lines.append("")
        lines.append(f"**failed benches:** {', '.join(failures)}")
    header = (
        f"### Bench trajectory ({'smoke' if new.get('smoke') else 'full'} "
        f"timings, {regressions} record(s) ≥ {threshold:g}x slower)\n\n"
    )
    return header + "\n".join(lines), regressions


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("old")
    ap.add_argument("new")
    ap.add_argument("--threshold", type=float, default=1.5,
                    help="ratio that flags a record as a regression")
    ap.add_argument("--output", default=None,
                    help="write the markdown here (default: stdout)")
    args = ap.parse_args(argv)
    try:
        with open(args.new) as f:
            new = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench_compare: cannot read new record: {e}", file=sys.stderr)
        return 2
    seeded = False
    try:
        with open(args.old) as f:
            old = json.load(f)
        if not isinstance(old, dict):
            raise json.JSONDecodeError("not a JSON object", "", 0)
    except (OSError, json.JSONDecodeError) as e:
        # First run on a fresh cache: seed the trajectory, don't fail CI.
        print(f"bench_compare: no prior record ({e}); seeding trajectory",
              file=sys.stderr)
        old, seeded = {"benches": {}}, True
    table, _ = compare(old, new, threshold=args.threshold)
    if seeded:
        table += ("\n\n*(no readable prior record — this run seeds the "
                  "trajectory)*")
    if args.output:
        with open(args.output, "w") as f:
            f.write(table + "\n")
    else:
        print(table)
    return 0


if __name__ == "__main__":
    sys.exit(main())
