#!/usr/bin/env python3
"""Link/anchor checker for the markdown docs (stdlib only — CI docs job).

    python scripts/check_docs.py README.md docs

Walks every given markdown file (directories are searched for ``*.md``)
and verifies each relative link:

  * the target file exists (resolved against the linking file's dir);
  * a ``#anchor`` fragment matches a heading slug in the target file
    (GitHub slugging: lowercase, punctuation dropped, spaces -> dashes).

External links (http/https/mailto) are not fetched — CI must not flake
on the network. Exit 1 with one line per broken link.
"""

from __future__ import annotations

import pathlib
import re
import sys

LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$")
CODE_FENCE_RE = re.compile(r"^\s*(```|~~~)")


def slugify(heading: str) -> str:
    """GitHub's anchor slug: strip markup, lowercase, keep word chars,
    spaces and hyphens, then spaces -> hyphens."""
    text = re.sub(r"[`*_]", "", heading).strip().lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def heading_slugs(path: pathlib.Path) -> set[str]:
    slugs: set[str] = set()
    counts: dict[str, int] = {}
    in_fence = False
    for line in path.read_text(encoding="utf-8").splitlines():
        if CODE_FENCE_RE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = HEADING_RE.match(line)
        if not m:
            continue
        slug = slugify(m.group(1))
        n = counts.get(slug, 0)
        counts[slug] = n + 1
        slugs.add(slug if n == 0 else f"{slug}-{n}")
    return slugs


def iter_links(path: pathlib.Path):
    in_fence = False
    for lineno, line in enumerate(
            path.read_text(encoding="utf-8").splitlines(), 1):
        if CODE_FENCE_RE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for m in LINK_RE.finditer(line):
            yield lineno, m.group(1)


def check_file(path: pathlib.Path, root: pathlib.Path) -> list[str]:
    errors = []
    for lineno, target in iter_links(path):
        where = f"{path.relative_to(root)}:{lineno}"
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        ref, _, anchor = target.partition("#")
        dest = path if not ref else (path.parent / ref).resolve()
        if ref and not dest.exists():
            errors.append(f"{where}: broken link {target!r} "
                          f"(no such file {ref!r})")
            continue
        if anchor and dest.suffix == ".md":
            if anchor not in heading_slugs(dest):
                errors.append(f"{where}: broken anchor {target!r} "
                              f"(no heading slug {anchor!r} in "
                              f"{dest.name})")
    return errors


def main(argv=None) -> int:
    args = (argv if argv is not None else sys.argv[1:]) or ["README.md",
                                                            "docs"]
    root = pathlib.Path.cwd()
    files: list[pathlib.Path] = []
    for a in args:
        p = pathlib.Path(a)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.md")))
        elif p.exists():
            files.append(p)
        else:
            print(f"check_docs: no such path {a!r}", file=sys.stderr)
            return 2
    errors = [e for f in files for e in check_file(f.resolve(), root)]
    for e in errors:
        print(e, file=sys.stderr)
    print(f"check_docs: {len(files)} file(s), {len(errors)} broken "
          f"link(s)/anchor(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
