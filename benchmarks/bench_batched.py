"""Frame-batched throughput: frames/sec vs microbatch size per method.

The paper's headline metric is frames per second on a video stream
(300.4 fps at 640x480x32 bins); its dual-stream pipeline (§4.4) wins by
overlapping transfer with compute.  On XLA an orthogonal lever is batching
the frame axis into one dispatch (cf. Koppaka et al., arXiv:1011.0235):
per-dispatch overhead is amortized and the scans vectorize across frames.

Regimes (measured, CPU):
  * dispatch-bound — small frames (ROI/tracking-window scale): batching
    wins big; batch=16 is >= 1.5x frames/sec over batch=1 on wf_tis.
  * cache-bound — large frames: the batched working set spills the LLC
    and small batches win.  `IntegralHistogram.map_frames(batch_size=
    "auto")` picks the regime from the per-frame footprint.

This bench times the batched `integral_histogram` directly (pure dispatch
throughput, batch = 1/4/16) for each method across both regimes; the
pipeline-level overlap on top of it is measured by bench_pipeline.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import fmt_table, time_fn
from repro.data import video_frames
from repro.kernels.ops import integral_histogram

BATCHES = (1, 4, 16)


def run(quick: bool = False) -> str:
    # (h, w, bins): ROI/tracking-window scale first (dispatch-bound — the
    # batching win), then full-frame scales (cache-bound on CPU).
    sizes = [(64, 64, 16), (240, 320, 16)]
    methods = ["wf_tis", "cw_tis", "cw_sts"]
    if not quick:
        sizes.append((480, 640, 32))
        methods.append("cw_b")

    rows = []
    for h, w, bins in sizes:
        frames = video_frames(h, w, max(BATCHES), seed=7)
        for method in methods:
            fps = {}
            for n in BATCHES:
                fn = jax.jit(functools.partial(
                    integral_histogram, num_bins=bins, method=method,
                    backend="jnp"))
                x = jnp.asarray(frames[:n]) if n > 1 else jnp.asarray(frames[0])
                t = time_fn(fn, x, warmup=2, iters=3 if quick else 5)
                fps[n] = n / t["median_s"]
            rows.append([f"{h}x{w}x{bins}", method]
                        + [f"{fps[n]:.2f}" for n in BATCHES]
                        + [f"{fps[16] / fps[1]:.2f}x"])
    return ("frames/sec by microbatch size (jnp backend)\n"
            + fmt_table(["frame", "method"]
                        + [f"batch={n}" for n in BATCHES] + ["16 vs 1"],
                        rows))


if __name__ == "__main__":
    print(run())
