"""Roofline table renderer: reads results/dryrun/*.json (written by
launch/dryrun.py) and emits the §Roofline table for EXPERIMENTS.md.

This bench does NOT compile anything itself — the dry-run sweep is the
expensive producer; here we aggregate."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import fmt_table
from repro.config import HW


def load_results(out_dir: str = "results/dryrun") -> list[dict]:
    res = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            res.append(json.load(f))
    return res


def render(results: list[dict], mesh: str = "pod") -> str:
    rows = []
    for r in results:
        if r.get("mesh") != mesh:
            continue
        if r.get("status") == "skipped":
            rows.append([r["arch"], r["shape"], "skip", "-", "-", "-", "-",
                         "-", "-"])
            continue
        if r.get("status") != "ok":
            rows.append([r["arch"], r["shape"], "ERROR", "-", "-", "-", "-",
                         "-", "-"])
            continue
        t = r["terms"]
        rows.append([
            r["arch"], r["shape"], t["dominant"],
            f"{t['compute_s']*1e3:.2f}",
            f"{t['memory_s']*1e3:.2f}",
            f"{t['collective_s']*1e3:.2f}",
            f"{t['roofline_fraction']:.3f}",
            f"{r['useful_flops_ratio']:.2f}",
            f"{r['memory']['per_device_total_gb']:.2f}",
        ])
    hdr = ["arch", "shape", "bound", "compute ms", "memory ms",
           "collective ms", "roofline frac", "6ND/HLO", "GiB/dev"]
    return fmt_table(hdr, rows)


def run(quick: bool = False) -> str:
    results = load_results()
    if not results:
        return ("no dry-run results found — run "
                "`PYTHONPATH=src python -m repro.launch.dryrun --all "
                "--mesh both` first")
    ok = sum(1 for r in results if r.get("status") == "ok")
    skip = sum(1 for r in results if r.get("status") == "skipped")
    err = sum(1 for r in results if r.get("status") == "error")
    head = (f"cells: {ok} ok / {skip} skipped (per assignment rules) / "
            f"{err} error   hw: {HW['peak_flops_bf16']/1e12:.0f} TF/s, "
            f"{HW['hbm_bw']/1e9:.0f} GB/s HBM, "
            f"{HW['ici_link_bw']/1e9:.0f} GB/s/link\n")
    return (head + "\n== single-pod (16x16) ==\n" + render(results, "pod")
            + "\n\n== multi-pod (2x16x16) ==\n"
            + render(results, "multipod"))


if __name__ == "__main__":
    print(run())
