"""Paper Fig. 16/17: multi-device scaling (bins over devices; large
frames spatially sharded).

Runs in a subprocess with 8 forced host devices so the rest of the
benchmark suite keeps its single-device view (assignment requirement).
The "4 GTX480 + task queue" of the paper becomes a mesh axis; the
spatial sharding with cross-device carries is the beyond-paper extension
(DESIGN.md §2)."""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

_BODY = r"""
import json, time, warnings
warnings.filterwarnings("ignore")
import numpy as np, jax, jax.numpy as jnp
from repro.core.distributed import bin_sharded_ih, spatial_sharded_ih
from repro.kernels.ops import integral_histogram
from benchmarks.common import fmt_table

quick = __QUICK__
rows = []
recs = []


def timed(fn, img, label):
    fn(img).block_until_ready()
    t0 = time.perf_counter(); fn(img).block_until_ready()
    dt = time.perf_counter() - t0
    recs.append({"median_s": dt, "min_s": dt, "iters": 1, "label": label})
    return dt


rng = np.random.default_rng(0)
cases = [((1280, 720), 32), ((1920, 1080), 32)]
if not quick:
    cases += [((4096, 3072), 32), ((1920, 1080), 128)]
for (w, h), bins in cases:
    img = jnp.asarray(rng.integers(0, 256, (h, w), dtype=np.uint8))
    # single device
    fn1 = jax.jit(lambda im: integral_histogram(im, bins, method="wf_tis",
                                                backend="jnp"))
    t1 = timed(fn1, img, f"multidev_{h}x{w}_b{bins}_1dev")
    for ndev in (2, 4, 8):
        mesh = jax.make_mesh((1, ndev), ("data", "model"))
        fnd = jax.jit(lambda im: bin_sharded_ih(im, bins, mesh))
        td = timed(fnd, img, f"multidev_{h}x{w}_b{bins}_bins{ndev}")
        rows.append([f"{h}x{w}", bins, ndev, "bins",
                     f"{td*1e3:.1f} ms", f"{t1/td:.2f}x"])
    mesh = jax.make_mesh((8, 1), ("data", "model"))
    fns = jax.jit(lambda im: spatial_sharded_ih(im, bins, mesh,
                                                scan_impl="ppermute"))
    ts = timed(fns, img, f"multidev_{h}x{w}_b{bins}_rows8")
    rows.append([f"{h}x{w}", bins, 8, "rows+carry wavefront",
                 f"{ts*1e3:.1f} ms", f"{t1/ts:.2f}x"])
print(fmt_table(["frame", "bins", "devices", "shard", "wall", "vs 1 dev"],
                rows))
print("NOTE: host 'devices' share one physical CPU core, so wall-clock")
print("speedup is bounded by 1x; the table demonstrates correct sharded")
print("execution + collective schedule; real scaling is the dry-run's job.")
print("TIMINGS_JSON " + json.dumps(recs))
"""


def run(quick: bool = False) -> str:
    from benchmarks import common

    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.environ.get("PYTHONPATH", "src"))
    code = _BODY.replace("__QUICK__", repr(quick or common.SMOKE))
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          env=env, capture_output=True, text=True,
                          timeout=900)
    if proc.returncode != 0:
        return f"FAILED:\n{proc.stderr[-2000:]}"
    # The subprocess owns the 8-device view, so its timings never pass
    # through common.time_fn — it ships them back on a TIMINGS_JSON line
    # that we fold into the parent's record stream (the --json artifact
    # previously had no multidevice records at all).
    lines = []
    for line in proc.stdout.splitlines():
        if line.startswith("TIMINGS_JSON "):
            common.TIMINGS.extend(json.loads(line[len("TIMINGS_JSON "):]))
        else:
            lines.append(line)
    return "\n".join(lines).strip()


if __name__ == "__main__":
    print(run())
