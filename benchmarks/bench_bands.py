"""Band-streamed integral histograms: throughput vs band height and the
peak-memory proxy of a budgeted large-frame likelihood map.

The paper's §4.6 scale story is a frame whose H tensor dwarfs memory
(64 MB x 128 bins -> 32 GB).  core/bands.py streams row bands through the
carry-aware kernels so that regime fits one host:

  * part 1 — throughput vs band height: reduce-on-the-fly (only the
    (b, w) carry survives each band), Mpix/s across a band_h sweep.
    Measures the dispatch + carry overhead banding adds over the
    monolithic computation (band_h = h row).
  * part 2 — the acceptance scenario: a likelihood map computed under a
    memory budget a fraction of the full H footprint.  The peak-allocation
    proxy (largest live band + the two corner-row slabs) is asserted
    below the monolithic footprint — the full (b, h, w) H never exists.
  * part 3 — spill storage policies: host-side footprint of
    float32/uint32/uint16 band spills (uint16 halves storage and keeps
    <= 65535-px queries exact by modular arithmetic, arXiv:1510.05142).
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from benchmarks.common import fmt_table, time_fn
from repro.core import distances
from repro.core.bands import (
    iter_banded_ih,
    plan_bands,
    reduce_banded_ih,
    spill_banded_ih,
)
from repro.core.hsource import BandedH
from repro.core.region_query import likelihood_map
from repro.data import video_frames


def run(quick: bool = False) -> str:
    h = w = 384 if quick else 1024
    bins = 16 if quick else 32
    img = video_frames(h, w, 1, seed=11)[0]

    out = []

    # -- part 1: throughput vs band height (nothing retained but the carry)
    rows = []
    for band_h in (h, h // 4, h // 16, h // 64):
        def consume():
            return reduce_banded_ih(
                img, bins, lambda acc, band: band.carry,
                band_h=band_h, backend="jnp")

        t = time_fn(consume, label=f"band_h={band_h}")
        plan = plan_bands(h, w, bins, band_h=band_h)
        rows.append([
            band_h, plan.num_bands,
            f"{plan.band_bytes / 2**20:.1f}",
            f"{h * w / t['median_s'] / 1e6:.1f}",
        ])
    out.append(f"throughput vs band height ({h}x{w}x{bins} bins, wf_tis/jnp)\n"
               + fmt_table(["band_h", "bands", "band MB", "Mpix/s"], rows))

    # -- part 2: budgeted likelihood map, peak-memory proxy asserted
    plan_full = plan_bands(h, w, bins)
    budget = plan_full.full_h_bytes // 8
    target = jnp.ones((bins,), jnp.float32) * (48 * 48 / bins)
    stats: dict = {}
    lmap = likelihood_map(
        BandedH(iter_banded_ih(img, bins, memory_budget_bytes=budget,
                               backend="jnp")),
        target, (48, 48), distances.intersection, stride=16, stats=stats)
    # The acceptance claim: exact O(1) analytics for a frame whose full H
    # exceeds the budget, without ever allocating (b, h, w).
    assert stats["full_h_bytes"] > budget >= stats["band_bytes"]
    assert stats["peak_bytes"] < stats["full_h_bytes"]
    out.append(
        "budgeted likelihood map (stride 16, 48x48 window): "
        f"map {tuple(lmap.shape)}, budget {budget / 2**20:.1f} MB, "
        f"{stats['num_bands']} bands\n"
        f"peak proxy {stats['peak_bytes'] / 2**20:.1f} MB "
        f"(band {stats['band_bytes'] / 2**20:.1f} + slabs "
        f"{stats['slab_bytes'] / 2**20:.1f}) vs full H "
        f"{stats['full_h_bytes'] / 2**20:.1f} MB -> "
        f"{stats['full_h_bytes'] / stats['peak_bytes']:.1f}x smaller")

    # -- part 3: spill storage policies (small frame: assemble() stays cheap)
    sh, sw = 240, 320
    simg = video_frames(sh, sw, 1, seed=12)[0]
    rows = []
    for storage in ("float32", "uint32", "uint16"):
        sp = spill_banded_ih(simg, bins, band_h=64, backend="jnp",
                             storage=storage)
        hist = sp.region_histogram(np.array([40, 40, 199, 279]))
        rows.append([storage, f"{sp.nbytes / 2**20:.2f}",
                     f"{float(hist.sum()):.0f}"])
    out.append(f"spill policies ({sh}x{sw}x{bins} bins): host MB + a "
               "160x240 region query (count must be 38400)\n"
               + fmt_table(["storage", "MB", "query px"], rows))

    return "\n\n".join(out)


if __name__ == "__main__":
    print(run())
