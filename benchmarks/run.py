"""Benchmark driver: one bench per paper table/figure + the roofline
aggregation.  `python -m benchmarks.run [--quick|--smoke] [--only NAME]`.

`--smoke` is the CI mode: quick sizes AND single-iteration timing
(benchmarks.common.SMOKE), so every bench script still executes end to
end — numbers are meaningless, rot is caught."""

from __future__ import annotations

import argparse
import sys
import time

BENCHES = [
    ("methods", "benchmarks.bench_methods",
     "paper Fig. 7/8 — four methods, kernel time"),
    ("tiles", "benchmarks.bench_tiles",
     "paper Fig. 9/10 — tile/block configuration sweep"),
    ("pipeline", "benchmarks.bench_pipeline",
     "paper Fig. 13/15 — dual-buffering frame rate"),
    ("batched", "benchmarks.bench_batched",
     "paper §4.4 + arXiv:1011.0235 — frame-batched throughput"),
    ("analytics", "benchmarks.bench_analytics",
     "paper abstract — O(1) sliding-window queries + tracker fps"),
    ("multidevice", "benchmarks.bench_multidevice",
     "paper Fig. 16/17 — multi-device bin/spatial sharding"),
    ("speedup", "benchmarks.bench_speedup",
     "paper Fig. 19/20 — speedup vs sequential CPU"),
    ("roofline", "benchmarks.bench_roofline",
     "assignment §Roofline — dry-run derived terms"),
]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sizes/iterations")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: --quick sizes + 1 timing iteration")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None
    if args.smoke:
        from benchmarks import common
        common.SMOKE = True
        args.quick = True

    failures = []
    for name, module, desc in BENCHES:
        if only and name not in only:
            continue
        print(f"\n{'='*72}\n[{name}] {desc}\n{'='*72}")
        t0 = time.perf_counter()
        try:
            mod = __import__(module, fromlist=["run"])
            print(mod.run(quick=args.quick))
            print(f"-- {name} done in {time.perf_counter()-t0:.1f}s")
        except Exception as e:  # keep the suite going
            failures.append(name)
            print(f"-- {name} FAILED: {type(e).__name__}: {e}")
    if failures:
        print(f"\nFAILED benches: {failures}")
        sys.exit(1)
    print("\nall benches complete")


if __name__ == "__main__":
    main()
