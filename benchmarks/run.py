"""Benchmark driver: one bench per paper table/figure + the roofline
aggregation.  `python -m benchmarks.run [--quick|--smoke] [--only NAME]
[--json PATH] [--list]`.

`--list` prints every bench name with its one-line description and
exits 0 (the CLI's discovery surface; tested in tests/test_bench_run.py).

`--smoke` is the CI mode: quick sizes AND single-iteration timing
(benchmarks.common.SMOKE), so every bench script still executes end to
end — numbers are meaningless, rot is caught.

`--json PATH` serializes every bench's `time_fn` records (keyed by bench
name, in call order) plus the failure list — CI uploads it as the
per-commit perf-trajectory artifact."""

from __future__ import annotations

import argparse
import json
import sys
import time

BENCHES = [
    ("methods", "benchmarks.bench_methods",
     "paper Fig. 7/8 — four methods, kernel time"),
    ("tiles", "benchmarks.bench_tiles",
     "paper Fig. 9/10 — tile/block configuration sweep"),
    ("pipeline", "benchmarks.bench_pipeline",
     "paper Fig. 13/15 — dual-buffering frame rate"),
    ("batched", "benchmarks.bench_batched",
     "paper §4.4 + arXiv:1011.0235 — frame-batched throughput"),
    ("analytics", "benchmarks.bench_analytics",
     "paper abstract — O(1) sliding-window queries + tracker fps"),
    ("bands", "benchmarks.bench_bands",
     "paper §4.6 + arXiv:1510.05142 — band streaming under a "
     "memory budget"),
    ("engine", "benchmarks.bench_engine",
     "ISSUE 4 — plan/execute engine overhead vs hand-routed calls"),
    ("serve", "benchmarks.bench_serve",
     "ISSUE 5 — AnalyticsService requests/sec vs in-flight depth and "
     "cache"),
    ("fused", "benchmarks.bench_fused",
     "ISSUE 8 — query-fused corner rows vs banded streaming"),
    ("delta", "benchmarks.bench_delta",
     "ISSUE 9 — incremental video-delta H updates vs full recompute"),
    ("multidevice", "benchmarks.bench_multidevice",
     "paper Fig. 16/17 — multi-device bin/spatial sharding"),
    ("speedup", "benchmarks.bench_speedup",
     "paper Fig. 19/20 — speedup vs sequential CPU"),
    ("roofline", "benchmarks.bench_roofline",
     "assignment §Roofline — dry-run derived terms"),
]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sizes/iterations")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: --quick sizes + 1 timing iteration")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write per-bench time_fn records as JSON")
    ap.add_argument("--list", action="store_true",
                    help="print bench names with descriptions and exit 0")
    args = ap.parse_args(argv)

    if args.list:
        width = max(len(name) for name, _, _ in BENCHES)
        for name, _, desc in BENCHES:
            print(f"{name.ljust(width)}  {desc}")
        return

    valid = [name for name, _, _ in BENCHES]
    only = None
    if args.only:
        only = {n.strip() for n in args.only.split(",") if n.strip()}
        unknown = sorted(only - set(valid))
        if unknown or not only:
            # An unknown name must fail loudly: silently selecting nothing
            # and reporting "all benches complete" hid typos from CI.
            print(f"unknown bench name(s): {unknown or '(none given)'}\n"
                  f"valid names: {valid}", file=sys.stderr)
            sys.exit(2)

    from benchmarks import common

    if args.smoke:
        common.SMOKE = True
        args.quick = True

    failures = []
    records: dict = {}
    for name, module, desc in BENCHES:
        if only and name not in only:
            continue
        print(f"\n{'='*72}\n[{name}] {desc}\n{'='*72}")
        t0 = time.perf_counter()
        start = len(common.TIMINGS)
        try:
            mod = __import__(module, fromlist=["run"])
            print(mod.run(quick=args.quick))
            print(f"-- {name} done in {time.perf_counter()-t0:.1f}s")
        except Exception as e:  # keep the suite going
            failures.append(name)
            print(f"-- {name} FAILED: {type(e).__name__}: {e}")
        records[name] = common.TIMINGS[start:]

    if args.json:
        payload = {
            "smoke": args.smoke,
            "quick": args.quick,
            "failures": failures,
            "benches": records,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"\nwrote {sum(map(len, records.values()))} timing records "
              f"to {args.json}")

    if failures:
        print(f"\nFAILED benches: {failures}")
        sys.exit(1)
    print("\nall benches complete")


if __name__ == "__main__":
    main()
