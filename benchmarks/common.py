"""Shared benchmark utilities: wall-clock timing of jitted callables and
result table formatting.  CPU wall-times measure the XLA:CPU executables
of the schedule-faithful jnp restatements (DESIGN.md §2: kernel wall-time
on the TPU target is covered by the analytic roofline, not measurable in
this container)."""

from __future__ import annotations

import time

import jax
import numpy as np

# Set by `benchmarks.run --smoke` (CI): collapse every timing loop to a
# single un-warmed iteration so bench scripts execute end to end without
# burning CI minutes on stable medians.
SMOKE = False

# Every time_fn result is appended here, in call order.  benchmarks.run
# snapshots the list around each bench to key records by bench name and
# serialize them with --json (the CI perf-trajectory artifact).
TIMINGS: list = []


def time_fn(fn, *args, warmup: int = 2, iters: int = 5,
            label: str | None = None) -> dict:
    """Median wall time of a jitted callable (blocks on results).

    ``label`` tags the record in the --json artifact (optional; records
    are ordered regardless).
    """
    if SMOKE:
        warmup, iters = 0, 1
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    record = {"median_s": float(np.median(ts)),
              "min_s": float(np.min(ts)),
              "iters": iters,
              "label": label}
    TIMINGS.append(record)
    return record


def fmt_table(headers, rows) -> str:
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows))
              for i, h in enumerate(headers)]
    def line(cells):
        return "| " + " | ".join(str(c).ljust(w)
                                 for c, w in zip(cells, widths)) + " |"
    sep = "|" + "|".join("-" * (w + 2) for w in widths) + "|"
    return "\n".join([line(headers), sep] + [line(r) for r in rows])
