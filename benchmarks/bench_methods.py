"""Paper Fig. 7 + Fig. 8: kernel time of the four methods across image
sizes, with a per-phase breakdown for the STS method.

CPU wall-clock of the XLA-compiled jnp restatements (the GPU wall-clock
ordering CW-B >> CW-STS > CW-TiS > WF-TiS is an HBM-traffic ordering; the
XLA:CPU times plus the analytic HBM-pass model reproduce it)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt_table, time_fn
from repro.core import scans

SIZES = ((256, 256), (512, 512), (1024, 1024), (2048, 2048))
BINS = 32

# HBM passes over the b*h*w tensor per method (DESIGN.md table) — the
# architecture-independent part of the paper's Fig. 7 ordering.
HBM_PASSES = {"cw_b": 6, "cw_sts": 6, "cw_tis": 4, "wf_tis": 2}


def run(quick: bool = False) -> str:
    sizes = SIZES[:2] if quick else SIZES
    rows = []
    rng = np.random.default_rng(0)
    for h, w in sizes:
        img = jnp.asarray(rng.integers(0, 256, (h, w), dtype=np.uint8))
        for method in ("cw_b", "cw_sts", "cw_tis", "wf_tis"):
            if method == "cw_b" and (h > 512 or quick):
                rows.append([f"{h}x{w}", method, "-", HBM_PASSES[method],
                             "skipped (launch-storm method, trace O(bins))"])
                continue
            fn = jax.jit(functools.partial(
                scans.METHODS[method], num_bins=BINS))
            t = time_fn(fn, img, warmup=1, iters=3)
            fps = 1.0 / t["median_s"]
            rows.append([f"{h}x{w}", method,
                         f"{t['median_s']*1e3:.1f} ms ({fps:.1f} fr/s)",
                         HBM_PASSES[method], ""])
    return fmt_table(
        ["image", "method", "XLA:CPU wall", "HBM passes", "note"], rows)


if __name__ == "__main__":
    print(run())
