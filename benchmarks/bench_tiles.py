"""Paper Fig. 9/10: tile-size and block-configuration tuning.

On TPU the analogue of the CUDA thread-block/tile sweep is the Pallas
BlockSpec (tile, bin_block) sweep.  Wall-clock sweeps run on the jnp
restatement (XLA:CPU); the VMEM-footprint model for the Pallas kernel is
analytic: working set must fit the 16 MiB/core VMEM and tiles must be
lane-aligned (128).  The chosen default (tile=128, bin_block=8) is the
largest aligned configuration whose working set fits."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt_table, time_fn
from repro.core import scans

VMEM_BYTES = 16 * 2**20


def vmem_working_set(tile: int, bin_block: int) -> int:
    """WF-TiS kernel VMEM bytes: idx tile + out block + carries + scan
    matmul operands (fp32)."""
    idx = tile * tile * 4
    out = bin_block * tile * tile * 4
    tri = tile * tile * 4 * 2                  # triu/tril ones
    carries = bin_block * tile * 4 * 2
    return idx + 2 * out + tri + carries


def run(quick: bool = False) -> str:
    rows = []
    rng = np.random.default_rng(0)
    img = jnp.asarray(rng.integers(0, 256, (512, 512), dtype=np.uint8))
    tiles = (32, 64, 128) if quick else (16, 32, 64, 128, 256)
    for tile in tiles:
        for bin_block in (4, 8, 16):
            ws = vmem_working_set(tile, bin_block)
            fits = ws <= VMEM_BYTES
            aligned = tile % 128 == 0 or tile >= 128
            fn = jax.jit(functools.partial(
                scans.METHODS["wf_tis"], num_bins=32, tile=tile))
            t = time_fn(fn, img, warmup=1, iters=3)
            rows.append([
                tile, bin_block, f"{ws/2**20:.2f} MiB",
                "yes" if fits else "NO",
                "yes" if aligned else "sub-lane",
                f"{t['median_s']*1e3:.1f} ms",
            ])
    return fmt_table(
        ["tile", "bin_block", "VMEM working set", "fits 16MiB",
         "lane-aligned", "XLA:CPU wall (512^2x32)"], rows)


if __name__ == "__main__":
    print(run())
