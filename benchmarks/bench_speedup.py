"""Paper Fig. 19/20: speedup over the sequential CPU implementation.

Baseline: the paper's Algorithm 1 — the O(N) row-recursive single-
threaded method — implemented in numpy exactly as published (one pass,
4-term recurrence per pixel per bin, vectorized per row to make it
runnable; a pure-python pixel loop would only flatter our speedup).
"XLA:CPU" is the repro framework's wf_tis on the same host."""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt_table, time_fn
from repro.core.binning import bin_indices
from repro.core import scans


def sequential_cpu_ih(img: np.ndarray, bins: int) -> np.ndarray:
    """Algorithm 1 of the paper (numpy, row-recursive)."""
    h, w = img.shape
    idx = np.asarray(bin_indices(jnp.asarray(img), bins))
    H = np.zeros((bins, h, w), np.float32)
    onehot_row = np.zeros((bins, w), np.float32)
    for x in range(h):
        onehot_row[:] = 0.0
        onehot_row[idx[x], np.arange(w)] = 1.0
        rowsum = np.cumsum(onehot_row, axis=1)        # row prefix
        if x == 0:
            H[:, 0, :] = rowsum
        else:
            H[:, x, :] = H[:, x - 1, :] + rowsum
    return H


def run(quick: bool = False) -> str:
    rows = []
    rng = np.random.default_rng(0)
    sizes = [(256, 256), (512, 512)] if quick else \
            [(256, 256), (512, 512), (1024, 1024), (2048, 2048)]
    for h, w in sizes:
        img = rng.integers(0, 256, (h, w), dtype=np.uint8)
        t0 = time.perf_counter()
        ref = sequential_cpu_ih(img, 32)
        t_seq = time.perf_counter() - t0
        fn = jax.jit(functools.partial(scans.wf_tis, num_bins=32))
        t = time_fn(fn, jnp.asarray(img), warmup=1, iters=3)
        out = fn(jnp.asarray(img))
        assert np.allclose(np.asarray(out), ref, atol=1e-2)
        rows.append([f"{h}x{w}",
                     f"{t_seq*1e3:.1f} ms",
                     f"{t['median_s']*1e3:.1f} ms",
                     f"{t_seq/t['median_s']:.1f}x"])
    return fmt_table(
        ["image (32 bins)", "sequential CPU (Alg.1)", "repro wf_tis XLA:CPU",
         "speedup"], rows)


if __name__ == "__main__":
    print(run())
