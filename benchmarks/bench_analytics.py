"""O(1) analytics throughput: sliding-window queries and the tracker.

Two regressions this bench guards:

  * **windows/sec, gather vs slice** — `sliding_window_histograms` used
    to issue one Eq.-2 gather per window position; on a regular grid all
    four corners of every window live on a strided lattice, so the whole
    query field is four strided slices of H combined elementwise (no
    index arrays, no gather).  The paper's dense multi-scale search
    (640x480, 32 bins, stride 1 -> ~280k windows) is the headline shape.
    Caveat for reading the steady-state column: XLA:CPU constant-folds
    the gather's strided index arrays into near-slice code, so both
    paths sit at the memory-bandwidth floor and the slice win there is
    a few percent; the structural win shows in (a) first-call latency —
    the gather path folds megabytes of index constants per compiled
    (window, stride) variant, which is what `multi_scale_search` pays
    per scale — and (b) gather-hostile backends (TPU), where index
    arrays never lower to strided loads.

  * **tracker frames/sec, step loop vs track()** — `FragmentTracker.track`
    chunks the clip, computes each chunk's integral histograms in ONE
    batched dispatch (PR 1's (n, h, w) kernel path) and threads the state
    through a `lax.scan`, vs the per-frame `step` loop that pays one H
    dispatch + one vote dispatch per frame.

Both comparisons are bit-exact (tests/test_analytics.py); this bench
reports only the speed side.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

import time

from benchmarks import common
from benchmarks.common import fmt_table, time_fn
from repro.core.region_query import sliding_window_histograms
from repro.core.tracking import FragmentTracker, TrackerConfig
from repro.data import video_frames
from repro.kernels.ops import integral_histogram


def _windows_rows(quick: bool) -> list:
    # (h, w, bins, stride): the paper's 640x480x32 dense-stride search is
    # the headline row; quick keeps a smaller frame so CI stays fast.
    # Sub-millisecond cases are omitted — dispatch jitter drowns them.
    cases = [(240, 320, 16, 2), (240, 320, 16, 1)]
    if not quick:
        cases += [(480, 640, 32, 4), (480, 640, 32, 1)]
    window = (24, 24)
    rows = []
    for h, w, bins, stride in cases:
        img = jnp.asarray(video_frames(h, w, 1, seed=11)[0])
        H = integral_histogram(img, bins, backend="jnp")
        n_win = ((h - window[0]) // stride + 1) * ((w - window[1]) // stride + 1)
        fns = {
            impl: jax.jit(functools.partial(
                sliding_window_histograms, window=window, stride=stride,
                impl=impl))
            for impl in ("gather", "slice")
        }
        # Interleave the two implementations and keep the per-impl min:
        # back-to-back same-impl medians are hostage to machine-load drift
        # on shared hosts, which would drown the comparison in noise.
        iters = 1 if common.SMOKE else (3 if quick else 9)
        best = {}
        for impl, fn in fns.items():
            jax.block_until_ready(fn(H))             # compile + warm
            best[impl] = float("inf")
        for _ in range(iters):
            for impl, fn in fns.items():
                t0 = time.perf_counter()
                jax.block_until_ready(fn(H))
                best[impl] = min(best[impl], time.perf_counter() - t0)
        wps = {impl: n_win / best[impl] for impl in fns}
        # First-call latency: fresh jit per sample (distinct window sizes,
        # like multi_scale_search compiling one variant per scale).
        first = {}
        n_first = 1 if common.SMOKE else 3
        for impl in fns:
            samples = []
            for k in range(n_first):
                fn = jax.jit(functools.partial(
                    sliding_window_histograms,
                    window=(window[0] + 1 + k, window[1]), stride=stride,
                    impl=impl))
                t0 = time.perf_counter()
                jax.block_until_ready(fn(H))
                samples.append(time.perf_counter() - t0)
            first[impl] = sorted(samples)[len(samples) // 2]
        rows.append([f"{h}x{w}x{bins}", f"s={stride}", f"{n_win}",
                     f"{wps['gather']:.3g}", f"{wps['slice']:.3g}",
                     f"{wps['slice'] / wps['gather']:.2f}x",
                     f"{first['gather']*1e3:.0f}", f"{first['slice']*1e3:.0f}",
                     f"{first['gather'] / first['slice']:.2f}x"])
    return rows


def _tracker_rows(quick: bool) -> list:
    n_frames = 12 if quick else 32
    cases = [(128, 128, 1), (128, 128, 4)]
    if not quick:
        cases.append((240, 320, 4))
    rows = []
    for h, w, n_targets in cases:
        frames = video_frames(h, w, n_frames + 1, seed=5)
        tracker = FragmentTracker(TrackerConfig(num_bins=16, search_radius=8))
        size = min(h, w) // 4
        starts = np.stack([
            [r, c, r + size - 1, c + size - 1]
            for r, c in zip(
                np.linspace(4, h - size - 4, n_targets).astype(int),
                np.linspace(4, w - size - 4, n_targets).astype(int))
        ])
        bbox = starts[0] if n_targets == 1 else starts
        state0 = tracker.init(jnp.asarray(frames[0]), bbox)
        clip = frames[1:]

        def step_loop():
            st = state0
            for f in clip:
                st = tracker.step(st, jnp.asarray(f))
            return st["bbox"]

        def track_clip():
            _, boxes = tracker.track(state0, clip)     # batch_size="auto"
            return boxes

        t_loop = time_fn(step_loop, warmup=1, iters=2 if quick else 3)
        t_track = time_fn(track_clip, warmup=1, iters=2 if quick else 3)
        fps_loop = n_frames / t_loop["median_s"]
        fps_track = n_frames / t_track["median_s"]
        rows.append([f"{h}x{w}", f"t={n_targets}",
                     f"{fps_loop:.2f}", f"{fps_track:.2f}",
                     f"{fps_track / fps_loop:.2f}x"])
    return rows


def run(quick: bool = False) -> str:
    win = fmt_table(
        ["frame", "stride", "windows", "gather w/s", "slice w/s",
         "w/s ratio", "gather 1st ms", "slice 1st ms", "1st ratio"],
        _windows_rows(quick))
    trk = fmt_table(
        ["frame", "targets", "step-loop fps", "track() fps", "speedup"],
        _tracker_rows(quick))
    return ("sliding-window histograms: windows/sec by implementation\n"
            + win
            + "\n\ntracker: frames/sec, per-frame step loop vs batched track()\n"
            + trk)


if __name__ == "__main__":
    print(run())
