"""Query-fused corner rows vs banded streaming (ISSUE 8 acceptance).

The workload the fused path exists for: a handful of region queries
whose corner rows all sit in the top quarter of the frame, under a
memory budget that would otherwise force band streaming.  The banded
path must still scan EVERY band (the scan's carry runs top to bottom
and the stream only retires bands, it cannot stop early for a query it
never sees); the fused path stops at the band holding the last
requested row AND writes only the K-row slab.  Same budget, same
queries — fused should win on time and, provably, on bytes.
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from benchmarks import common
from benchmarks.common import fmt_table, time_fn
from repro.core.engine import HistogramEngine, RegionQuery
from repro.data import video_frames
from repro.kernels.ops import fused_corner_rows


def run(quick: bool = False) -> str:
    h, w = (256, 160) if quick else (768, 320)
    bins = 16
    frame = np.asarray(video_frames(h, w, 1, seed=8)[0])
    # corner rows confined to the top quarter: the early-exit case
    rects = np.array([[8, 8, 40, 40],
                      [16, 24, 56, 80],
                      [4, 4, h // 4 - 2, w - 8]])
    rows = np.unique(np.r_[rects[:, 0] - 1, rects[:, 2]])
    rows = rows[rows >= 0]
    queries = [RegionQuery(rects)]
    budget = 4 * bins * (h // 8) * w        # 8 bands — forces banding

    banded = HistogramEngine(bins, backend="jnp",
                             memory_budget_bytes=budget)
    # same budget: the fused slab must also fit under it (it does — the
    # planner checks), so the comparison is like for like
    fused = HistogramEngine(bins, backend="jnp",
                            memory_budget_bytes=budget)

    def run_banded():
        # pin the banded plan by planning WITHOUT query rows (the
        # pre-fusion behavior: plan first, see the queries later)
        p = banded.plan_for(frame)
        src = banded.compute(frame, p)
        return [q.apply(src) for q in queries]

    def run_fused():
        return fused.run(frame, queries).results

    r_banded = run_banded()
    out_fused = fused.run(frame, queries)
    r_fused = out_fused.results
    for a, b in zip(r_banded, r_fused):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert out_fused.plan.representation == "fused", \
        out_fused.plan.representation

    t_banded = time_fn(run_banded, label="banded stream + query")
    t_fused = time_fn(run_fused, label="fused corner rows")

    stats: dict = {}
    fused_corner_rows(jnp.asarray(frame), bins, rows, backend="jnp",
                      stats=stats)
    full_h = stats["full_h_bytes"]
    slab = stats["rows_bytes"]

    out = [fmt_table(
        ["path", "median ms", "min ms", "H bytes touched"],
        [["banded (all bands stream)",
          f"{t_banded['median_s'] * 1e3:.2f}",
          f"{t_banded['min_s'] * 1e3:.2f}", f"{full_h}"],
         ["fused (corner rows only)",
          f"{t_fused['median_s'] * 1e3:.2f}",
          f"{t_fused['min_s'] * 1e3:.2f}", f"{slab}"]])]
    speedup = t_banded["median_s"] / t_fused["median_s"]
    out.append(
        f"fused vs banded: {speedup:.2f}x on time; "
        f"{stats['bands_computed']}/{stats['bands_total']} bands "
        f"computed; slab {slab} B vs full H {full_h} B "
        f"({full_h / slab:.0f}x less memory)")

    # the acceptance bar: H never materialized, and the fused path is
    # not slower than streaming every band (robust margin outside smoke)
    assert slab * 8 <= full_h
    assert stats["bands_computed"] < stats["bands_total"]
    if not common.SMOKE:
        assert t_fused["median_s"] < t_banded["median_s"], \
            "fused path slower than banded on its own workload"
    return "\n".join(out)


if __name__ == "__main__":
    print(run())
