"""Serving throughput: `AnalyticsService` requests/sec under query traffic.

Closed-loop load against a synthetic frame store with a skewed (hot-set)
frame popularity — the video-analytics serving shape: many queries land
on few recent frames.  Two sweeps:

  * in-flight depth — how many submits are outstanding before the caller
    blocks on a future (1 = fully synchronous request/response); the
    worker drains whatever accumulated, so depth is also the coalescing
    opportunity;
  * HSource cache on vs off — repeated queries on a hot frame skip the H
    computation entirely on a hit.

Reported: requests/sec, cache hit rate, coalesced share, engine runs per
request, the share of runs served by an incremental video-delta update
(the hot frames here are regenerated independently, so the update ratio
is 0 unless the store is a low-motion stream), p95 latency.
"""

from __future__ import annotations

import collections
import json
import os
import subprocess
import sys
import textwrap
import time

import jax
import numpy as np

from benchmarks import common
from benchmarks.common import fmt_table
from repro.core import distances
from repro.core.engine import HistogramEngine, LikelihoodQuery, RegionQuery
from repro.data import video_frames
from repro.serve import AnalyticsService


def _requests(num_requests: int, num_frames: int, hot: int, seed: int):
    """(frame_ref, query) load: 80% of traffic on the `hot` newest frames."""
    rng = np.random.default_rng(seed)
    target = np.ones(16, np.float32)
    reqs = []
    for i in range(num_requests):
        if rng.random() < 0.8:
            ref = int(num_frames - 1 - rng.integers(0, hot))
        else:
            ref = int(rng.integers(0, num_frames))
        if i % 3 == 2:
            q = LikelihoodQuery(target, (24, 24), distances.intersection,
                                stride=8)
        else:
            r0, c0 = int(rng.integers(0, 40)), int(rng.integers(0, 40))
            q = RegionQuery(np.array([r0, c0, r0 + 23, c0 + 23]))
        reqs.append((ref, q))
    return reqs


def _drive(svc: AnalyticsService, reqs, depth: int) -> float:
    """Closed loop with `depth` submits outstanding; returns seconds.

    A resolved future may still hold lazy device arrays, so the elapsed
    time is taken only after blocking on every answer — otherwise this
    times dispatch, not compute (the host-sync/timing rule the linter
    enforces for the kernels applies to benchmarks by hand)."""
    t0 = time.perf_counter()
    inflight: collections.deque = collections.deque()
    outs = []
    with svc:
        for ref, q in reqs:
            inflight.append(svc.submit(ref, q, block=True))
            if len(inflight) >= depth:
                outs.append(inflight.popleft().result())
        while inflight:
            outs.append(inflight.popleft().result())
        jax.block_until_ready(outs)
    return time.perf_counter() - t0


# Mesh-scale curve (ISSUE 10): the same closed-loop traffic against
# DistributedAnalyticsService at 1/2/4/8 forced host devices.  Each point
# runs in a subprocess so the device count can differ per point without
# disturbing the parent's single-device view; the subprocess reports one
# `RESULT {json}` line with the wall time and a digest of every answer,
# and the parent asserts the multi-device digests match the single-device
# baseline (bit-exactness is the acceptance bar, throughput is the curve).
_SCALE_BODY = r"""
import hashlib, json, time, warnings
warnings.filterwarnings("ignore")
import numpy as np, jax

from repro.core import distances
from repro.core.engine import HistogramEngine, LikelihoodQuery, RegionQuery
from repro.data import video_frames
from repro.serve import (AnalyticsService, DistributedAnalyticsService,
                         sharded_engine_factory)

ndev = __NDEV__
smoke = __SMOKE__
assert len(jax.devices()) == ndev, (ndev, jax.devices())

n_req = 48 if smoke else 240
n_cams, per_cam = (4, 4) if smoke else (8, 8)
h, w, bins = (96, 128, 16) if smoke else (240, 320, 16)

# Independent camera streams: string refs do not chain (no predecessor),
# so the consistent-hash router spreads them across replica groups.
frames = {}
for cam in range(n_cams):
    for i, f in enumerate(video_frames(h, w, per_cam, seed=100 + cam)):
        frames[f"cam{cam}/{i}"] = f
refs = sorted(frames)
rng = np.random.default_rng(3)
target = np.ones(bins, np.float32)
reqs = []
for i in range(n_req):
    ref = refs[int(rng.integers(0, len(refs)))]
    if i % 3 == 2:
        q = LikelihoodQuery(target, (24, 24), distances.intersection,
                            stride=8)
    else:
        r0, c0 = int(rng.integers(0, 40)), int(rng.integers(0, 40))
        q = RegionQuery(np.array([r0, c0, r0 + 23, c0 + 23]))
    reqs.append((ref, q))

if ndev == 1:
    svc = AnalyticsService(HistogramEngine(bins, backend="jnp"), frames,
                           cache_size=8, max_pending=256)
else:
    shape = {2: (1, 2), 4: (1, 4), 8: (2, 4)}[ndev]
    mesh = jax.make_mesh(shape, ("data", "model"))
    svc = DistributedAnalyticsService(
        sharded_engine_factory(bins, backend="jnp"), frames,
        mesh=mesh, replica_axis="data", cache_size=8, max_pending=256)

svc.process(reqs[:2])  # warm the XLA compile cache
svc.clear_cache()
t0 = time.perf_counter()
outs = svc.process(reqs)
jax.block_until_ready(outs)
wall = time.perf_counter() - t0

digest = hashlib.blake2b(digest_size=16)
for out in outs:
    for leaf in jax.tree_util.tree_leaves(out):
        digest.update(np.ascontiguousarray(np.asarray(leaf)).tobytes())
print("RESULT " + json.dumps({"ndev": ndev, "wall_s": wall,
                              "req_s": n_req / wall,
                              "digest": digest.hexdigest()}))
"""

_SCALE_LAYOUT = {1: "single device (plain service)",
                 2: "1 group x 2-way bins",
                 4: "1 group x 4-way bins",
                 8: "2 groups x 4-way bins"}


def _scale_curve(smoke: bool) -> str:
    """req/s vs forced host device count; asserts answers stay bit-exact."""
    rows = []
    digests: dict[int, str] = {}
    for ndev in (1, 2, 4, 8):
        env = dict(os.environ,
                   XLA_FLAGS=f"--xla_force_host_platform_device_count={ndev}",
                   PYTHONPATH=os.environ.get("PYTHONPATH", "src"))
        code = (_SCALE_BODY.replace("__NDEV__", str(ndev))
                .replace("__SMOKE__", repr(smoke)))
        proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                              env=env, capture_output=True, text=True,
                              timeout=900)
        if proc.returncode != 0:
            raise RuntimeError(
                f"scale point ndev={ndev} failed:\n{proc.stderr[-2000:]}")
        res = next(json.loads(line[len("RESULT "):])
                   for line in proc.stdout.splitlines()
                   if line.startswith("RESULT "))
        digests[ndev] = res["digest"]
        exact = res["digest"] == digests[1]
        if not exact:
            raise AssertionError(
                f"ndev={ndev} answers diverge from the single-device "
                f"baseline ({res['digest']} != {digests[1]})")
        common.TIMINGS.append({
            "median_s": res["wall_s"], "min_s": res["wall_s"], "iters": 1,
            "label": f"serve_scale_ndev{ndev}",
        })
        rows.append([ndev, _SCALE_LAYOUT[ndev], f"{res['req_s']:.1f}",
                     f"{res['wall_s'] * 1e3:.0f} ms",
                     "yes" if exact else "NO"])
    return fmt_table(
        ["devices", "replica x shard layout", "req/s", "wall",
         "bit-exact vs 1 dev"], rows)


def run(quick: bool = False) -> str:
    n_req = 60 if (quick or common.SMOKE) else 400
    n_frames, hot = (8, 2) if (quick or common.SMOKE) else (32, 4)
    h, w, bins = (96, 128, 16) if (quick or common.SMOKE) else (240, 320, 16)
    store = {i: f for i, f in enumerate(video_frames(h, w, n_frames, seed=7))}

    rows = []
    for depth in (1, 4, 16):
        for cache in (0, 8):
            reqs = _requests(n_req, n_frames, hot, seed=depth)
            svc = AnalyticsService(
                HistogramEngine(bins, backend="jnp"), store,
                cache_size=cache, max_pending=max(depth * 2, 4),
            )
            # warm the XLA compile cache, then start the measurement
            # cold: clear the HSource cache so hit rates are earned by
            # the measured traffic, not the warm-up
            svc.process(reqs[:2])
            svc.clear_cache()
            svc.stats = type(svc.stats)()
            dt = _drive(svc, reqs, depth)
            common.TIMINGS.append({
                "median_s": dt, "min_s": dt, "iters": 1,
                "label": f"serve_depth{depth}_cache{cache}",
            })
            s = svc.stats.snapshot()
            rows.append([
                depth, "on" if cache else "off",
                f"{n_req / dt:.1f}",
                f"{100 * s['cache_hit_rate']:.0f}%",
                f"{100 * s['coalesced'] / max(s['requests'], 1):.0f}%",
                f"{s['engine_runs'] / max(s['requests'], 1):.2f}",
                f"{100 * s['update_ratio']:.0f}%",
                f"{1e3 * s['latency_p95_s']:.1f}",
            ])
    out = fmt_table(
        ["depth", "cache", "req/s", "hit rate", "coalesced",
         "runs/req", "updated", "p95 ms"],
        rows,
    )
    out += ("\n\nmesh scaling (host 'devices' share one CPU core, so "
            "req/s is about\ncorrectness of the sharded path under load, "
            "not real speedup):\n")
    out += _scale_curve(quick or common.SMOKE)
    return out


if __name__ == "__main__":
    print(run())
