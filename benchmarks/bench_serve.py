"""Serving throughput: `AnalyticsService` requests/sec under query traffic.

Closed-loop load against a synthetic frame store with a skewed (hot-set)
frame popularity — the video-analytics serving shape: many queries land
on few recent frames.  Two sweeps:

  * in-flight depth — how many submits are outstanding before the caller
    blocks on a future (1 = fully synchronous request/response); the
    worker drains whatever accumulated, so depth is also the coalescing
    opportunity;
  * HSource cache on vs off — repeated queries on a hot frame skip the H
    computation entirely on a hit.

Reported: requests/sec, cache hit rate, coalesced share, engine runs per
request, the share of runs served by an incremental video-delta update
(the hot frames here are regenerated independently, so the update ratio
is 0 unless the store is a low-motion stream), p95 latency.
"""

from __future__ import annotations

import collections
import time

import jax
import numpy as np

from benchmarks import common
from benchmarks.common import fmt_table
from repro.core import distances
from repro.core.engine import HistogramEngine, LikelihoodQuery, RegionQuery
from repro.data import video_frames
from repro.serve import AnalyticsService


def _requests(num_requests: int, num_frames: int, hot: int, seed: int):
    """(frame_ref, query) load: 80% of traffic on the `hot` newest frames."""
    rng = np.random.default_rng(seed)
    target = np.ones(16, np.float32)
    reqs = []
    for i in range(num_requests):
        if rng.random() < 0.8:
            ref = int(num_frames - 1 - rng.integers(0, hot))
        else:
            ref = int(rng.integers(0, num_frames))
        if i % 3 == 2:
            q = LikelihoodQuery(target, (24, 24), distances.intersection,
                                stride=8)
        else:
            r0, c0 = int(rng.integers(0, 40)), int(rng.integers(0, 40))
            q = RegionQuery(np.array([r0, c0, r0 + 23, c0 + 23]))
        reqs.append((ref, q))
    return reqs


def _drive(svc: AnalyticsService, reqs, depth: int) -> float:
    """Closed loop with `depth` submits outstanding; returns seconds.

    A resolved future may still hold lazy device arrays, so the elapsed
    time is taken only after blocking on every answer — otherwise this
    times dispatch, not compute (the host-sync/timing rule the linter
    enforces for the kernels applies to benchmarks by hand)."""
    t0 = time.perf_counter()
    inflight: collections.deque = collections.deque()
    outs = []
    with svc:
        for ref, q in reqs:
            inflight.append(svc.submit(ref, q, block=True))
            if len(inflight) >= depth:
                outs.append(inflight.popleft().result())
        while inflight:
            outs.append(inflight.popleft().result())
        jax.block_until_ready(outs)
    return time.perf_counter() - t0


def run(quick: bool = False) -> str:
    n_req = 60 if (quick or common.SMOKE) else 400
    n_frames, hot = (8, 2) if (quick or common.SMOKE) else (32, 4)
    h, w, bins = (96, 128, 16) if (quick or common.SMOKE) else (240, 320, 16)
    store = {i: f for i, f in enumerate(video_frames(h, w, n_frames, seed=7))}

    rows = []
    for depth in (1, 4, 16):
        for cache in (0, 8):
            reqs = _requests(n_req, n_frames, hot, seed=depth)
            svc = AnalyticsService(
                HistogramEngine(bins, backend="jnp"), store,
                cache_size=cache, max_pending=max(depth * 2, 4),
            )
            # warm the XLA compile cache, then start the measurement
            # cold: clear the HSource cache so hit rates are earned by
            # the measured traffic, not the warm-up
            svc.process(reqs[:2])
            svc.clear_cache()
            svc.stats = type(svc.stats)()
            dt = _drive(svc, reqs, depth)
            common.TIMINGS.append({
                "median_s": dt, "min_s": dt, "iters": 1,
                "label": f"serve_depth{depth}_cache{cache}",
            })
            s = svc.stats.snapshot()
            rows.append([
                depth, "on" if cache else "off",
                f"{n_req / dt:.1f}",
                f"{100 * s['cache_hit_rate']:.0f}%",
                f"{100 * s['coalesced'] / max(s['requests'], 1):.0f}%",
                f"{s['engine_runs'] / max(s['requests'], 1):.2f}",
                f"{100 * s['update_ratio']:.0f}%",
                f"{1e3 * s['latency_p95_s']:.1f}",
            ])
    return fmt_table(
        ["depth", "cache", "req/s", "hit rate", "coalesced",
         "runs/req", "updated", "p95 ms"],
        rows,
    )


if __name__ == "__main__":
    print(run())
