"""Plan/execute engine overhead: the unified entry point must cost nothing.

ISSUE 4's acceptance bar: ``HistogramEngine`` replaces hand-routing
among seven entry points, so its planner must be invisible in the
timings.

  * part 1 — ``plan()`` in isolation: pure-Python microseconds per call
    (asserted orders of magnitude under one kernel dispatch).
  * part 2 — planner overhead on the request path: engine.run (plan ->
    compute -> query) vs the same compute + query hand-routed.  The
    delta must sit inside timing noise (asserted against the spread of
    the direct measurement itself outside smoke mode).
  * part 3 — end-to-end streaming: engine.map_frames (planner-chosen
    microbatch + double buffering) frames/sec vs the hand-routed PR 3
    pipeline (IntegralHistogram.map_frames + tracker step_on_h).
"""

from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks import common
from benchmarks.common import fmt_table, time_fn
from repro.core.engine import HistogramEngine, RegionQuery, plan
from repro.core.integral_histogram import IntegralHistogram
from repro.core.region_query import region_histogram
from repro.core.tracking import FragmentTracker, TrackerConfig
from repro.data import video_frames
from repro.kernels.ops import integral_histogram


def run(quick: bool = False) -> str:
    h, w = (120, 160) if quick else (240, 320)
    bins = 16
    n_frames = 8 if quick else 24
    frames = video_frames(h, w, n_frames, seed=21)
    img = frames[0]
    rects = jnp.asarray(
        np.array([[0, 0, h - 1, w - 1], [h // 4, w // 4,
                                         3 * h // 4, 3 * w // 4]]))
    out = []

    # -- part 1: the planner itself ---------------------------------------
    eng = HistogramEngine(bins, backend="jnp")
    spec = eng.spec_for((h, w))
    iters = 10 if common.SMOKE else 1000
    t0 = time.perf_counter()
    for _ in range(iters):
        plan(spec)
    plan_us = (time.perf_counter() - t0) / iters * 1e6
    out.append(f"plan() alone: {plan_us:.1f} us/call "
               f"({iters} calls, pure Python, no dispatch)")

    # -- part 2: engine.run vs hand-routed compute + query ------------------
    def direct():
        H = integral_histogram(jnp.asarray(img), bins, backend="jnp")
        return region_histogram(H, rects)

    def engined():
        return eng.run(img, [RegionQuery(rects)]).results[0]

    t_direct = time_fn(direct, label="direct compute+query")
    t_engine = time_fn(engined, label="engine.run compute+query")
    overhead = t_engine["median_s"] - t_direct["median_s"]
    noise = t_direct["median_s"] - t_direct["min_s"]
    out.append(fmt_table(
        ["path", "median ms", "min ms"],
        [["direct (hand-routed)", f"{t_direct['median_s'] * 1e3:.2f}",
          f"{t_direct['min_s'] * 1e3:.2f}"],
         ["engine.run", f"{t_engine['median_s'] * 1e3:.2f}",
          f"{t_engine['min_s'] * 1e3:.2f}"]]))
    out.append(f"planner overhead: {overhead * 1e3:+.3f} ms vs direct "
               f"(direct's own median-min spread: {noise * 1e3:.3f} ms)")
    # The acceptance assertion: planning is not a measurable cost.  The
    # plan is pure Python (~us); give it 10x the direct path's own
    # spread or 2 ms of slack, whichever is larger, so the assert survives
    # CI-runner jitter while still catching a dispatch-sized regression.
    if not common.SMOKE:
        assert plan_us < 1e4, f"plan() took {plan_us:.0f} us"
        assert overhead < max(10 * noise, 2e-3), (
            f"engine overhead {overhead * 1e3:.3f} ms exceeds noise "
            f"allowance {max(10 * noise, 2e-3) * 1e3:.3f} ms")

    # -- part 3: end-to-end streaming pipeline ------------------------------
    cfg = TrackerConfig(num_bins=bins, search_radius=6, backend="jnp")
    bbox = np.array([h // 3, w // 3, h // 3 + 31, w // 3 + 31])

    def hand_routed():
        ih = IntegralHistogram(num_bins=bins, backend="jnp")
        tracker = FragmentTracker(cfg)
        state = tracker.init(jnp.asarray(frames[0]), bbox)
        for H in ih.map_frames(frames, batch_size="auto"):
            state = tracker.step_on_h(state, H)
        return state["bbox"]

    def engine_driven():
        e = HistogramEngine(bins, backend="jnp")
        tracker = FragmentTracker(cfg, engine=e)
        state = tracker.init(jnp.asarray(frames[0]), bbox)
        for H in e.map_frames(frames):
            state = tracker.step_on_h(state, H)
        return state["bbox"]

    t_hand = time_fn(hand_routed, label="pipeline hand-routed")
    t_eng = time_fn(engine_driven, label="pipeline engine-driven")
    rows = [
        ["hand-routed (PR 3)", f"{n_frames / t_hand['median_s']:.1f}"],
        ["engine-driven", f"{n_frames / t_eng['median_s']:.1f}"],
    ]
    out.append(
        f"end-to-end tracker pipeline ({n_frames} frames of {h}x{w}, "
        f"{bins} bins)\n" + fmt_table(["pipeline", "frames/s"], rows))
    boxes_match = np.array_equal(np.asarray(hand_routed()),
                                 np.asarray(engine_driven()))
    assert boxes_match, "engine-driven pipeline diverged from hand-routed"
    out.append(f"final bboxes identical: {boxes_match} on {jax.devices()[0]}")
    return "\n\n".join(out)


if __name__ == "__main__":
    print(run())
