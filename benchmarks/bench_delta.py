"""Incremental video-delta H updates: fps vs dirty fraction (ISSUE 9).

A fixed-camera low-motion stream rewrites a contiguous block of rows per
frame; everything else is identical.  The incremental path
(core/delta.py) recomputes only the dirty bands and carry-corrects the
clean slabs below, so per-frame cost scales with the dirty fraction —
the compute-vs-reuse tradeoff of Ehsan et al. applied across time.  The
foil recomputes every frame's H from scratch through the same engine.

Reported per dirty fraction: end-to-end fps for both paths (the
incremental stream pays ONE full compute to seed the chain), the
speedup, and how many of the stream's plans actually took the update
(high-motion rows fall back — the 0.50 row shows the threshold working).

Outside smoke mode the 10%-dirty row must clear 3x end-to-end — the
acceptance floor for this path; parity of the final H is asserted on
every row regardless.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks import common
from benchmarks.common import fmt_table
from repro.core.engine import HistogramEngine


def _stream(h: int, w: int, n: int, dirty_rows: int, seed: int):
    """n frames; each rewrites `dirty_rows` rows of its predecessor at a
    random position (repro.data.video_frames regenerates whole frames, so
    low-motion streams are built here)."""
    rng = np.random.default_rng(seed)
    frames = [rng.integers(0, 256, (h, w), dtype=np.uint8)]
    for _ in range(n - 1):
        nxt = frames[-1].copy()
        if dirty_rows:
            r = int(rng.integers(0, h - dirty_rows + 1))
            nxt[r:r + dirty_rows] = rng.integers(
                0, 256, (dirty_rows, w), dtype=np.uint8)
        frames.append(nxt)
    return frames


def _best_of(fn, iters: int) -> float:
    fn()                                # warm the compile caches
    if common.SMOKE:
        iters = 1
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(quick: bool = False) -> str:
    if common.SMOKE:
        h, w, bins, n = 240, 320, 16, 4
        fractions = (0.10,)
    elif quick:
        h, w, bins, n = 480, 640, 32, 12
        fractions = (0.10, 0.50)
    else:
        h, w, bins, n = 480, 640, 32, 24
        fractions = (0.02, 0.10, 0.25, 0.50)
    iters = 2 if quick else 3
    eng = HistogramEngine(bins, backend="jnp")

    rows = []
    for df in fractions:
        dirty_rows = max(1, int(df * h))
        frames = _stream(h, w, n, dirty_rows, seed=3)
        last = {}

        def full_pass():
            outs = [eng.run(f).source.H for f in frames]
            jax.block_until_ready(outs)
            last["full"] = outs[-1]

        def inc_pass():
            outs, prev, updated = [], None, 0
            for f in frames:
                out = eng.run(f, prev=prev)
                updated += bool(out.plan.incremental)
                outs.append(out.source.H)
                prev = (f, out.source)
            jax.block_until_ready(outs)
            last["inc"] = outs[-1]
            last["updated"] = updated

        t_full = _best_of(full_pass, iters)
        t_inc = _best_of(inc_pass, iters)
        for label, t in (("full", t_full), ("inc", t_inc)):
            common.TIMINGS.append({
                "median_s": t, "min_s": t, "iters": iters,
                "label": f"delta_{label}_df{int(100 * df):02d}",
            })
        # bit-exact: the delta-updated chain ends on the same H
        np.testing.assert_array_equal(np.asarray(last["inc"]),
                                      np.asarray(last["full"]))
        speedup = t_full / t_inc
        rows.append([
            f"{df:.2f}", f"{last['updated']}/{n}",
            f"{n / t_full:.1f}", f"{n / t_inc:.1f}", f"{speedup:.2f}x",
        ])
        if not common.SMOKE and abs(df - 0.10) < 1e-9:
            assert speedup >= 3.0, (
                f"incremental path {speedup:.2f}x at 10% dirty — "
                "below the 3x acceptance floor")
    return fmt_table(
        ["dirty", "updated", "full fps", "inc fps", "speedup"], rows)


if __name__ == "__main__":
    print(run())
