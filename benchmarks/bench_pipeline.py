"""Paper Fig. 13/15: frame rate with and without dual-buffering.

DoubleBufferedExecutor(depth=1) is the synchronous baseline;
depth=2 overlaps host staging + async dispatch with computation —
the XLA analogue of the paper's two CUDA streams."""

from __future__ import annotations

import functools
import time

import jax

from benchmarks.common import fmt_table
from repro.core.pipeline import DoubleBufferedExecutor
from repro.data import video_frames
from repro.kernels.ops import integral_histogram


def _frame_rate(fn, frames, depth: int) -> float:
    ex = DoubleBufferedExecutor(fn, depth=depth)
    list(ex.map(frames[:2]))                      # warmup/compile
    t0 = time.perf_counter()
    for _ in ex.map(frames):
        pass
    return len(frames) / (time.perf_counter() - t0)


def run(quick: bool = False) -> str:
    rows = []
    n = 12 if quick else 40
    cases = [((720, 1280), 16), ((720, 1280), 32)]
    if not quick:
        cases += [((480, 640), 32), ((512, 512), 32)]
    for (h, w), bins in cases:
        frames = list(video_frames(h, w, n, seed=1))
        fn = jax.jit(functools.partial(
            integral_histogram, num_bins=bins, method="wf_tis",
            backend="jnp"))
        f1 = _frame_rate(fn, frames, depth=1)
        f2 = _frame_rate(fn, frames, depth=2)
        f3 = _frame_rate(fn, frames, depth=3)
        rows.append([f"{h}x{w}", bins, f"{f1:.2f}", f"{f2:.2f}",
                     f"{f3:.2f}", f"{f2/f1:.2f}x"])
    return fmt_table(
        ["frame", "bins", "sync fr/s", "double-buf fr/s",
         "triple-buf fr/s", "overlap gain"], rows)


if __name__ == "__main__":
    print(run())
