"""Pallas TPU kernel for the Mamba-2 SSD chunked scan (G=1 groups).

This is the WF-TiS pattern (kernels/wf_tis.py) transplanted to the model
zoo's hot spot: the sequence is tiled into chunks; each grid step
computes the intra-chunk quadratic form on the MXU and carries the
(state, decay) boundary summary in VMEM scratch across the sequential
TPU grid — exactly the tiled-scan-plus-carry structure of the paper,
with the SSD state playing the role of the column carry.

Grid: (B, H, num_chunks), chunks innermost (carry resets at chunk 0).
Math (fp32):  h_t = exp(a_t) h_{t-1} + B_t (dt x)_t^T ;  y_t = C_t h_t.

ref: models/ssm.ssd_chunked (pure jnp oracle, tested allclose).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None


def _tril_ones(q: int, dtype=jnp.float32):
    r = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    c = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    return (r >= c).astype(dtype)


def _ssd_kernel(a_ref, xdt_ref, b_ref, c_ref, y_ref, state, logdec):
    ci = pl.program_id(2)

    a = a_ref[0, 0, :]                                   # (Q,)
    xdt = xdt_ref[0, 0]                                  # (Q, P)
    Bq = b_ref[0]                                        # (Q, N)
    Cq = c_ref[0]                                        # (Q, N)
    q = a.shape[0]

    # intra-chunk cumulative log-decay via MXU triangular matmul
    tril = _tril_ones(q)
    a_cum = jnp.dot(tril, a, preferred_element_type=jnp.float32)   # (Q,)
    total = a_cum[-1]

    # decay mask L[i, j] = exp(a_cum_i - a_cum_j), j <= i
    L = jnp.where(tril > 0, jnp.exp(a_cum[:, None] - a_cum[None, :]), 0.0)
    scores = jnp.dot(Cq, Bq.T, preferred_element_type=jnp.float32)  # (Q,Q)
    y_intra = jnp.dot(scores * L, xdt,
                      preferred_element_type=jnp.float32)           # (Q,P)

    # carried state from previous chunks (reset at chunk 0)
    h_prev = jnp.where(ci == 0, 0.0, state[...])                    # (N,P)
    y_inter = jnp.dot(Cq, h_prev,
                      preferred_element_type=jnp.float32)
    y_inter = y_inter * jnp.exp(a_cum)[:, None]

    # boundary carry: decayed old state + this chunk's contribution
    decay_out = jnp.exp(total - a_cum)                              # (Q,)
    h_new = jnp.exp(total) * h_prev + jnp.dot(
        Bq.T, xdt * decay_out[:, None],
        preferred_element_type=jnp.float32)
    state[...] = h_new
    logdec[0] = total

    y_ref[0, 0] = y_intra + y_inter


def ssd_scan_pallas(a, xdt, Bm, Cm, *, chunk: int = 128,
                    interpret: bool = False):
    """SSD scan. a: (B,H,S) log-decays; xdt: (B,H,S,P); Bm/Cm: (B,S,N).

    Returns y: (B, H, S, P) fp32.  S must be a multiple of `chunk`
    (pad with a=0, xdt=0 upstream — identity steps).
    """
    b, h, s = a.shape
    p = xdt.shape[-1]
    n = Bm.shape[-1]
    if s % chunk:
        raise ValueError(f"S={s} not divisible by chunk={chunk}")
    nc = s // chunk

    grid = (b, h, nc)
    return pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk), lambda bi, hi, ci: (bi, hi, ci)),
            pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, p),
                               lambda bi, hi, ci: (bi, hi, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, p), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((n, p), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        interpret=interpret,
    )(a, xdt, Bm, Cm)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 128, interpret: bool = False):
    """ops-style wrapper matching models/ssm.ssd_chunked's signature.

    x: (B,S,H,P); dt: (B,S,H); A: (H,); Bm/Cm: (B,S,G=1,N).
    Returns y (B,S,H,P) fp32.
    """
    a = jnp.swapaxes(dt * A, 1, 2)                     # (B,H,S)
    xdt = jnp.moveaxis(x * dt[..., None], 2, 1)        # (B,H,S,P)
    y = ssd_scan_pallas(a.astype(jnp.float32), xdt.astype(jnp.float32),
                        Bm[:, :, 0].astype(jnp.float32),
                        Cm[:, :, 0].astype(jnp.float32),
                        chunk=chunk, interpret=interpret)
    return jnp.moveaxis(y, 1, 2)                       # (B,S,H,P)
