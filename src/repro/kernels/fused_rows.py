"""Query-fused WF-TiS: emit ONLY the requested corner rows — H never
exists in HBM.

Eq. 2 answers every region/window query from corner *rows* of the
integral histogram, and Ehsan et al.'s embedded integral-image work
(arXiv:1510.05138, 1510.05142) makes the compute-vs-store decision
explicit: when the rows a request reads are small relative to H, storing
H at all is waste.  This kernel is the compute side of that decision —
the limit case of the paper's §4.6 memory-budget problem, where the
budget drops to the corner-row slab itself.

The scan is ``wf_tis.py``'s raster walk unchanged: grid
``(f, ih, iw, bb)`` bins innermost, row/column carries in VMEM scratch,
the band carry-in seeding the column scan at ``ih == 0``.  The one
change is the output stage.  Each tile's post-scan block ``vs`` already
IS the final H restricted to the tile (every dependency is an earlier
raster step), so instead of writing ``vs`` to an (n, b, h, w) output,
the kernel projects out the requested rows with a one-hot selection
matmul:

    sel[j, o] = 1  iff  slot j of this strip requests tile row o
    out[b, j, :] = sum_o sel[j, o] * vs[b, o, :]        (MXU, like the
                                                         scan matmuls)

``slots`` is a host-built (nth, kp) int32 table: for each tile-row
strip, the in-strip offsets of its requested rows, padded with -1
(matches no row, contributes zeros).  ``kp`` — the emission width — is
the max rows any strip requests, padded to a sublane multiple of 8.
The output is ``(n, nb_pad, nth * kp, w_pad)``: one kp-row slab per
strip, written exactly once per grid step (the coverage discipline the
dense kernel has), gathered back to request order on the host by the
``pos`` indices ``slot_plan`` returns.

HBM traffic drops from (1/b read + 1 write of b*h*w) to
(1/b read + kp/tile write); peak device memory for the result is the
corner-row slab, not H.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

try:  # TPU-specific pallas helpers; interpret mode works without a TPU.
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

from repro.kernels.specs import (
    FusedRowsGeometry,
    KernelGeometry,
    KernelSpec,
    Operand,
    Scratch,
)
from repro.kernels.wf_tis import _col_scan_mxu, _row_scan_mxu

#: default emission width when a geometry declares none (the
#: ``--check-kernels`` sweep runs plain KernelGeometry through here).
DEFAULT_KP = 8

#: fp32 sublane multiple the emission width is padded to.
_SUBLANE = 8


def slot_plan(row_ids, tile: int, height: int):
    """Host-side layout of requested rows onto per-strip emission slots.

    Args:
      row_ids: sorted unique frame rows in ``[0, height)``.
      tile: strip height (the kernel's tile size).
      height: logical frame height (pre-padding).

    Returns:
      ``(slots, kp, pos)`` — ``slots`` is the (nth, kp) int32 table of
      in-strip row offsets (-1 = empty slot), ``kp`` the padded emission
      width, and ``pos`` the (K,) indices into the flattened
      ``nth * kp`` output axis that recover the rows in request order.
    """
    # analysis: allow-host-sync(row ids are host-side request metadata, never device data)
    rows = np.asarray(row_ids, np.int64)
    if rows.size and (np.any(np.diff(rows) <= 0) or rows[0] < 0
                      or rows[-1] >= height):
        raise ValueError(
            f"row_ids must be sorted unique within [0, {height}), got "
            f"{rows.tolist()[:8]}...")
    nth = -(-height // tile)
    strips = rows // tile
    per_strip = np.bincount(strips, minlength=nth) if rows.size else \
        np.zeros(nth, np.int64)
    kp = max(int(per_strip.max(initial=0)), 1)
    kp = -(-kp // _SUBLANE) * _SUBLANE
    slots = np.full((nth, kp), -1, np.int32)
    pos = np.empty(rows.size, np.int64)
    fill = np.zeros(nth, np.int64)
    for i, (s, r) in enumerate(zip(strips, rows)):
        j = fill[s]
        slots[s, j] = r % tile
        pos[i] = s * kp + j
        fill[s] += 1
    return slots, kp, pos


def kernel_specs(geom: KernelGeometry) -> tuple[KernelSpec, ...]:
    """The declarative contract of ``fused_rows_pallas``'s one
    ``pallas_call`` (verified by ``repro.analysis.kernelcheck``; the
    conformance test in tests/test_fused.py pins it against the live
    call).

    The grid and carry edges are ``wf_tis.kernel_specs`` verbatim — the
    scan is the same wavefront.  What changes is the out-spec: block
    ``(1, bin_block, kp, tile)`` at index ``(f, bb, ih, iw)`` into the
    ``(n, nb_pad, nth * kp, w_pad)`` row-slab output (exactly-once
    coverage, like the dense kernel), plus the per-strip ``slots`` table
    as a third input broadcast over ``iw``/``bb``.
    """
    kp = getattr(geom, "kp", DEFAULT_KP)
    n, nth, ntw, nbb = geom.n, geom.nth, geom.ntw, geom.nbb
    t, bb_blk = geom.tile, geom.bin_block
    hp, wp, nbp = geom.h_pad, geom.w_pad, geom.nb_pad

    def reads(g):
        edges = []
        if g["iw"] > 0:     # row carry from the tile to the left
            edges.append(
                (("row", g["bb"]), {**g, "iw": g["iw"] - 1}))
        if g["ih"] > 0:     # column carry from the strip above
            edges.append(
                (("col", g["bb"], g["iw"]), {**g, "ih": g["ih"] - 1}))
        return edges

    def writes(g):
        return [("row", g["bb"]), ("col", g["bb"], g["iw"])]

    return (
        KernelSpec(
            name="fused_rows",
            grid=(("f", n), ("ih", nth), ("iw", ntw), ("bb", nbb)),
            in_specs=(
                Operand("idx", (n, hp, wp), (1, t, t),
                        lambda f, ih, iw, bb: (f, ih, iw), dtype="int32"),
                Operand("carry", (n, nbp, wp), (1, bb_blk, t),
                        lambda f, ih, iw, bb: (f, bb, iw)),
                Operand("slots", (nth, kp), (1, kp),
                        lambda f, ih, iw, bb: (ih, 0), dtype="int32"),
            ),
            out_specs=(
                Operand("rows", (n, nbp, nth * kp, wp), (1, bb_blk, kp, t),
                        lambda f, ih, iw, bb: (f, bb, ih, iw)),
            ),
            scratch=(
                Scratch("row_carry", (nbb, bb_blk, t)),
                Scratch("col_carry", (nbb, bb_blk, wp)),
            ),
            carry_reads=reads,
            carry_writes=writes,
        ),
    )


def _select_rows_mxu(sel: jnp.ndarray, vs: jnp.ndarray) -> jnp.ndarray:
    """out[b, j, :] = sum_o sel[j, o] * vs[b, o, :] — the one-hot row
    gather as a batched MXU matmul (same shape discipline as the scan's
    ``_col_scan_mxu``; dynamic sublane gathers are not a TPU primitive,
    a 0/1 matmul is)."""
    b = vs.shape[0]
    sel_b = jnp.broadcast_to(sel, (b,) + sel.shape)
    return jax.lax.dot_general(
        sel_b,
        vs,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )


def _fused_rows_kernel(
    idx_ref,      # (1, TH, TW) int32 bin indices (PAD_BIN outside the image)
    carry_ref,    # (1, BIN_BLOCK, TW) fp32 band carry-in (zeros = frame top)
    slots_ref,    # (1, KP) int32 in-strip offsets of emitted rows (-1 empty)
    out_ref,      # (1, BIN_BLOCK, KP, TW) fp32 emitted corner rows
    row_carry,    # VMEM scratch (NBB, BIN_BLOCK, TH) — right-edge carries
    col_carry,    # VMEM scratch (NBB, BIN_BLOCK, W_PAD) — bottom-edge carries
    *,
    bin_block: int,
    tile_w: int,
    use_mxu: bool,
):
    ih = pl.program_id(1)
    iw = pl.program_id(2)
    bb = pl.program_id(3)

    idx = idx_ref[0]
    th, tw = idx.shape

    # ---- the WF-TiS scan, unchanged from kernels/wf_tis.py ----
    bin_ids = bb * bin_block + jax.lax.broadcasted_iota(
        jnp.int32, (bin_block, th, tw), 0
    )
    mask = (idx[None, :, :] == bin_ids).astype(jnp.float32)

    if use_mxu:
        hs = _row_scan_mxu(mask)
    else:
        hs = jnp.cumsum(mask, axis=2)
    rc = jnp.where(iw == 0, 0.0, row_carry[bb])            # (BIN_BLOCK, TH)
    hs = hs + rc[:, :, None]
    row_carry[bb] = hs[:, :, -1]

    if use_mxu:
        vs = _col_scan_mxu(hs)
    else:
        vs = jnp.cumsum(hs, axis=1)
    cols = pl.dslice(iw * tile_w, tile_w)
    cc = jnp.where(ih == 0, carry_ref[0], col_carry[bb, :, cols])
    vs = vs + cc[:, None, :]
    col_carry[bb, :, cols] = vs[:, -1, :]

    # ---- the fused output stage: project the requested rows ----
    # vs is the final H on this tile (all dependencies are earlier raster
    # steps), so the strip's requested rows can be emitted right now.
    off = slots_ref[0]                                     # (KP,)
    kp = off.shape[0]
    sel = (
        off[:, None]
        == jax.lax.broadcasted_iota(jnp.int32, (kp, th), 1)
    ).astype(jnp.float32)                                  # (KP, TH)
    if use_mxu:
        out_ref[0] = _select_rows_mxu(sel, vs)
    else:
        out_ref[0] = jnp.sum(
            sel[None, :, :, None] * vs[:, None, :, :], axis=2
        )


def fused_rows_pallas(
    idx: jnp.ndarray,
    num_bins: int,
    slots: np.ndarray,
    *,
    tile: int = 128,
    bin_block: int = 8,
    use_mxu: bool = True,
    interpret: bool = False,
    carry: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Run the fused scan and emit the per-strip row slabs.

    Args:
      idx: (n, h, w) int32 bin indices, padded to tile multiples
        (PAD_BIN outside the image) — same contract as ``wf_tis_pallas``.
      num_bins: padded bin count, multiple of ``bin_block``.
      slots: (nth, kp) int32 table from ``slot_plan`` — in-strip offsets
        of the rows each strip emits, -1 for empty slots.
      carry: optional (n, num_bins, w) fp32 band carry-in.

    Returns:
      (n, num_bins, nth * kp, w) fp32 — strip-major row slabs; index
      with ``slot_plan``'s ``pos`` to recover request order.  The full
      (n, num_bins, h, w) H is never an output of this call.
    """
    n, h, w = idx.shape
    if h % tile or w % tile:
        raise ValueError(f"padded image {h}x{w} not divisible by tile {tile}")
    if num_bins % bin_block:
        raise ValueError(
            f"{num_bins} bins not divisible by bin_block {bin_block}")
    nth, ntw, nbb = h // tile, w // tile, num_bins // bin_block
    # analysis: allow-host-sync(slot table is host-built request metadata, never device data)
    slots = np.asarray(slots, np.int32)
    if slots.ndim != 2 or slots.shape[0] != nth:
        raise ValueError(
            f"slots shape {slots.shape} != ({nth}, kp) for {nth} strips")
    kp = slots.shape[1]
    if carry is None:
        carry = jnp.zeros((n, num_bins, w), jnp.float32)
    if carry.shape != (n, num_bins, w):
        raise ValueError(
            f"carry shape {carry.shape} != {(n, num_bins, w)} (frames, "
            "padded bins, padded width)"
        )

    kernel = functools.partial(
        _fused_rows_kernel, bin_block=bin_block, tile_w=tile,
        use_mxu=use_mxu,
    )
    scratch = [
        pltpu.VMEM((nbb, bin_block, tile), jnp.float32),  # row carries
        pltpu.VMEM((nbb, bin_block, w), jnp.float32),     # column carries
    ]
    return pl.pallas_call(
        kernel,
        grid=(n, nth, ntw, nbb),
        in_specs=[
            pl.BlockSpec((1, tile, tile), lambda f, ih, iw, bb: (f, ih, iw)),
            pl.BlockSpec(
                (1, bin_block, tile), lambda f, ih, iw, bb: (f, bb, iw)
            ),
            pl.BlockSpec((1, kp), lambda f, ih, iw, bb: (ih, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, bin_block, kp, tile), lambda f, ih, iw, bb: (f, bb, ih, iw)
        ),
        out_shape=jax.ShapeDtypeStruct(
            (n, num_bins, nth * kp, w), jnp.float32),
        scratch_shapes=scratch,
        interpret=interpret,
    )(idx, carry.astype(jnp.float32), jnp.asarray(slots))


def fused_geometry(
    row_ids, n: int, h: int, w: int, num_bins: int,
    *, tile: int = 128, bin_block: int = 8,
) -> FusedRowsGeometry:
    """The :class:`FusedRowsGeometry` a fused dispatch for ``row_ids``
    launches with — what ``kernelcheck.plan_geometry`` hands the
    verifier."""
    _, kp, _ = slot_plan(row_ids, tile, h)
    return FusedRowsGeometry(n=n, h=h, w=w, num_bins=num_bins, tile=tile,
                             bin_block=bin_block, kp=kp)
