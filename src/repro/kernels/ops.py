"""Jit'd public wrappers around the integral-histogram kernels.

``integral_histogram`` is the framework's single entry point: it bins the
image, pads spatial dims to tile multiples and bins to bin-block multiples
(padding pixels get PAD_BIN so they match no bin), dispatches to the chosen
method/backend, and crops the result back.

Input rank is polymorphic over a frame batch axis:

  (h, w)    -> (num_bins, h, w)       single frame
  (n, h, w) -> (n, num_bins, h, w)    frame stack — identical to n
               single-frame calls, executed as ONE dispatch (the jnp
               methods fuse the frame axis into their batched scans; the
               Pallas kernels take it as the outermost grid dimension).

Backends:
  "pallas"  — the TPU kernels (on CPU only with interpret=True; tests do).
  "jnp"     — the schedule-faithful jnp restatements (XLA-compiled; used
              for CPU wall-time benchmarks and as the production path on
              non-TPU hosts).
  "auto"    — pallas on TPU, jnp elsewhere.

Band streaming (core/bands.py) enters here through two knobs:

  carry_in            — ([n,] num_bins, w) aggregate of everything above
                        this image slice; the result is the full-frame H
                        restricted to the slice's rows.  Threads into the
                        Pallas kernels' VMEM carry chain and the jnp
                        wf_tis scan seed; bit-exact either way (all
                        arithmetic is integer-valued fp32).
  memory_budget_bytes — cap on the per-dispatch H footprint: frames whose
                        (n, b, h, w) output exceeds it are computed band
                        by band with the carry threaded between dispatches
                        and reassembled.  Bounds the transient working set
                        (one-hot masks, transposes, scan intermediates) to
                        a band; use core/bands.py directly when even the
                        assembled H must never materialize.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scans
from repro.core.binning import PAD_BIN, bin_indices
from repro.kernels import cw_tis, delta_apply as delta_apply_mod, \
    fused_rows, wf_tis
from repro.kernels.cw_tis import cw_tis_pallas
from repro.kernels.delta_apply import delta_apply_pallas
from repro.kernels.fused_rows import fused_rows_pallas, slot_plan
from repro.kernels.wf_tis import wf_tis_pallas

PALLAS_METHODS = {"cw_tis": cw_tis_pallas, "wf_tis": wf_tis_pallas}

# method -> kernel_specs(geom) builder: the declarative contracts
# repro.analysis.kernelcheck verifies (grid order, carry happens-before,
# output coverage, in-bounds index maps, VMEM fit).  Every PALLAS_METHODS
# entry must have one — asserted by the kernelcheck conformance tests.
# "fused_rows" and "delta_apply" are spec-verified too but are NOT
# PALLAS_METHODS entries: they are not full-H methods you can name in
# integral_histogram(); they are the query-fused dispatch behind
# fused_corner_rows() and the slab-repair primitive behind delta_apply().
KERNEL_SPECS = {
    "cw_tis": cw_tis.kernel_specs,
    "wf_tis": wf_tis.kernel_specs,
    "fused_rows": fused_rows.kernel_specs,
    "delta_apply": delta_apply_mod.kernel_specs,
}


def _pad_to(x: jnp.ndarray, mult_h: int, mult_w: int, fill) -> jnp.ndarray:
    """Pad the spatial (last two) axes up to multiples; leading axes kept."""
    h, w = x.shape[-2:]
    ph = (-h) % mult_h
    pw = (-w) % mult_w
    if ph or pw:
        pad = [(0, 0)] * (x.ndim - 2) + [(0, ph), (0, pw)]
        x = jnp.pad(x, pad, constant_values=fill)
    return x


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit,
    static_argnames=(
        "num_bins", "method", "backend", "tile", "bin_block", "use_mxu",
        "interpret", "value_range",
    ),
)
def _integral_histogram_jit(
    image: jnp.ndarray,
    carry_in: jnp.ndarray | None,
    num_bins: int,
    *,
    method: str,
    backend: str,
    tile: int,
    bin_block: int,
    use_mxu: bool,
    interpret: bool,
    value_range: int,
) -> jnp.ndarray:
    """The jit'd core: backend already resolved, inputs already validated."""
    if backend == "jnp":
        if method == "wf_tis":
            # Native carry seeding: the band scan starts from carry_in.
            return scans.wf_tis(
                image, num_bins, value_range, tile=tile, carry_in=carry_in
            )
        kw = {} if method in ("cw_b", "cw_sts") else {"tile": tile}
        H = scans.METHODS[method](image, num_bins, value_range, **kw)
        return scans.apply_carry(H, carry_in)

    h, w = image.shape[-2:]
    idx = bin_indices(image, num_bins, value_range)
    idx = _pad_to(idx, tile, tile, PAD_BIN)
    nb_pad = num_bins + (-num_bins) % bin_block
    carry = None
    if carry_in is not None:
        # Pad (..., num_bins, w) -> (..., nb_pad, w_pad): padded bins hold
        # no mass and padded columns are cropped, so zero-fill is exact.
        pad = [(0, 0)] * (carry_in.ndim - 2)
        pad += [(0, nb_pad - num_bins), (0, (-w) % tile)]
        carry = jnp.pad(carry_in.astype(jnp.float32), pad)
    out = PALLAS_METHODS[method](
        idx, nb_pad, tile=tile, bin_block=bin_block, use_mxu=use_mxu,
        interpret=interpret, carry=carry,
    )
    return out[..., :num_bins, :h, :w]


def integral_histogram(
    image: jnp.ndarray,
    num_bins: int,
    *,
    method: str = "wf_tis",
    backend: str = "auto",
    tile: int = 128,
    bin_block: int = 8,
    use_mxu: bool = True,
    interpret: bool = False,
    value_range: int = 256,
    carry_in: jnp.ndarray | None = None,
    memory_budget_bytes: int | None = None,
) -> jnp.ndarray:
    """Inclusive integral histogram of a frame or an (n, h, w) frame stack.

    See the module docstring for ``carry_in`` (band composition) and
    ``memory_budget_bytes`` (auto-banding).
    """
    if image.ndim not in (2, 3):
        raise ValueError(f"expected (h, w) or (n, h, w), got {image.shape}")
    if backend not in ("auto", "pallas", "jnp"):
        raise ValueError(f"unknown backend {backend!r}")
    if method not in scans.METHODS:
        raise ValueError(f"unknown method {method!r}")
    if backend == "pallas" and method not in PALLAS_METHODS:
        # An explicit backend request must not silently degrade: only
        # "auto" may fall back to the jnp scans.
        raise ValueError(
            f"method {method!r} has no Pallas kernel (Pallas methods: "
            f"{sorted(PALLAS_METHODS)}); use backend='auto' or 'jnp'"
        )
    if backend == "auto":
        backend = (
            "pallas" if _on_tpu() and method in PALLAS_METHODS else "jnp"
        )
    if carry_in is not None:
        want = image.shape[:-2] + (num_bins, image.shape[-1])
        if carry_in.shape != want:
            raise ValueError(
                f"carry_in shape {carry_in.shape} != {want} "
                "(leading frame axes, num_bins, width)"
            )

    if memory_budget_bytes is not None:
        # The banding decision lives in the planner (core/engine.py) —
        # this entry point just executes whatever plan it hands back.
        from repro.core import bands, engine  # deferred: both import us

        h, w = image.shape[-2:]
        num_frames = 1 if image.ndim == 2 else image.shape[0]
        p = engine.plan(engine.WorkloadSpec(
            height=h, width=w, num_bins=num_bins, num_frames=num_frames,
            method=method, backend=backend, tile=tile, bin_block=bin_block,
            use_mxu=use_mxu, interpret=interpret, value_range=value_range,
            memory_budget_bytes=memory_budget_bytes,
        ))
        if p.band_plan is not None:
            return bands.banded_integral_histogram(
                image, num_bins, plan=p.band_plan, carry_in=carry_in,
                method=method, backend=p.backend, tile=tile,
                bin_block=bin_block, use_mxu=use_mxu, interpret=interpret,
                value_range=value_range,
            )

    return _integral_histogram_jit(
        image, carry_in, num_bins, method=method, backend=backend,
        tile=tile, bin_block=bin_block, use_mxu=use_mxu,
        interpret=interpret, value_range=value_range,
    )


def fused_corner_rows(
    image: jnp.ndarray,
    num_bins: int,
    row_ids,
    *,
    method: str = "wf_tis",
    backend: str = "auto",
    tile: int = 128,
    bin_block: int = 8,
    use_mxu: bool = True,
    interpret: bool = False,
    value_range: int = 256,
    carry_in: jnp.ndarray | None = None,
    stats: dict | None = None,
) -> jnp.ndarray:
    """Corner rows of H for a known request — without materializing H.

    The Ehsan compute-vs-store fusion (arXiv:1510.05138): when the rows a
    request reads (Eq. 2 corner rows) are known up front, run the scan and
    emit ONLY those rows.  Two properties distinguish this from computing
    H and slicing:

      * the full (n, b, h, w) H never exists — on the Pallas path the
        fused kernel (kernels/fused_rows.py) writes kp rows per strip
        straight from VMEM; on the jnp path the scan streams tile-high
        bands so the live set is one band plus the emitted rows;
      * compute stops at the band containing ``max(row_ids)`` — rows
        below the last requested one contribute to nothing and are never
        scanned.  Banded streaming of full H must touch every band.

    Args:
      image: (h, w) or (n, h, w) frame(s), same contract as
        ``integral_histogram``.
      row_ids: sorted unique frame rows to emit, each in ``[0, h)``.
      stats: optional dict filled with ``bands_computed``/``bands_total``
        (tile-high bands scanned vs in the frame), ``rows_bytes`` (the
        result slab), ``full_h_bytes`` (what dense H would have cost) and
        the resolved ``backend`` — the peak-memory proxy the fused tests
        assert on.

    Returns:
      (..., num_bins, K, w) fp32 — H restricted to ``row_ids``, in
      ``row_ids`` order.  Bit-exact against dense H sliced at the same
      rows (all arithmetic is integer-valued fp32).
    """
    if image.ndim not in (2, 3):
        raise ValueError(f"expected (h, w) or (n, h, w), got {image.shape}")
    squeeze = image.ndim == 2
    frames = image[None] if squeeze else image
    n, h, w = frames.shape
    # analysis: allow-host-sync(row ids are host-side request metadata, never device data)
    rows = np.asarray(row_ids, np.int64).reshape(-1)
    if rows.size == 0:
        raise ValueError("row_ids is empty — nothing to fuse")
    if np.any(np.diff(rows) <= 0) or rows[0] < 0 or rows[-1] >= h:
        raise ValueError(
            f"row_ids must be sorted unique within [0, {h})")
    if backend not in ("auto", "pallas", "jnp"):
        raise ValueError(f"unknown backend {backend!r}")
    if method not in scans.METHODS:
        raise ValueError(f"unknown method {method!r}")
    if backend == "pallas" and method != "wf_tis":
        raise ValueError(
            f"the fused kernel runs the wf_tis scan; method {method!r} "
            "has no fused Pallas path — use backend='auto' or 'jnp'"
        )
    if backend == "auto":
        backend = "pallas" if _on_tpu() and method == "wf_tis" else "jnp"
    if carry_in is not None:
        want = frames.shape[:-2] + (num_bins, w)
        got = carry_in[None] if squeeze and carry_in.ndim == 2 else carry_in
        if got.shape != want:
            raise ValueError(
                f"carry_in shape {carry_in.shape} incompatible with "
                f"{want} (frames, num_bins, width)"
            )
        carry_in = got

    # Early exit: nothing below the last requested row feeds any output.
    bands_total = -(-h // tile)
    bands_needed = int(rows[-1]) // tile + 1
    h_cut = min(h, bands_needed * tile)
    frames = frames[:, :h_cut]

    if backend == "pallas":
        idx = bin_indices(frames, num_bins, value_range)
        idx = _pad_to(idx, tile, tile, PAD_BIN)
        nb_pad = num_bins + (-num_bins) % bin_block
        slots, _, pos = slot_plan(rows, tile, idx.shape[-2])
        carry = None
        if carry_in is not None:
            pad = [(0, 0), (0, nb_pad - num_bins), (0, (-w) % tile)]
            carry = jnp.pad(carry_in.astype(jnp.float32), pad)
        out = fused_rows_pallas(
            idx, nb_pad, slots, tile=tile, bin_block=bin_block,
            use_mxu=use_mxu, interpret=interpret, carry=carry,
        )
        R = out[:, :num_bins, pos, :w]
    else:
        # Stream tile-high bands through the scan, carry threaded between
        # dispatches; keep only the requested rows of each band.
        carry = carry_in
        kept = []
        for b in range(bands_needed):
            band = frames[:, b * tile:(b + 1) * tile]
            Hb = _integral_histogram_jit(
                band, carry, num_bins, method=method, backend="jnp",
                tile=tile, bin_block=bin_block, use_mxu=use_mxu,
                interpret=interpret, value_range=value_range,
            )
            carry = Hb[..., -1, :]
            local = rows[(rows >= b * tile) & (rows < (b + 1) * tile)]
            if local.size:
                kept.append(Hb[..., local - b * tile, :])
        R = jnp.concatenate(kept, axis=-2)

    if stats is not None:
        stats.update(
            bands_computed=bands_needed,
            bands_total=bands_total,
            rows_bytes=n * num_bins * rows.size * w * 4,
            full_h_bytes=n * num_bins * h * w * 4,
            backend=backend,
        )
    return R[0] if squeeze else R


def delta_apply(
    H: jnp.ndarray,
    delta: jnp.ndarray,
    *,
    backend: str = "auto",
    tile: int = 128,
    bin_block: int = 8,
    interpret: bool = False,
) -> jnp.ndarray:
    """Repair a clean H slab with a broadcast carry delta.

    The incremental video path (core/delta.py): when rows above a slab
    were edited, the slab's correction is one ``(..., num_bins, w)``
    delta — the dirty band's new bottom row minus its old one — added
    to every row.  All arithmetic is integer-valued fp32, so the result
    is bit-exact against recomputing the slab from the new frame.

    Args:
      H: (num_bins, h, w) or (n, num_bins, h, w) fp32 clean slab.
      delta: (num_bins, w) or (n, num_bins, w) carry delta, leading
        frame axis matching ``H``.

    Returns:
      ``H + delta`` broadcast over the row axis, same logical shape as
      ``H``.  Pallas backend streams the slab tile-by-tile through VMEM
      (kernels/delta_apply.py); the jnp backend is one fused XLA add.
    """
    if H.ndim not in (3, 4):
        raise ValueError(
            f"expected (num_bins, h, w) or (n, num_bins, h, w), got "
            f"{H.shape}")
    if backend not in ("auto", "pallas", "jnp"):
        raise ValueError(f"unknown backend {backend!r}")
    squeeze = H.ndim == 3
    slab = H[None] if squeeze else H
    d = delta[None] if squeeze and delta.ndim == 2 else delta
    n, nb, h, w = slab.shape
    if d.shape != (n, nb, w):
        raise ValueError(
            f"delta shape {delta.shape} incompatible with {(n, nb, w)} "
            "(frames, num_bins, width)")
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "jnp"

    if backend == "jnp":
        out = slab + d[..., None, :]
    else:
        nb_pad = nb + (-nb) % bin_block
        pad_b = [(0, 0), (0, nb_pad - nb)]
        slab_p = jnp.pad(
            _pad_to(slab.astype(jnp.float32), tile, tile, 0.0),
            pad_b + [(0, 0), (0, 0)])
        d_p = jnp.pad(d.astype(jnp.float32),
                      pad_b + [(0, (-w) % tile)])
        out = delta_apply_pallas(
            slab_p, d_p, tile=tile, bin_block=bin_block,
            interpret=interpret,
        )[:, :nb, :h, :w]
    return out[0] if squeeze else out


def fused_likelihood_map(
    image: jnp.ndarray,
    model: jnp.ndarray,
    metric,
    *,
    window: tuple[int, int],
    stride: int = 1,
    num_bins: int | None = None,
    stats: dict | None = None,
    **kwargs,
):
    """Likelihood-map tiles straight off the fused scan — the second
    output mode of the query-fused path.

    Computes the two corner-row lattices the (window, stride) sliding
    grid reads, fuses them out of the scan with ``fused_corner_rows``,
    and scores every window against ``model`` via the shared
    row-difference evaluator.  Dense H is never built.

    Returns the same (..., out_h, out_w) map as
    ``HSource.likelihood_map``.
    """
    from repro.core.hsource import FusedRowsH  # deferred: hsource imports us

    nb = int(model.shape[-1]) if num_bins is None else num_bins
    h, w = image.shape[-2:]
    probe = FusedRowsH(row_ids=(0,), R=np.zeros((nb, 1, w), np.float32),
                       height=h, width=w)
    _, _, bot, top = probe._window_lattices(window, stride)
    rows = np.unique(np.concatenate([bot, top[top >= 0]]))
    R = fused_corner_rows(image, nb, rows, stats=stats, **kwargs)
    # analysis: allow-host-sync(FusedRowsH stores host arrays by protocol — the K-row slab pull IS the result readback)
    source = FusedRowsH(row_ids=rows, R=np.asarray(R), height=h, width=w)
    return source.likelihood_map(model, window, metric, stride)
