"""Jit'd public wrappers around the integral-histogram kernels.

``integral_histogram`` is the framework's single entry point: it bins the
image, pads spatial dims to tile multiples and bins to bin-block multiples
(padding pixels get PAD_BIN so they match no bin), dispatches to the chosen
method/backend, and crops the result back.

Input rank is polymorphic over a frame batch axis:

  (h, w)    -> (num_bins, h, w)       single frame
  (n, h, w) -> (n, num_bins, h, w)    frame stack — identical to n
               single-frame calls, executed as ONE dispatch (the jnp
               methods fuse the frame axis into their batched scans; the
               Pallas kernels take it as the outermost grid dimension).

Backends:
  "pallas"  — the TPU kernels (on CPU only with interpret=True; tests do).
  "jnp"     — the schedule-faithful jnp restatements (XLA-compiled; used
              for CPU wall-time benchmarks and as the production path on
              non-TPU hosts).
  "auto"    — pallas on TPU, jnp elsewhere.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import scans
from repro.core.binning import PAD_BIN, bin_indices
from repro.kernels.cw_tis import cw_tis_pallas
from repro.kernels.wf_tis import wf_tis_pallas

PALLAS_METHODS = {"cw_tis": cw_tis_pallas, "wf_tis": wf_tis_pallas}


def _pad_to(x: jnp.ndarray, mult_h: int, mult_w: int, fill) -> jnp.ndarray:
    """Pad the spatial (last two) axes up to multiples; leading axes kept."""
    h, w = x.shape[-2:]
    ph = (-h) % mult_h
    pw = (-w) % mult_w
    if ph or pw:
        pad = [(0, 0)] * (x.ndim - 2) + [(0, ph), (0, pw)]
        x = jnp.pad(x, pad, constant_values=fill)
    return x


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit,
    static_argnames=(
        "num_bins", "method", "backend", "tile", "bin_block", "use_mxu",
        "interpret", "value_range",
    ),
)
def integral_histogram(
    image: jnp.ndarray,
    num_bins: int,
    *,
    method: str = "wf_tis",
    backend: str = "auto",
    tile: int = 128,
    bin_block: int = 8,
    use_mxu: bool = True,
    interpret: bool = False,
    value_range: int = 256,
) -> jnp.ndarray:
    """Inclusive integral histogram of a frame or an (n, h, w) frame stack."""
    if image.ndim not in (2, 3):
        raise ValueError(f"expected (h, w) or (n, h, w), got {image.shape}")
    if backend not in ("auto", "pallas", "jnp"):
        raise ValueError(f"unknown backend {backend!r}")
    if method not in scans.METHODS:
        raise ValueError(f"unknown method {method!r}")
    if backend == "pallas" and method not in PALLAS_METHODS:
        # An explicit backend request must not silently degrade: only
        # "auto" may fall back to the jnp scans.
        raise ValueError(
            f"method {method!r} has no Pallas kernel (Pallas methods: "
            f"{sorted(PALLAS_METHODS)}); use backend='auto' or 'jnp'"
        )
    if backend == "auto":
        backend = (
            "pallas" if _on_tpu() and method in PALLAS_METHODS else "jnp"
        )

    if backend == "jnp":
        kw = {} if method in ("cw_b", "cw_sts") else {"tile": tile}
        return scans.METHODS[method](image, num_bins, value_range, **kw)

    h, w = image.shape[-2:]
    idx = bin_indices(image, num_bins, value_range)
    idx = _pad_to(idx, tile, tile, PAD_BIN)
    nb_pad = num_bins + (-num_bins) % bin_block
    out = PALLAS_METHODS[method](
        idx, nb_pad, tile=tile, bin_block=bin_block, use_mxu=use_mxu,
        interpret=interpret,
    )
    return out[..., :num_bins, :h, :w]
