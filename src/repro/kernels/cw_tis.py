"""CW-TiS: Cross-weave Tiled horizontal/vertical Scan — Pallas TPU kernels.

Paper (§3.4): two custom kernels — a tiled horizontal strip scan over the
one-hot histogram, then a tiled vertical strip scan — eliminating CW-STS's
transpose.  Each pass reads and writes the full b*h*w tensor: 4 HBM passes
(vs WF-TiS's 2), which is exactly the gap the paper measures as the
CW-TiS -> WF-TiS 1.5x and we measure as the memory-roofline ratio.

Binning is fused into the horizontal pass (the init kernel's extra pass is
still avoided), so the measured gap vs WF-TiS isolates the h/v fusion —
same methodology as the paper's Fig. 8 breakdown.

Frame batching: both passes take the frame index as the outermost grid
dimension, so an (n, h, w) stack is two pallas_calls total, not 2n.  The
strip carries reset themselves at frame boundaries because their zeroing
predicates (iw == 0 / ih == 0) fire when the inner raster restarts.

Same MXU triangular-matmul scan trick as wf_tis.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

from repro.kernels.specs import KernelGeometry, KernelSpec, Operand, Scratch
from repro.kernels.wf_tis import _col_scan_mxu, _row_scan_mxu


def kernel_specs(geom: KernelGeometry) -> tuple[KernelSpec, ...]:
    """The declarative contracts of ``cw_tis_pallas``'s TWO
    ``pallas_call``s (verified by ``repro.analysis.kernelcheck``; a
    conformance test pins them against the live calls below).

    Pass 1 sweeps column tiles innermost (grid ``(f, bb, ih, iw)``), so
    the single row-carry scratch is always one step stale — its producer
    is exactly the previous grid step.  Pass 2 DELIBERATELY swaps the
    spatial dims (grid ``(f, bb, iw, ih)``, row tiles innermost): the
    column carry now chains down a vertical strip, and that order is a
    declared contract the verifier must *prove*, not assume row-major —
    re-declaring pass 2 with pass 1's order is the grid-reordering bug
    class kernelcheck exists to catch (its happens-before check fails:
    the last write to the shared scratch before ``(iw, ih)`` would come
    from ``(iw-1, nth-1)``, not the declared producer ``(iw, ih-1)``).
    """
    n, nth, ntw, nbb = geom.n, geom.nth, geom.ntw, geom.nbb
    t, bb_blk = geom.tile, geom.bin_block
    hp, wp, nbp = geom.h_pad, geom.w_pad, geom.nb_pad

    def h_reads(g):
        if g["iw"] > 0:
            return [(("rc",), {**g, "iw": g["iw"] - 1})]
        return []

    def v_reads(g):
        if g["ih"] > 0:
            return [(("cc",), {**g, "ih": g["ih"] - 1})]
        return []

    return (
        KernelSpec(
            name="cw_tis/hscan",
            grid=(("f", n), ("bb", nbb), ("ih", nth), ("iw", ntw)),
            in_specs=(
                Operand("idx", (n, hp, wp), (1, t, t),
                        lambda f, bb, ih, iw: (f, ih, iw), dtype="int32"),
            ),
            out_specs=(
                Operand("hh", (n, nbp, hp, wp), (1, bb_blk, t, t),
                        lambda f, bb, ih, iw: (f, bb, ih, iw)),
            ),
            scratch=(Scratch("row_carry", (bb_blk, t)),),
            carry_reads=h_reads,
            carry_writes=lambda g: [("rc",)],
        ),
        KernelSpec(
            name="cw_tis/vscan",
            grid=(("f", n), ("bb", nbb), ("iw", ntw), ("ih", nth)),
            in_specs=(
                Operand("hh", (n, nbp, hp, wp), (1, bb_blk, t, t),
                        lambda f, bb, iw, ih: (f, bb, ih, iw)),
                Operand("carry", (n, nbp, wp), (1, bb_blk, t),
                        lambda f, bb, iw, ih: (f, bb, iw)),
            ),
            out_specs=(
                Operand("out", (n, nbp, hp, wp), (1, bb_blk, t, t),
                        lambda f, bb, iw, ih: (f, bb, ih, iw)),
            ),
            scratch=(Scratch("col_carry", (bb_blk, t)),),
            carry_reads=v_reads,
            carry_writes=lambda g: [("cc",)],
        ),
    )


def _hscan_kernel(idx_ref, out_ref, row_carry, *, bin_block, use_mxu):
    """Grid (n, nbb, nth, ntw), column tiles innermost: strip sweep per bin
    block (the paper's vertical-strip schedule, Fig. 5 left)."""
    bb = pl.program_id(1)
    iw = pl.program_id(3)

    idx = idx_ref[0]
    th, tw = idx.shape
    bin_ids = bb * bin_block + jax.lax.broadcasted_iota(
        jnp.int32, (bin_block, th, tw), 0
    )
    mask = (idx[None, :, :] == bin_ids).astype(jnp.float32)

    hs = _row_scan_mxu(mask) if use_mxu else jnp.cumsum(mask, axis=2)
    rc = jnp.where(iw == 0, 0.0, row_carry[...])           # (BIN_BLOCK, TH)
    hs = hs + rc[:, :, None]
    row_carry[...] = hs[:, :, -1]
    out_ref[0] = hs


def _vscan_kernel(hh_ref, carry_ref, out_ref, col_carry, *, use_mxu):
    """Grid (n, nbb, ntw, nth), row tiles innermost: horizontal-strip sweep
    (Fig. 5 right).  Input is the horizontally-scanned tensor.  The first
    tile row of each frame seeds its carry from the band carry-in (zeros
    unless this call computes a row band of a larger frame)."""
    ih = pl.program_id(3)

    hs = hh_ref[0]                                         # (BIN_BLOCK, TH, TW)
    vs = _col_scan_mxu(hs) if use_mxu else jnp.cumsum(hs, axis=1)
    cc = jnp.where(ih == 0, carry_ref[0], col_carry[...])  # (BIN_BLOCK, TW)
    vs = vs + cc[:, None, :]
    col_carry[...] = vs[:, -1, :]
    out_ref[0] = vs


def cw_tis_pallas(
    idx: jnp.ndarray,
    num_bins: int,
    *,
    tile: int = 128,
    bin_block: int = 8,
    use_mxu: bool = True,
    interpret: bool = False,
    carry: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Two-pass CW-TiS integral histogram (see wf_tis_pallas for contract).

    ``carry`` ([n,] num_bins, w) enters the vertical pass only: the
    horizontal scan is band-local, the band composition is a column offset.
    """
    squeeze = idx.ndim == 2
    if squeeze:
        idx = idx[None]
        if carry is not None:
            carry = carry[None]
    n, h, w = idx.shape
    if h % tile or w % tile:
        raise ValueError(f"padded image {h}x{w} not divisible by tile {tile}")
    if num_bins % bin_block:
        raise ValueError(f"{num_bins} bins not divisible by bin_block {bin_block}")
    if carry is None:
        carry = jnp.zeros((n, num_bins, w), jnp.float32)
    if carry.shape != (n, num_bins, w):
        raise ValueError(
            f"carry shape {carry.shape} != {(n, num_bins, w)} (frames, "
            "padded bins, padded width)"
        )
    nth, ntw, nbb = h // tile, w // tile, num_bins // bin_block

    hh = pl.pallas_call(
        functools.partial(_hscan_kernel, bin_block=bin_block, use_mxu=use_mxu),
        grid=(n, nbb, nth, ntw),
        in_specs=[
            pl.BlockSpec((1, tile, tile), lambda f, bb, ih, iw: (f, ih, iw))
        ],
        out_specs=pl.BlockSpec(
            (1, bin_block, tile, tile), lambda f, bb, ih, iw: (f, bb, ih, iw)
        ),
        out_shape=jax.ShapeDtypeStruct((n, num_bins, h, w), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bin_block, tile), jnp.float32)],
        interpret=interpret,
    )(idx)

    out = pl.pallas_call(
        functools.partial(_vscan_kernel, use_mxu=use_mxu),
        grid=(n, nbb, ntw, nth),
        in_specs=[
            pl.BlockSpec(
                (1, bin_block, tile, tile), lambda f, bb, iw, ih: (f, bb, ih, iw)
            ),
            pl.BlockSpec(
                (1, bin_block, tile), lambda f, bb, iw, ih: (f, bb, iw)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, bin_block, tile, tile), lambda f, bb, iw, ih: (f, bb, ih, iw)
        ),
        out_shape=jax.ShapeDtypeStruct((n, num_bins, h, w), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bin_block, tile), jnp.float32)],
        interpret=interpret,
    )(hh, carry.astype(jnp.float32))
    return out[0] if squeeze else out
