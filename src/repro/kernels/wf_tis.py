"""WF-TiS: fused Wave-Front Tiled Scan integral histogram — Pallas TPU kernel.

Paper (§3.5): one kernel computes per-tile horizontal AND vertical scans,
tiles scheduled on anti-diagonal wavefronts so independent GPU thread
blocks can run as soon as their left+top neighbours finish; boundary
columns are spilled to global memory.  Net effect: the b*h*w tensor is
read/written exactly once each (2 HBM passes) instead of CW-TiS's 4.

TPU adaptation (DESIGN.md §2):
  * A TPU core executes the Pallas grid sequentially in row-major order, so
    left+top dependencies are satisfied without diagonal scheduling; the
    wavefront becomes a raster walk with carries in VMEM scratch that
    persist across grid steps (GPU shared memory cannot do this).
  * The per-tile prefix sums are computed on the MXU as triangular-ones
    matmuls: row-cumsum(X) = X @ triu(1), col-cumsum(X) = tril(1) @ X.
    A 128x128 tile cumsum is a single systolic pass — far cheaper than a
    log-depth shift-add ladder on the VPU (see DESIGN.md napkin math).
  * Binning is fused: the kernel reads the int32 bin-index image and forms
    the one-hot mask in VREGs — the paper's separate init kernel (a full
    extra write+read of b*h*w) never exists.  This is a beyond-paper win,
    reducing the HBM floor from 2 passes + init to (1/b read + 1 write).
  * Grid order is (frames, row_tiles, col_tiles, bin_blocks) with bins
    innermost: consecutive grid steps reuse the same image block, so Pallas
    fetches each image tile from HBM once, not once per bin block.
  * Frame batching rides the outermost grid dimension: the same kernel
    instance sweeps frame after frame, and the carry-reset predicates
    (iw == 0 for row carries, ih == 0 for column carries) fire at every
    frame boundary because the raster restarts — per-frame reset needs no
    extra state.  One pallas_call for the whole stack amortizes dispatch
    exactly like the paper's dual-stream frame pipeline (§4.4).

Accumulation is fp32 (exact for counts < 2**24; all supported planes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-specific pallas helpers; interpret mode works without a TPU.
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

from repro.kernels.specs import KernelGeometry, KernelSpec, Operand, Scratch


def kernel_specs(geom: KernelGeometry) -> tuple[KernelSpec, ...]:
    """The declarative contract of ``wf_tis_pallas``'s one ``pallas_call``
    (verified by ``repro.analysis.kernelcheck``; a conformance test pins
    it against the live call below).

    Grid ``(f, ih, iw, bb)`` with bins innermost — the raster walk whose
    sequential order IS the wavefront: the row carry produced at
    ``(ih, iw-1)`` and the column carry produced at ``(ih-1, iw)`` are
    both earlier steps.  The carry edges restate the kernel's reset
    predicates: ``iw == 0`` consumes no row carry, ``ih == 0`` consumes
    the band carry-in operand instead of the column scratch — which is
    also why frame boundaries need no extra state (the raster restart
    fires both predicates).
    """
    n, nth, ntw, nbb = geom.n, geom.nth, geom.ntw, geom.nbb
    t, bb_blk = geom.tile, geom.bin_block
    hp, wp, nbp = geom.h_pad, geom.w_pad, geom.nb_pad

    def reads(g):
        edges = []
        if g["iw"] > 0:     # row carry from the tile to the left
            edges.append(
                (("row", g["bb"]), {**g, "iw": g["iw"] - 1}))
        if g["ih"] > 0:     # column carry from the strip above
            edges.append(
                (("col", g["bb"], g["iw"]), {**g, "ih": g["ih"] - 1}))
        return edges

    def writes(g):
        return [("row", g["bb"]), ("col", g["bb"], g["iw"])]

    return (
        KernelSpec(
            name="wf_tis",
            grid=(("f", n), ("ih", nth), ("iw", ntw), ("bb", nbb)),
            in_specs=(
                Operand("idx", (n, hp, wp), (1, t, t),
                        lambda f, ih, iw, bb: (f, ih, iw), dtype="int32"),
                Operand("carry", (n, nbp, wp), (1, bb_blk, t),
                        lambda f, ih, iw, bb: (f, bb, iw)),
            ),
            out_specs=(
                Operand("out", (n, nbp, hp, wp), (1, bb_blk, t, t),
                        lambda f, ih, iw, bb: (f, bb, ih, iw)),
            ),
            scratch=(
                Scratch("row_carry", (nbb, bb_blk, t)),
                Scratch("col_carry", (nbb, bb_blk, wp)),
            ),
            carry_reads=reads,
            carry_writes=writes,
        ),
    )


def _triu_ones(n: int, dtype=jnp.float32):
    r = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
    c = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    return (r <= c).astype(dtype)


def _tril_ones(n: int, dtype=jnp.float32):
    r = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
    c = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    return (r >= c).astype(dtype)


def _row_scan_mxu(x: jnp.ndarray) -> jnp.ndarray:
    """Inclusive cumsum along the last axis via MXU: X @ triu(1)."""
    tw = x.shape[-1]
    return jax.lax.dot_general(
        x,
        _triu_ones(tw, x.dtype),
        dimension_numbers=(((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _col_scan_mxu(x: jnp.ndarray) -> jnp.ndarray:
    """Inclusive cumsum along axis -2 via MXU: tril(1) @ X (batched).

    out[b, i, j] = sum_r tril[i, r] * x[b, r, j] — expressed as a batched
    dot_general (tril broadcast over the bin-block batch) so the result
    keeps (batch, row, col) layout without a post-transpose.
    """
    b, th = x.shape[0], x.shape[-2]
    tril = jnp.broadcast_to(_tril_ones(th, x.dtype), (b, th, th))
    return jax.lax.dot_general(
        tril,
        x,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )


def _wf_tis_kernel(
    idx_ref,      # (1, TH, TW) int32 bin indices (PAD_BIN=-1 outside the image)
    carry_ref,    # (1, BIN_BLOCK, TW) fp32 band carry-in (zeros = topmost band)
    out_ref,      # (1, BIN_BLOCK, TH, TW) fp32 integral histogram block
    row_carry,    # VMEM scratch (NBB, BIN_BLOCK, TH) — right-edge carries
    col_carry,    # VMEM scratch (NBB, BIN_BLOCK, W_PAD) — bottom-edge carries
    *,
    bin_block: int,
    tile_w: int,
    use_mxu: bool,
):
    ih = pl.program_id(1)
    iw = pl.program_id(2)
    bb = pl.program_id(3)

    idx = idx_ref[0]
    th, tw = idx.shape

    # Fused binning: one-hot mask for this block of bins, formed in VREGs.
    bin_ids = bb * bin_block + jax.lax.broadcasted_iota(
        jnp.int32, (bin_block, th, tw), 0
    )
    mask = (idx[None, :, :] == bin_ids).astype(jnp.float32)

    # ---- horizontal scan within the tile (MXU triangular matmul) ----
    if use_mxu:
        hs = _row_scan_mxu(mask)
    else:
        hs = jnp.cumsum(mask, axis=2)

    # Add the running row carry (prefix of everything left of this tile in
    # the current row strip), zeroed at the first column of tiles — which
    # also resets it at every new frame, since the raster restarts there.
    rc = jnp.where(iw == 0, 0.0, row_carry[bb])            # (BIN_BLOCK, TH)
    hs = hs + rc[:, :, None]
    row_carry[bb] = hs[:, :, -1]                           # new right edge

    # ---- vertical scan within the tile ----
    if use_mxu:
        vs = _col_scan_mxu(hs)
    else:
        vs = jnp.cumsum(hs, axis=1)

    # Add the running column carry (full integral at the last row of the
    # strip above).  On the first strip — of every frame, since the raster
    # restarts there — it is seeded from the band carry-in instead of zero:
    # the host-level band decomposition (core/bands.py) enters the kernel
    # here, exactly where the VMEM carry chain begins.
    cols = pl.dslice(iw * tile_w, tile_w)
    cc = jnp.where(ih == 0, carry_ref[0], col_carry[bb, :, cols])
    vs = vs + cc[:, None, :]
    col_carry[bb, :, cols] = vs[:, -1, :]                  # new bottom edge

    out_ref[0] = vs


def wf_tis_pallas(
    idx: jnp.ndarray,
    num_bins: int,
    *,
    tile: int = 128,
    bin_block: int = 8,
    use_mxu: bool = True,
    interpret: bool = False,
    carry: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Fused WF-TiS integral histogram.

    Args:
      idx: (h, w) or (n, h, w) int32 bin indices, already padded so
        h % tile == 0 and w % tile == 0 (padding uses PAD_BIN so it matches
        no bin).
      num_bins: padded bin count, multiple of ``bin_block``.
      carry: optional ([n,] num_bins, w) fp32 band carry-in — the bottom row
        of the band above when this call computes one row band of a larger
        frame (core/bands.py).  ``None`` means a frame top (zero carry).

    Returns:
      (num_bins, h, w) fp32 inclusive integral histogram for a single
      frame, (n, num_bins, h, w) for a frame stack.
    """
    squeeze = idx.ndim == 2
    if squeeze:
        idx = idx[None]
        if carry is not None:
            carry = carry[None]
    n, h, w = idx.shape
    if h % tile or w % tile:
        raise ValueError(f"padded image {h}x{w} not divisible by tile {tile}")
    if num_bins % bin_block:
        raise ValueError(f"{num_bins} bins not divisible by bin_block {bin_block}")
    if carry is None:
        carry = jnp.zeros((n, num_bins, w), jnp.float32)
    if carry.shape != (n, num_bins, w):
        raise ValueError(
            f"carry shape {carry.shape} != {(n, num_bins, w)} (frames, "
            "padded bins, padded width)"
        )
    nth, ntw, nbb = h // tile, w // tile, num_bins // bin_block

    kernel = functools.partial(
        _wf_tis_kernel, bin_block=bin_block, tile_w=tile, use_mxu=use_mxu
    )
    scratch = [
        pltpu.VMEM((nbb, bin_block, tile), jnp.float32),  # row carries
        pltpu.VMEM((nbb, bin_block, w), jnp.float32),     # column carries
    ]
    out = pl.pallas_call(
        kernel,
        grid=(n, nth, ntw, nbb),
        in_specs=[
            pl.BlockSpec((1, tile, tile), lambda f, ih, iw, bb: (f, ih, iw)),
            pl.BlockSpec(
                (1, bin_block, tile), lambda f, ih, iw, bb: (f, bb, iw)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, bin_block, tile, tile), lambda f, ih, iw, bb: (f, bb, ih, iw)
        ),
        out_shape=jax.ShapeDtypeStruct((n, num_bins, h, w), jnp.float32),
        scratch_shapes=scratch,
        interpret=interpret,
    )(idx, carry.astype(jnp.float32))
    return out[0] if squeeze else out
