"""Declarative kernel contracts: the metadata ``kernelcheck`` verifies.

The Pallas kernels' correctness rests on invariants that the
``pallas_call`` arguments *imply* but nothing checks: the sequential
grid order that makes the VMEM carry chain a happens-before relation,
index maps that tile the output exactly once, block indices that stay
inside the padded operands, and a working set that fits per-core VMEM.
Each kernel module exports a ``kernel_specs(geom)`` builder (right next
to its ``pallas_call``) returning the :class:`KernelSpec` restatement of
those arguments; :mod:`repro.analysis.kernelcheck` enumerates the grid
symbolically and proves all four properties, and a conformance test
cross-checks the spec against the live ``pallas_call`` so the metadata
cannot drift from the code.

This module is deliberately stdlib-only (no jax import): a spec is data
— shapes, index maps as plain Python callables over named grid indices,
and carry-edge functions describing which scratch cells a grid step
reads (and from which producer step) and writes.

Grid-order semantics (the property check (1) leans on): a TPU core
executes the Pallas grid *sequentially* with the **last** grid dimension
innermost — ``grid=(a, b, c)`` iterates c fastest, exactly nested-loop
order.  The spec's ``grid`` tuple therefore both names the dimensions
(for the carry-edge functions) and declares the execution order the
carry chain depends on.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Mapping, Sequence

#: bytes per element for the dtypes the kernels use.
DTYPE_BYTES = {"int32": 4, "float32": 4, "uint32": 4, "uint16": 2}


@dataclasses.dataclass(frozen=True)
class KernelGeometry:
    """One concrete kernel launch geometry (pre-padding sizes).

    ``h``/``w``/``num_bins`` are the *logical* sizes; the padded sizes
    the ``pallas_call`` actually sees (tile/bin-block multiples, the
    padding rule of ``kernels/ops.py``) are derived properties.
    """

    n: int                      # frames (outermost grid dimension)
    h: int
    w: int
    num_bins: int
    tile: int = 128
    bin_block: int = 8

    @property
    def h_pad(self) -> int:
        return math.ceil(self.h / self.tile) * self.tile

    @property
    def w_pad(self) -> int:
        return math.ceil(self.w / self.tile) * self.tile

    @property
    def nb_pad(self) -> int:
        return math.ceil(self.num_bins / self.bin_block) * self.bin_block

    @property
    def nth(self) -> int:
        return self.h_pad // self.tile

    @property
    def ntw(self) -> int:
        return self.w_pad // self.tile

    @property
    def nbb(self) -> int:
        return self.nb_pad // self.bin_block

    def canonical(self, max_blocks: int = 3) -> "KernelGeometry":
        """The reduced geometry grid enumeration runs on: every grid
        dimension clamped to ``max_blocks`` and the frame count to 2.

        The bug classes the enumeration targets (reordered grid dims,
        overlapping/gapped index maps, off-by-one block indices, missed
        carry resets at frame/strip boundaries) all manifest within 2-3
        steps per dimension, so clamping keeps the walk O(100) steps at
        any frame size.  Frame count 2 is a floor as well as a cap: the
        frame-boundary carry resets only exercise with a second frame.
        """
        return KernelGeometry(
            n=2,
            h=min(self.nth, max_blocks) * self.tile,
            w=min(self.ntw, max_blocks) * self.tile,
            num_bins=min(self.nbb, max_blocks) * self.bin_block,
            tile=self.tile,
            bin_block=self.bin_block,
        )


@dataclasses.dataclass(frozen=True)
class FusedRowsGeometry(KernelGeometry):
    """Launch geometry of the query-fused kernel (kernels/fused_rows.py).

    ``kp`` is the per-strip emission width: the padded count of row
    slots each tile-row strip may emit (the maximum corner rows any
    strip of the request carries, rounded up to a sublane multiple of
    8).  The fused output is ``(n, nb_pad, nth * kp, w_pad)`` — never
    the full H."""

    kp: int = 8

    def canonical(self, max_blocks: int = 3) -> "FusedRowsGeometry":
        base = super().canonical(max_blocks)
        return FusedRowsGeometry(
            n=base.n, h=base.h, w=base.w, num_bins=base.num_bins,
            tile=base.tile, bin_block=base.bin_block,
            kp=min(self.kp, self.tile),
        )


@dataclasses.dataclass(frozen=True)
class Operand:
    """One blocked ``pallas_call`` operand (an in_spec or out_spec).

    ``index_map`` mirrors the BlockSpec lambda: positional grid indices
    (in the spec's grid order) -> block-index tuple.
    """

    name: str
    shape: tuple[int, ...]          # full padded operand shape
    block: tuple[int, ...]          # BlockSpec block shape
    index_map: Callable[..., tuple[int, ...]]
    dtype: str = "float32"

    @property
    def block_bytes(self) -> int:
        return math.prod(self.block) * DTYPE_BYTES[self.dtype]


@dataclasses.dataclass(frozen=True)
class Scratch:
    """One VMEM scratch buffer (``scratch_shapes`` entry)."""

    name: str
    shape: tuple[int, ...]
    dtype: str = "float32"

    @property
    def nbytes(self) -> int:
        return math.prod(self.shape) * DTYPE_BYTES[self.dtype]


#: a scratch cell key: hashable, first element names the buffer.
Cell = tuple
#: carry reads at one grid step: (cell, producer grid point) pairs.
#: The producer is the step whose write the read value must come from.
CarryReads = Callable[[Mapping[str, int]], Sequence[tuple[Cell, Mapping[str, int]]]]
#: carry writes at one grid step: cells (re)written.
CarryWrites = Callable[[Mapping[str, int]], Sequence[Cell]]


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """The declarative contract of one ``pallas_call``.

    ``grid`` is ``((dim_name, size), ...)`` in launch order (last dim
    innermost — the sequential order property (1) is proved under).
    ``carry_reads(g)`` returns the scratch values grid step ``g``
    *consumes* (value-flow reads: a buffered read whose value a reset
    predicate discards, e.g. ``jnp.where(iw == 0, 0, row_carry[bb])`` at
    ``iw == 0``, is NOT a read) together with the grid point that must
    have produced each value.  ``carry_writes(g)`` returns the cells
    ``g`` (re)writes.  Cells model whole regions written atomically —
    e.g. the ``row_carry[bb]`` slice is one cell keyed ``("row", bb)``.
    """

    name: str
    grid: tuple[tuple[str, int], ...]
    in_specs: tuple[Operand, ...]
    out_specs: tuple[Operand, ...]
    scratch: tuple[Scratch, ...] = ()
    carry_reads: CarryReads | None = None
    carry_writes: CarryWrites | None = None

    @property
    def grid_sizes(self) -> tuple[int, ...]:
        return tuple(size for _, size in self.grid)

    @property
    def dim_names(self) -> tuple[str, ...]:
        return tuple(name for name, _ in self.grid)

    def vmem_bytes(self) -> int:
        """The per-core VMEM working set this launch needs: every
        blocked operand double-buffered (Pallas overlaps the next
        block's DMA with the current step) plus the scratch, which is
        single-buffered because it persists across grid steps."""
        blocks = sum(op.block_bytes for op in self.in_specs + self.out_specs)
        scratch = sum(s.nbytes for s in self.scratch)
        return 2 * blocks + scratch

    def vmem_detail(self) -> str:
        ops = " + ".join(
            f"{op.name}{list(op.block)}"
            for op in self.in_specs + self.out_specs
        )
        scratch = sum(s.nbytes for s in self.scratch)
        return f"2x({ops}) blocks + {scratch} B scratch"
