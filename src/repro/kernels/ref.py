"""Pure-jnp oracle for the integral histogram kernels.

H(b, x, y) = sum_{r<=x} sum_{c<=y} Q(I(r, c), b)        (paper Eq. 1)

Inclusive on both spatial axes, matching Algorithm 1 of the paper.  Every
Pallas kernel and every scan method in core/scans.py is tested allclose
against this function.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.binning import bin_indices, one_hot_bins


def integral_histogram_ref(
    image: jnp.ndarray,
    num_bins: int,
    value_range: int = 256,
    dtype=jnp.float32,
) -> jnp.ndarray:
    """Oracle: (h, w) image -> (num_bins, h, w) inclusive integral histogram."""
    idx = bin_indices(image, num_bins, value_range)
    q = one_hot_bins(idx, num_bins, dtype=dtype)
    return jnp.cumsum(jnp.cumsum(q, axis=1), axis=2)


def region_histogram_ref(
    image: jnp.ndarray,
    num_bins: int,
    r0: int,
    c0: int,
    r1: int,
    c1: int,
    value_range: int = 256,
) -> jnp.ndarray:
    """Direct (no integral image) histogram of the inclusive region
    [r0..r1] x [c0..c1] — the ground truth for Eq. (2) queries."""
    patch = image[r0 : r1 + 1, c0 : c1 + 1]
    idx = bin_indices(patch, num_bins, value_range)
    return jnp.sum(one_hot_bins(idx, num_bins), axis=(1, 2))
