"""Carry-delta broadcast: the device half of the incremental video path.

Consecutive video frames from a fixed camera differ in a handful of
rows.  Because every column of H is a prefix sum, editing rows
``[r0, r1)`` changes H *below* ``r1`` only through the band's bottom
row: for any clean row ``r >= r1``,

    H_new[r, c, b] = H_old[r, c, b] + delta[c, b]
    delta          = H_new[r1 - 1]  -  H_old[r1 - 1]        # (bins, w)

so a cached H is repaired by recomputing just the dirty bands and
adding one broadcast ``(bins, w)`` delta to every clean slab below —
the compute-vs-reuse tradeoff of Ehsan et al. (arXiv:1510.05142)
applied across *time* instead of across queries.  All arithmetic is
integer-valued fp32 (exact below 2**24), so the repaired H is
bit-exact against a full recompute; ``core/delta.py`` owns that walk
and the exactness argument.

This kernel is the slab-repair primitive: stream a clean
``(n, bins, h, w)`` slab through VMEM tile by tile and add the delta
row to every row of each tile.  There is no carry chain and no
scratch — each grid step is independent (any grid order is valid; the
declared one just keeps the delta block resident while a frame's
spatial tiles stream by).  The interesting contract is pure coverage:
every output tile written exactly once, the delta block indexed by
``(f, bb, iw)`` only — which ``kernel_specs`` declares and
``repro.analysis.kernelcheck`` proves.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.specs import KernelGeometry, KernelSpec, Operand


def kernel_specs(geom: KernelGeometry) -> tuple[KernelSpec, ...]:
    """The declarative contract of ``delta_apply_pallas``'s one
    ``pallas_call`` (verified by ``repro.analysis.kernelcheck``; the
    conformance test in tests/test_delta.py pins it against the live
    call).

    No scratch and no carry edges — the add is pointwise per tile, so
    carry-order is trivially satisfied and the whole contract is
    exactly-once output coverage, in-bounds index maps, and the
    double-buffered VMEM fit of one H tile + one delta row block.
    """
    n, nth, ntw, nbb = geom.n, geom.nth, geom.ntw, geom.nbb
    t, bb_blk = geom.tile, geom.bin_block
    hp, wp, nbp = geom.h_pad, geom.w_pad, geom.nb_pad

    return (
        KernelSpec(
            name="delta_apply",
            grid=(("f", n), ("bb", nbb), ("ih", nth), ("iw", ntw)),
            in_specs=(
                Operand("h", (n, nbp, hp, wp), (1, bb_blk, t, t),
                        lambda f, bb, ih, iw: (f, bb, ih, iw)),
                Operand("delta", (n, nbp, wp), (1, bb_blk, t),
                        lambda f, bb, ih, iw: (f, bb, iw)),
            ),
            out_specs=(
                Operand("out", (n, nbp, hp, wp), (1, bb_blk, t, t),
                        lambda f, bb, ih, iw: (f, bb, ih, iw)),
            ),
        ),
    )


def _delta_apply_kernel(h_ref, delta_ref, out_ref):
    # (1, BB, T, T) += (1, BB, T) broadcast over the tile's rows.
    out_ref[0] = h_ref[0] + delta_ref[0][:, None, :]


def delta_apply_pallas(
    H: jnp.ndarray,
    delta: jnp.ndarray,
    *,
    tile: int = 128,
    bin_block: int = 8,
    interpret: bool = False,
) -> jnp.ndarray:
    """Add a broadcast ``(bins, w)`` delta to every row of an H slab.

    Args:
      H: (n, nb_pad, h_pad, w_pad) fp32 clean slab, spatial dims padded
        to tile multiples and bins to a bin_block multiple — the same
        padded layout the scan kernels write.
      delta: (n, nb_pad, w_pad) fp32 carry delta (new bottom row of the
        dirty band above, minus the old one).

    Returns:
      (n, nb_pad, h_pad, w_pad) fp32 — ``H + delta`` broadcast over the
      row axis, computed tile by tile in VMEM.
    """
    if H.ndim != 4:
        raise ValueError(f"expected (n, bins, h, w) slab, got {H.shape}")
    n, nb, h, w = H.shape
    if h % tile or w % tile:
        raise ValueError(f"padded slab {h}x{w} not divisible by tile {tile}")
    if nb % bin_block:
        raise ValueError(
            f"{nb} bins not divisible by bin_block {bin_block}")
    if delta.shape != (n, nb, w):
        raise ValueError(
            f"delta shape {delta.shape} != {(n, nb, w)} (frames, padded "
            "bins, padded width)")
    nth, ntw, nbb = h // tile, w // tile, nb // bin_block

    return pl.pallas_call(
        _delta_apply_kernel,
        grid=(n, nbb, nth, ntw),
        in_specs=[
            pl.BlockSpec((1, bin_block, tile, tile),
                         lambda f, bb, ih, iw: (f, bb, ih, iw)),
            pl.BlockSpec((1, bin_block, tile),
                         lambda f, bb, ih, iw: (f, bb, iw)),
        ],
        out_specs=pl.BlockSpec((1, bin_block, tile, tile),
                               lambda f, bb, ih, iw: (f, bb, ih, iw)),
        out_shape=jax.ShapeDtypeStruct((n, nb, h, w), jnp.float32),
        interpret=interpret,
    )(H.astype(jnp.float32), delta.astype(jnp.float32))
