"""Pallas TPU kernels for the paper's compute hot spot (the tiled scans).

wf_tis.py — fused single-pass wavefront tiled scan (paper's fastest).
cw_tis.py — two-pass tiled horizontal/vertical scan.
ops.py    — jit'd dispatch + padding.
ref.py    — pure-jnp oracle every kernel is tested against.
"""

from repro.kernels.ops import integral_histogram
from repro.kernels.ref import integral_histogram_ref

__all__ = ["integral_histogram", "integral_histogram_ref"]
