"""Pallas TPU kernels for the paper's compute hot spot (the tiled scans).

wf_tis.py     — fused single-pass wavefront tiled scan (paper's fastest).
cw_tis.py     — two-pass tiled horizontal/vertical scan.
fused_rows.py — query-fused WF-TiS: emits ONLY requested corner rows,
                full H never reaches HBM (ROADMAP item 2).
ops.py        — jit'd dispatch + padding (incl. fused_corner_rows /
                fused_likelihood_map).
specs.py      — declarative KernelSpecs the contract verifier proves.
ref.py        — pure-jnp oracle every kernel is tested against.
"""

from repro.kernels.ops import integral_histogram
from repro.kernels.ref import integral_histogram_ref

__all__ = ["integral_histogram", "integral_histogram_ref"]
