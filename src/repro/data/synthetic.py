"""Deterministic, seekable synthetic data — the fault-tolerance substrate.

`batch_at(step)` is a pure function of (seed, step): resuming training
from a checkpoint at step k replays exactly the batches k, k+1, ... with
no stored cursor state.  This is the data-side half of checkpoint/restart
(train/fault.py); tests assert bit-exact resume.

Also provides the deterministic video-frame generator used by the
integral-histogram examples and benchmarks (moving blobs over textured
noise — content-independent for the kernels, but gives the tracker
something to track).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenStream:
    """Synthetic LM data: shifted-label random tokens + structure.

    Tokens mix a deterministic arithmetic pattern with PRNG noise so the
    loss is learnable (the examples' loss curves actually go down).
    """
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0
    pattern_frac: float = 0.7

    def batch_at(self, step: int) -> dict:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        k1, k2, k3 = jax.random.split(key, 3)
        b, s, v = self.batch, self.seq_len, self.vocab_size
        # arithmetic progressions (learnable) + uniform noise (not)
        start = jax.random.randint(k1, (b, 1), 0, v)
        stride = jax.random.randint(k2, (b, 1), 1, 7)
        pattern = (start + stride * jnp.arange(s + 1)[None, :]) % v
        noise = jax.random.randint(k3, (b, s + 1), 0, v)
        use_pattern = (
            jax.random.uniform(k1, (b, 1)) < self.pattern_frac)
        toks = jnp.where(use_pattern, pattern, noise).astype(jnp.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@dataclasses.dataclass(frozen=True)
class MultimodalStream:
    """TokenStream + stub modality embeddings (vlm/audio assignments)."""
    base: TokenStream
    d_model: int
    num_prefix: int = 0            # vlm: patch embeddings
    src_len: int = 0               # audio: encoder frame embeddings
    dtype: str = "bfloat16"

    def batch_at(self, step: int) -> dict:
        out = self.base.batch_at(step)
        key = jax.random.fold_in(
            jax.random.PRNGKey(self.base.seed + 77), step)
        if self.num_prefix:
            out["prefix_embeds"] = 0.02 * jax.random.normal(
                key, (self.base.batch, self.num_prefix, self.d_model)
            ).astype(self.dtype)
        if self.src_len:
            out["src_embeds"] = 0.02 * jax.random.normal(
                key, (self.base.batch, self.src_len, self.d_model)
            ).astype(self.dtype)
        return out


def make_stream(cfg, batch: int, seq_len: int, seed: int = 0):
    """Family-appropriate stream for a ModelConfig."""
    base = TokenStream(cfg.vocab_size, batch, seq_len, seed)
    if cfg.family == "vlm":
        return MultimodalStream(
            TokenStream(cfg.vocab_size, batch, seq_len - cfg.num_prefix_embeds,
                        seed),
            cfg.d_model, num_prefix=cfg.num_prefix_embeds)
    if cfg.family == "audio":
        return MultimodalStream(base, cfg.d_model, src_len=seq_len)
    return base


# ---------------------------------------------------------------------------
# Video frames (integral-histogram substrate)
# ---------------------------------------------------------------------------
def video_frames(h: int, w: int, num_frames: int, seed: int = 0,
                 num_blobs: int = 3) -> np.ndarray:
    """Deterministic uint8 frame sequence: moving Gaussian blobs over
    banded texture.  Shape (num_frames, h, w)."""
    rng = np.random.default_rng(seed)
    yy, xx = np.mgrid[0:h, 0:w].astype(np.float32)
    base = (
        40.0 * (1 + np.sin(2 * np.pi * yy / 64))
        + 40.0 * (1 + np.sin(2 * np.pi * xx / 96))
    )
    pos = rng.uniform(0.2, 0.8, (num_blobs, 2)) * [h, w]
    vel = rng.uniform(-4, 4, (num_blobs, 2))
    amp = rng.uniform(60, 120, (num_blobs,))
    sig = rng.uniform(h / 16, h / 6, (num_blobs,))
    frames = np.empty((num_frames, h, w), np.uint8)
    for t in range(num_frames):
        img = base + 8.0 * rng.standard_normal((h, w)).astype(np.float32)
        for i in range(num_blobs):
            cy, cx = pos[i]
            img += amp[i] * np.exp(
                -((yy - cy) ** 2 + (xx - cx) ** 2) / (2 * sig[i] ** 2))
            pos[i] += vel[i]
            pos[i] %= [h, w]
        frames[t] = np.clip(img, 0, 255).astype(np.uint8)
    return frames
