"""Synthetic deterministic data pipelines + host->device prefetch."""

from repro.data.synthetic import (
    MultimodalStream, TokenStream, make_stream, video_frames,
)
from repro.core.pipeline import DoubleBufferedExecutor, prefetch_to_device

__all__ = [
    "MultimodalStream", "TokenStream", "make_stream", "video_frames",
    "DoubleBufferedExecutor", "prefetch_to_device",
]
