"""Mixture-of-Experts block: sort-based capacity dispatch + expert parallel.

Design notes (DESIGN.md §4/§5):
  * The paper's multi-GPU *bin task queue* maps onto expert parallelism —
    both distribute an embarrassingly-parallel channel axis (bins/experts)
    over devices and rebalance work via capacity limits.
  * Dispatch is sort-based (argsort over token->expert assignments, ragged
    positions via bincount prefix), NOT the GShard (T, E, C) one-hot einsum:
    at kimi-k2 scale (T=32k/device, E=384) the one-hot dispatch tensor
    would be ~10^10 elements; the sort path is O(T k log(T k)).
  * Expert parallelism runs inside shard_map: each model-rank owns
    E/|model| experts, computes its share of every token's top-k, and the
    partial outputs are summed with one psum over 'model' (the same
    collective shape as a Megatron TP all-reduce).  FSDP shards of the
    expert weights are re-gathered per layer with lax.all_gather (ZeRO-3).
  * A mesh-free local path (same math, no collectives) backs smoke tests.

Capacity: cap = ceil(T * k / E * capacity_factor), rounded up to 8.
Tokens over capacity are dropped (scatter mode="drop"), matching
GShard/Switch semantics.  A load-balance aux loss is returned.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.models.layers import dense_init
from repro.sharding.rules import current_context


def moe_params(key, cfg, dtype=jnp.float32) -> dict:
    d, fe, e = cfg.d_model, cfg.expert_d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e), in_axis=0, dtype=jnp.float32),
        "we_gate": dense_init(ks[1], (e, d, fe), in_axis=1, dtype=dtype),
        "we_up": dense_init(ks[2], (e, d, fe), in_axis=1, dtype=dtype),
        "we_down": dense_init(ks[3], (e, fe, d), in_axis=1, dtype=dtype),
    }
    if cfg.num_shared_experts:
        fs = fe * cfg.num_shared_experts
        sk = jax.random.split(ks[4], 3)
        p["ws_gate"] = dense_init(sk[0], (d, fs), in_axis=0, dtype=dtype)
        p["ws_up"] = dense_init(sk[1], (d, fs), in_axis=0, dtype=dtype)
        p["ws_down"] = dense_init(sk[2], (fs, d), in_axis=0, dtype=dtype)
    return p


def _capacity(num_tokens: int, cfg) -> int:
    cap = num_tokens * cfg.num_experts_per_token / cfg.num_experts
    cap = int(cap * cfg.capacity_factor) + 1
    return max(8, -(-cap // 8) * 8)


def _route(x_flat: jnp.ndarray, router_w: jnp.ndarray, cfg):
    """Returns (weights (T,k), experts (T,k), aux_loss scalar)."""
    logits = jnp.einsum(
        "td,de->te", x_flat.astype(jnp.float32), router_w.astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)
    weights, experts = lax.top_k(probs, cfg.num_experts_per_token)
    weights = weights / (jnp.sum(weights, axis=-1, keepdims=True) + 1e-9)
    # Switch-style load-balance loss: E * sum_e f_e * p_e
    e = cfg.num_experts
    dispatch_frac = jnp.mean(
        jax.nn.one_hot(experts[:, 0], e, dtype=jnp.float32), axis=0
    )
    prob_frac = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(dispatch_frac * prob_frac)
    return weights, experts, aux


def _expert_ffn(buf: jnp.ndarray, wg, wu, wd) -> jnp.ndarray:
    """buf: (E_local, cap, d) -> (E_local, cap, d); batched SwiGLU."""
    gate = jnp.einsum("ecd,edf->ecf", buf, wg)
    up = jnp.einsum("ecd,edf->ecf", buf, wu)
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(buf.dtype) * up
    return jnp.einsum("ecf,efd->ecd", h, wd)


def _dispatch_compute_combine(
    x_flat, weights, experts, wg, wu, wd, cfg, *, lo: int, e_local: int
):
    """Sort-based dispatch for experts [lo, lo+e_local); returns (T, d)."""
    t, d = x_flat.shape
    k = cfg.num_experts_per_token
    n = t * k
    cap = _capacity(t, cfg)

    e_flat = experts.reshape(-1)
    w_flat = weights.reshape(-1).astype(x_flat.dtype)
    tok_of = jnp.arange(n, dtype=jnp.int32) // k

    # position of each assignment within its expert's buffer
    perm = jnp.argsort(e_flat)
    ranks = jnp.zeros((n,), jnp.int32).at[perm].set(jnp.arange(n, dtype=jnp.int32))
    counts = jnp.bincount(e_flat, length=cfg.num_experts)
    starts = (jnp.cumsum(counts) - counts).astype(jnp.int32)
    pos = ranks - starts[e_flat]

    local_e = e_flat - lo
    valid = (local_e >= 0) & (local_e < e_local) & (pos < cap)
    slot = jnp.where(valid, local_e * cap + pos, e_local * cap)  # OOB -> drop

    buf = jnp.zeros((e_local * cap, d), x_flat.dtype)
    buf = buf.at[slot].set(x_flat[tok_of], mode="drop")
    out = _expert_ffn(buf.reshape(e_local, cap, d), wg, wu, wd)
    out_flat = out.reshape(e_local * cap, d)

    y = jnp.where(
        valid[:, None],
        out_flat[jnp.clip(slot, 0, e_local * cap - 1)],
        jnp.zeros((), x_flat.dtype),
    ) * w_flat[:, None]
    return jax.ops.segment_sum(y, tok_of, num_segments=t)


def _shared_expert(x_flat, p):
    gate = jnp.einsum("td,df->tf", x_flat, p["ws_gate"])
    up = jnp.einsum("td,df->tf", x_flat, p["ws_up"])
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(x_flat.dtype) * up
    return jnp.einsum("tf,fd->td", h, p["ws_down"])


def moe_block(x: jnp.ndarray, p: dict, cfg):
    """MoE FFN. x: (B, S, d). Returns (out, aux_loss).

    Under a sharding_context, runs expert-parallel via shard_map (experts
    over 'model', tokens over batch axes, FSDP re-gather over 'data');
    otherwise runs the identical math on one device.
    """
    ctx = current_context()
    b, s, d = x.shape

    if ctx is None:
        x_flat = x.reshape(b * s, d)
        weights, experts, aux = _route(x_flat, p["router"], cfg)
        out = _dispatch_compute_combine(
            x_flat, weights, experts, p["we_gate"], p["we_up"], p["we_down"],
            cfg, lo=0, e_local=cfg.num_experts,
        )
        if cfg.num_shared_experts:
            out = out + _shared_expert(x_flat, p)
        return out.reshape(b, s, d), aux

    mesh, rules = ctx.mesh, ctx.rules
    batch_axes = rules.present(mesh, rules.batch_axes)
    model_ax = rules.present(mesh, rules.tp_axes)[0]
    fsdp_axes = rules.present(mesh, rules.fsdp_axes)
    fsdp_ax = fsdp_axes[0] if fsdp_axes else None
    m = mesh.shape[model_ax]
    e_local = cfg.num_experts // m
    fsdp_n = mesh.shape[fsdp_ax] if fsdp_ax else 1

    shard_d = d % fsdp_n == 0 and fsdp_n > 1
    fe = cfg.expert_d_ff
    fs = fe * cfg.num_shared_experts
    shard_fs = cfg.num_shared_experts and fs % m == 0

    def inner(x_blk, router_w, wg, wu, wd, shared):
        tl = x_blk.shape[0] * x_blk.shape[1]
        x_flat = x_blk.reshape(tl, d)
        weights, experts, aux = _route(x_flat, router_w, cfg)
        if shard_d:  # ZeRO-3: re-gather the FSDP shard of expert weights
            wg = lax.all_gather(wg, fsdp_ax, axis=1, tiled=True)
            wu = lax.all_gather(wu, fsdp_ax, axis=1, tiled=True)
            wd = lax.all_gather(wd, fsdp_ax, axis=2, tiled=True)
        lo = lax.axis_index(model_ax) * e_local
        out = _dispatch_compute_combine(
            x_flat, weights, experts, wg, wu, wd, cfg,
            lo=lo, e_local=e_local,
        )
        if cfg.num_shared_experts:
            sh = _shared_expert(x_flat, shared)
            if shard_fs:
                out = lax.psum(out + sh, model_ax)  # both are partials
            else:
                out = lax.psum(out, model_ax) + sh  # sh replicated per rank
        else:
            out = lax.psum(out, model_ax)
        aux = lax.pmean(aux, model_ax)
        for ax in batch_axes:       # average the per-DP-shard estimates
            aux = lax.pmean(aux, ax)
        return out.reshape(x_blk.shape), aux[None]

    x_spec = P(batch_axes if len(batch_axes) > 1 else batch_axes[0], None, None)
    wg_spec = P(model_ax, fsdp_ax if shard_d else None, None)
    wd_spec = P(model_ax, None, fsdp_ax if shard_d else None)
    shared_specs = {
        "ws_gate": P(None, model_ax if shard_fs else None),
        "ws_up": P(None, model_ax if shard_fs else None),
        "ws_down": P(model_ax if shard_fs else None, None),
    }
    shared = {k: p[k] for k in shared_specs if k in p}
    # lo/e_local handled inside via axis_index; experts sharded over model.
    out, aux = shard_map(
        inner,
        mesh=mesh,
        in_specs=(
            x_spec, P(None, None), wg_spec, wg_spec, wd_spec,
            {k: shared_specs[k] for k in shared} if shared else P(),
        ),
        out_specs=(x_spec, P(None)),
        check_vma=False,
    )(x, p["router"], p["we_gate"], p["we_up"], p["we_down"], shared or {})
    return out, jnp.sum(aux) / aux.shape[0]
