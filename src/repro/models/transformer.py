"""Decoder-only LM: dense, MoE and multimodal-prefix variants.

Covers 7 of the 10 assigned archs (qwen2/2.5/3, llama3, llama4-scout,
kimi-k2, llava-next backbone).  Layers are stacked per *segment* (uniform
runs of identical blocks — e.g. kimi-k2 = 1 dense layer + 60 MoE layers)
and consumed with lax.scan so HLO size is O(segments), not O(depth):
a 61-layer 1T-param train_step lowers to the same module size as a 2-layer
toy.  Decode scans (params, kv-cache) jointly and emits the new cache.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.moe import moe_block, moe_params
from repro.sharding.rules import constrain


# ---------------------------------------------------------------------------
# Segments: uniform runs of identical blocks, each scanned.
# ---------------------------------------------------------------------------
def segments_spec(cfg) -> tuple[tuple[str, int], ...]:
    """((kind, num_layers), ...) with kind in {"dense", "moe"}."""
    if cfg.is_moe:
        segs = []
        if cfg.first_k_dense:
            segs.append(("dense", cfg.first_k_dense))
        segs.append(("moe", cfg.num_layers - cfg.first_k_dense))
        return tuple(segs)
    return (("dense", cfg.num_layers),)


def _layer_params(key, cfg, kind: str, dtype) -> dict:
    k_attn, k_ffn = jax.random.split(key)
    d = cfg.d_model
    p = {
        "attn_norm": L.norm_params(d, cfg.use_layer_norm, dtype),
        "attn": L.attention_params(k_attn, cfg, dtype=dtype),
        "mlp_norm": L.norm_params(d, cfg.use_layer_norm, dtype),
    }
    if kind == "moe":
        p["moe"] = moe_params(k_ffn, cfg, dtype=dtype)
    else:
        p["mlp"] = L.mlp_params(k_ffn, d, cfg.d_ff, dtype=dtype)
    return p


def _stack_init(fn, key, n: int):
    return jax.vmap(fn)(jax.random.split(key, n))


def init_params(key, cfg, dtype=jnp.float32) -> dict:
    keys = jax.random.split(key, 4 + len(segments_spec(cfg)))
    d, v = cfg.d_model, cfg.padded_vocab
    params = {
        "embed": L.embed_init(keys[0], (v, d), dtype),
        "final_norm": L.norm_params(d, cfg.use_layer_norm, dtype),
        "segments": {},
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(keys[1], (d, v), in_axis=0, dtype=dtype)
    for i, (kind, n) in enumerate(segments_spec(cfg)):
        params["segments"][f"seg{i}"] = {
            "layers": _stack_init(
                lambda k, kind=kind: _layer_params(k, cfg, kind, dtype),
                keys[3 + i], n,
            )
        }
    return params


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------
def _block(x, p, cfg, kind, *, positions, cache_layer=None):
    """One transformer block. Returns (x, new_cache_layer, aux_loss)."""
    h = L.norm(x, p["attn_norm"], cfg.norm_eps, cfg.use_layer_norm)
    h, new_cache = L.attention_block(
        h, p["attn"], cfg, positions=positions, causal=True,
        sliding_window=cfg.sliding_window, cache=cache_layer,
    )
    x = x + h
    h = L.norm(x, p["mlp_norm"], cfg.norm_eps, cfg.use_layer_norm)
    if kind == "moe":
        h, aux = moe_block(h, p["moe"], cfg)
    else:
        h, aux = L.swiglu(h, p["mlp"]), jnp.zeros((), jnp.float32)
    x = x + h
    x = constrain(x, "batch", None, None)
    return x, new_cache, aux


def _remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)  # "full": save nothing


def _run_segment(x, seg_params, cfg, kind, *, positions, seg_cache=None):
    """Scan a uniform segment of layers. Returns (x, new_seg_cache, aux)."""
    stacked = seg_params["layers"]

    if seg_cache is None:
        def body(carry, p_layer):
            h, aux = carry
            h, _, a = _block(h, p_layer, cfg, kind, positions=positions)
            return (h, aux + a), None
        body = _remat(body, cfg) if cfg.remat != "none" else body
        (x, aux), _ = L.scan_or_unroll(
            body, (x, jnp.zeros((), jnp.float32)), stacked, cfg.scan_layers)
        return x, None, aux

    # decode/prefill-with-cache: scan params and cache jointly
    def body(carry, xs):
        h, aux = carry
        p_layer, c_layer = xs
        h, new_c, a = _block(h, p_layer, cfg, kind, positions=positions,
                             cache_layer=c_layer)
        return (h, aux + a), {"k": new_c["k"], "v": new_c["v"]}

    kv = {"k": seg_cache["k"], "v": seg_cache["v"]}
    # per-layer cache view must carry the shared scalar len
    ln = seg_cache["len"]
    def body_with_len(carry, xs):
        p_layer, c_kv = xs
        return body(carry, (p_layer, {"k": c_kv["k"], "v": c_kv["v"],
                                      "len": ln}))
    (x, aux), new_kv = L.scan_or_unroll(
        body_with_len, (x, jnp.zeros((), jnp.float32)), (stacked, kv),
        cfg.scan_layers)
    s = positions.shape[-1]
    new_cache = {"k": new_kv["k"], "v": new_kv["v"], "len": ln + s}
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------
def forward(params, tokens, cfg, *, prefix_embeds=None, cache=None,
            positions=None):
    """tokens: (B, S) int32. prefix_embeds: (B, P, d) for VLM/audio stubs.

    Returns (logits (B, S_total, padded_vocab), aux_loss, new_cache).
    With a cache, S is the new-token count and positions default to
    cache['len'] + arange(S).
    """
    params = L.cast_params(params, cfg.dtype)
    b, s = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype)
    if cfg.scale_embeddings:
        x = x * jnp.sqrt(cfg.d_model).astype(cfg.dtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(cfg.dtype), x], axis=1)
        s = x.shape[1]
    if positions is None:
        base = cache["seg0"]["len"] if cache is not None else 0
        positions = jnp.broadcast_to(base + jnp.arange(s)[None, :], (b, s))
    x = constrain(x, "batch", None, None)

    aux_total = jnp.zeros((), jnp.float32)
    new_cache = {}
    for i, (kind, _) in enumerate(segments_spec(cfg)):
        seg_cache = cache[f"seg{i}"] if cache is not None else None
        x, seg_new, aux = _run_segment(
            x, params["segments"][f"seg{i}"], cfg, kind,
            positions=positions, seg_cache=seg_cache,
        )
        aux_total = aux_total + aux
        if seg_new is not None:
            new_cache[f"seg{i}"] = seg_new

    x = L.norm(x, params["final_norm"], cfg.norm_eps, cfg.use_layer_norm)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x,
                            params["embed"].astype(cfg.dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x,
                            params["lm_head"].astype(cfg.dtype))
    logits = L.softcap(logits.astype(jnp.float32), cfg.logits_softcap)
    logits = constrain(logits, "batch", None, "tp")
    return logits, aux_total, (new_cache if cache is not None else None)


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    from repro.models.cache import kv_cache

    c = {}
    for i, (kind, n) in enumerate(segments_spec(cfg)):
        ln = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
        c[f"seg{i}"] = kv_cache(n, batch, ln, cfg.num_kv_heads, cfg.head_dim,
                                dtype)
    return c
