"""Shared neural-net layers for the model zoo (pure JAX, no flax).

Conventions:
  * params are nested dicts of jnp arrays; stacked-layer params carry a
    leading L dim and are consumed via lax.scan (keeps HLO size O(1) in
    depth — essential for the 61-layer / 1T-param dry-runs).
  * activations compute in cfg.dtype (bf16 default); norms/softmax in fp32.
  * attention is GQA-general: Hq query heads share Hkv kv heads; kv is
    never materialized repeated.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.rules import constrain


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------
def dense_init(key, shape, in_axis=-2, dtype=jnp.float32):
    fan_in = shape[in_axis] if len(shape) > 1 else shape[0]
    scale = 1.0 / jnp.sqrt(jnp.maximum(fan_in, 1)).astype(jnp.float32)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Layer-stack execution: lax.scan (compact HLO) or unrolled (countable HLO)
# ---------------------------------------------------------------------------
def scan_or_unroll(body, carry, xs, use_scan: bool):
    """lax.scan when use_scan, else an unrolled python loop with identical
    semantics (body(carry, x_slice) -> (carry, y_slice); ys stacked).

    Unrolling exists for the dry-run cost measurement: HloCostAnalysis
    does not multiply while-loop bodies by trip count, so per-layer
    FLOPs/bytes/collectives are only countable in unrolled form.
    """
    if use_scan:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x_i = jax.tree.map(lambda t: t[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *ts: jnp.stack(ts), *ys)
    else:
        ys = None
    return carry, ys


# ---------------------------------------------------------------------------
# Mixed precision: fp32 master params, compute-dtype working copy
# ---------------------------------------------------------------------------
# Leaves that must stay fp32 regardless of compute dtype: router logits,
# SSD decay rates and step biases, RG-LRU gate parameters.
_FP32_LEAVES = frozenset(
    {"router", "A_log", "D", "dt_bias", "lam", "g_a", "b_a", "g_x", "b_x"}
)


def cast_params(params, dtype):
    """Cast float params to the compute dtype, except numerics-critical
    leaves (kept fp32).  Integer leaves pass through."""

    def f(path, x):
        leaf = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if leaf in _FP32_LEAVES or not jnp.issubdtype(x.dtype, jnp.floating):
            return x
        return x.astype(dtype)

    return jax.tree_util.tree_map_with_path(f, params)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def norm(x, p: dict, eps: float, use_layer_norm: bool):
    if use_layer_norm:
        return layer_norm(x, p["scale"], p["bias"], eps)
    return rms_norm(x, p["scale"], eps)


def norm_params(d: int, use_layer_norm: bool, dtype=jnp.float32) -> dict:
    p = {"scale": jnp.zeros((d,), dtype)}
    if use_layer_norm:
        p = {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    return p


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------
def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x: (B, S, H, D); positions: (B, S) or (S,). Rotate-half convention."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freq  # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA / MQA / MHA, causal / bidirectional / sliding / cross)
# ---------------------------------------------------------------------------
def _expand_kv_for_tp(q, k, v):
    """Under a sharding context, materialize KV per q-head group.

    The memory-lean grouped form reshapes Hq -> (Hkv, G), which GSPMD
    cannot keep head-sharded when Hkv < |model| (it replicates — measured
    34 GiB of fp32 scores per device for llama3 train_4k).  Repeating KV
    to Hq heads keeps the head axis TP-shardable end-to-end; the repeat
    itself is bytes-cheap (Hq x hd per token) next to the scores it saves.
    Outside a mesh context the grouped form is used unchanged.
    """
    from repro.sharding.rules import current_context

    ctx = current_context()
    if ctx is None:
        return q, k, v
    g = q.shape[2] // k.shape[2]
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    q = constrain(q, "batch", None, "tp", None)
    if (ctx.rules.decode_cache_layout == "seq"
            and q.shape[1] == 1 and k.shape[1] > 1):
        # flash-decode: keep the cache SEQUENCE-sharded; softmax over the
        # sharded KV axis partitions into per-shard partials + small psum
        # combines (GSPMD derives it from jnp max/sum/einsum).
        k = constrain(k, "batch", "tp", None, None)
        v = constrain(v, "batch", "tp", None, None)
    else:
        k = constrain(k, "batch", None, "tp", None)
        v = constrain(v, "batch", None, "tp", None)
    return q, k, v


def attention_chunked(
    q: jnp.ndarray,             # (B, Sq, Hq, D)
    k: jnp.ndarray,             # (B, Skv, Hkv, D)
    v: jnp.ndarray,             # (B, Skv, Hkv, D)
    *,
    positions_q: jnp.ndarray,
    positions_kv: jnp.ndarray,
    causal: bool = True,
    sliding_window: int | None = None,
    kv_valid_len: jnp.ndarray | None = None,
    block_kv: int = 1024,
) -> jnp.ndarray:
    """Online-softmax attention, KV consumed in blocks via lax.scan.

    Memory is O(Sq * block_kv) instead of O(Sq * Skv) — the jnp statement
    of FlashAttention, and the long-context prefill path.  Numerics: fp32
    running (max, sum, acc); exact (not approximate) softmax.
    """
    q, k, v = _expand_kv_for_tp(q, k, v)
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    if skv % block_kv:
        pad = (-skv) % block_kv
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        positions_kv = jnp.pad(positions_kv, ((0, 0), (0, pad)),
                               constant_values=jnp.iinfo(jnp.int32).max)
        skv += pad
    nblk = skv // block_kv
    qg = q.reshape(b, sq, hkv, g, d)
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)

    kb = k.reshape(b, nblk, block_kv, hkv, d).swapaxes(0, 1)
    vb = v.reshape(b, nblk, block_kv, hkv, d).swapaxes(0, 1)
    pb = positions_kv.reshape(b, nblk, block_kv).swapaxes(0, 1)

    def step(carry, blk):
        m, lse, acc = carry                     # (B,K,G,Sq), same, (B,K,G,Sq,D)
        kblk, vblk, pkv = blk
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kblk,
                       preferred_element_type=jnp.float32) * scale
        pqx = positions_q[:, None, None, :, None]
        pkx = pkv[:, None, None, None, :]
        mask = jnp.ones(s.shape, dtype=bool)
        if causal:
            mask &= pkx <= pqx
        if sliding_window is not None:
            mask &= pqx - pkx < sliding_window
        if kv_valid_len is not None:
            mask &= pkx < kv_valid_len[:, None, None, None, None]
        s = jnp.where(mask, s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard fully-masked rows (m_new == -inf): scale-factor 0
        alpha = jnp.where(jnp.isinf(m_new), 0.0, jnp.exp(m - m_new))
        p = jnp.where(jnp.isinf(m_new[..., None]), 0.0,
                      jnp.exp(s - m_new[..., None]))
        l_new = lse * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p.astype(vblk.dtype), vblk,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, g, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sq, d), jnp.float32)
    (m, lse, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kb, vb, pb))
    out = acc / jnp.maximum(lse, 1e-30)[..., None]
    out = jnp.moveaxis(out, 3, 1).reshape(b, sq, hq, d)
    return out.astype(q.dtype)


def attention(
    q: jnp.ndarray,             # (B, Sq, Hq, D)
    k: jnp.ndarray,             # (B, Skv, Hkv, D)
    v: jnp.ndarray,             # (B, Skv, Hkv, D)
    *,
    positions_q: jnp.ndarray,   # (B, Sq) absolute positions
    positions_kv: jnp.ndarray,  # (B, Skv)
    causal: bool = True,
    sliding_window: int | None = None,
    kv_valid_len: jnp.ndarray | None = None,  # (B,) valid cache length
) -> jnp.ndarray:
    q, k, v = _expand_kv_for_tp(q, k, v)
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, d)

    scores = jnp.einsum(
        "bqkgd,bskd->bkgqs", qg, k, preferred_element_type=jnp.float32
    ) / jnp.sqrt(d).astype(jnp.float32)

    # mask from absolute positions (works for full, prefill and decode)
    pq = positions_q[:, None, None, :, None]        # (B,1,1,Sq,1)
    pkv = positions_kv[:, None, None, None, :]      # (B,1,1,1,Skv)
    mask = jnp.ones((b, 1, 1, sq, k.shape[1]), dtype=bool)
    if causal:
        mask &= pkv <= pq
    if sliding_window is not None:
        mask &= pq - pkv < sliding_window
    if kv_valid_len is not None:
        mask &= pkv < kv_valid_len[:, None, None, None, None]

    scores = jnp.where(mask, scores, -1e30)
    weights = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", weights, v)
    return out.reshape(b, sq, hq, d)


def attention_block(
    x: jnp.ndarray,            # (B, S, d_model)
    p: dict,                   # wq, wk, wv, wo (+ biases, q/k norms)
    cfg,
    *,
    positions: jnp.ndarray,
    causal: bool = True,
    sliding_window=None,
    cache: dict | None = None,           # {"k","v","len"} for decode
    kv_source: jnp.ndarray | None = None,  # cross-attention memory
):
    """Full attention sub-block: projections + rope + attn + out-proj.

    Returns (out, updated_cache).
    """
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    kv_in = x if kv_source is None else kv_source
    k = jnp.einsum("bsd,dhe->bshe", kv_in, p["wk"])
    v = jnp.einsum("bsd,dhe->bshe", kv_in, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)

    use_rope = kv_source is None  # no rope on cross-attention memory
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)

    if cache is not None and "pos" in cache:
        # ring-buffer cache (sliding-window layers): slot = pos % window
        from repro.models.cache import ring_update

        if use_rope:
            k = apply_rope(k, positions, cfg.rope_theta)
        window = cache["k"].shape[1]
        if s == 1:
            upd = ring_update(cache, k, v, cache["len"])
            out = attention(
                q, upd["k"], upd["v"],
                positions_q=positions, positions_kv=upd["pos"], causal=True,
                sliding_window=sliding_window,
            )
        else:
            # prefill: attend over the full (windowed) sequence, then store
            # only the last `window` keys in the ring.
            out = attention(
                q, k, v, positions_q=positions, positions_kv=positions,
                causal=True, sliding_window=sliding_window,
            )
            keep = min(s, window)
            upd = ring_update(
                cache, k[:, -keep:], v[:, -keep:],
                cache["len"] + s - keep,
            )
        new_cache = {**upd, "len": cache["len"] + s}
    elif cache is not None:
        # decode: write new k/v at position cache["len"], attend over cache
        if use_rope:
            k = apply_rope(k, positions, cfg.rope_theta)
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), cache["len"], axis=1
        )
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), cache["len"], axis=1
        )
        skv = ck.shape[1]
        pos_kv = jnp.broadcast_to(jnp.arange(skv)[None, :], (b, skv))
        valid = jnp.full((b,), cache["len"] + s, jnp.int32)
        # long prefill into a cache: online-softmax path (dense S x S
        # scores at 32k would be ~17 GiB/device)
        use_chunked = s > 1 and skv >= getattr(cfg, "flash_min_seq", 8192)
        attn_fn = attention_chunked if use_chunked else attention
        kw = {"block_kv": cfg.attn_block_kv} if use_chunked else {}
        out = attn_fn(
            q, ck, cv,
            positions_q=positions, positions_kv=pos_kv, causal=causal,
            sliding_window=sliding_window, kv_valid_len=valid, **kw,
        )
        new_cache = {"k": ck, "v": cv, "len": cache["len"] + s}
    else:
        if use_rope:
            kv_pos = positions
            k = apply_rope(k, kv_pos, cfg.rope_theta)
        else:
            kv_pos = jnp.broadcast_to(
                jnp.arange(kv_in.shape[1])[None, :], (b, kv_in.shape[1])
            )
        use_chunked = (
            s >= getattr(cfg, "flash_min_seq", 8192)
            and k.shape[1] >= getattr(cfg, "flash_min_seq", 8192)
        )
        attn_fn = attention_chunked if use_chunked else attention
        kw = {"block_kv": cfg.attn_block_kv} if use_chunked else {}
        out = attn_fn(
            q, k, v,
            positions_q=positions, positions_kv=kv_pos,
            causal=causal and kv_source is None,
            sliding_window=sliding_window, **kw,
        )
        new_cache = None

    out = constrain(out, "batch", None, "tp", None)
    return jnp.einsum("bshe,hed->bsd", out, p["wo"]), new_cache


def attention_params(key, cfg, d_model=None, dtype=jnp.float32) -> dict:
    d = d_model or cfg.d_model
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, hq, hd), in_axis=0, dtype=dtype),
        "wk": dense_init(ks[1], (d, hkv, hd), in_axis=0, dtype=dtype),
        "wv": dense_init(ks[2], (d, hkv, hd), in_axis=0, dtype=dtype),
        "wo": dense_init(ks[3], (hq, hd, d), in_axis=1, dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq, hd), dtype)
        p["bk"] = jnp.zeros((hkv, hd), dtype)
        p["bv"] = jnp.zeros((hkv, hd), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def swiglu(x: jnp.ndarray, p: dict) -> jnp.ndarray:
    gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    h = constrain(h, "batch", None, "tp")
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])


def geglu(x: jnp.ndarray, p: dict) -> jnp.ndarray:
    gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    h = jax.nn.gelu(gate.astype(jnp.float32)).astype(x.dtype) * up
    h = constrain(h, "batch", None, "tp")
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])


def mlp_params(key, d: int, f: int, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (d, f), in_axis=0, dtype=dtype),
        "w_up": dense_init(ks[1], (d, f), in_axis=0, dtype=dtype),
        "w_down": dense_init(ks[2], (f, d), in_axis=0, dtype=dtype),
    }


def softcap(logits: jnp.ndarray, cap: float) -> jnp.ndarray:
    if cap <= 0:
        return logits
    return cap * jnp.tanh(logits / cap)
