"""Mamba-2 (SSD, state-space duality) — the assigned attention-free arch.

The SSD chunked algorithm is the 1-D analogue of the paper's WF-TiS tiled
scan (DESIGN.md §4): the sequence is split into chunks; each chunk computes
a local (intra-tile) result with dense matmuls, produces a boundary state,
and the states are propagated by a short sequential carry scan — exactly
"intra-tile scan + carry propagation", with the MXU-friendly quadratic
intra-chunk form playing the role of the triangular-matmul tile scan in
kernels/wf_tis.py.

Shapes: d_inner = expand * d_model; H = d_inner / ssm_head_dim heads;
B/C projections are per-group (ssm_groups, ssm_state).  fp32 state math.
"""

from __future__ import annotations

import jax
from jax import lax
import jax.numpy as jnp

from repro.models import layers as L
from repro.sharding.rules import constrain


def _dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    nheads = d_in // cfg.ssm_head_dim
    conv_ch = d_in + 2 * cfg.ssm_groups * cfg.ssm_state
    return d_in, nheads, conv_ch


def layer_params(key, cfg, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    d_in, nheads, conv_ch = _dims(cfg)
    proj_out = 2 * d_in + 2 * cfg.ssm_groups * cfg.ssm_state + nheads
    ks = jax.random.split(key, 4)
    return {
        "norm": L.norm_params(d, False, dtype),
        "in_proj": L.dense_init(ks[0], (d, proj_out), in_axis=0, dtype=dtype),
        "conv_w": L.dense_init(ks[1], (cfg.conv_kernel, conv_ch), in_axis=0,
                               dtype=dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.zeros((nheads,), jnp.float32),          # A = -exp(0) = -1
        "D": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "ssm_norm": L.norm_params(d_in, False, dtype),
        "out_proj": L.dense_init(ks[2], (d_in, d), in_axis=0, dtype=dtype),
    }


def init_params(key, cfg, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 3)
    params = {
        "embed": L.embed_init(ks[0], (cfg.padded_vocab, cfg.d_model), dtype),
        "final_norm": L.norm_params(cfg.d_model, False, dtype),
        "layers": jax.vmap(lambda k: layer_params(k, cfg, dtype))(
            jax.random.split(ks[1], cfg.num_layers)),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(
            ks[2], (cfg.d_model, cfg.padded_vocab), in_axis=0, dtype=dtype)
    return params


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 tail: jnp.ndarray | None = None):
    """Depthwise causal conv1d. x: (B, S, C); w: (K, C).

    tail: (B, K-1, C) previous inputs (decode); returns (y, new_tail).
    """
    k = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(k))
    new_tail = xp[:, -(k - 1) :, :] if k > 1 else tail
    return y + b, new_tail


def _segsum_decay(a_cum: jnp.ndarray) -> jnp.ndarray:
    """L[i, j] = exp(a_cum_i - a_cum_j) for j <= i else 0.  a_cum: (..., Q)."""
    q = a_cum.shape[-1]
    diff = a_cum[..., :, None] - a_cum[..., None, :]
    tri = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(tri, jnp.exp(diff), 0.0)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, h0=None):
    """Chunked SSD scan (fp32).

    x:  (B, S, H, P) values            dt: (B, S, H) positive step sizes
    A:  (H,) negative decay rates      Bm/Cm: (B, S, G, N)
    h0: optional (B, H, N, P) initial state (prefill-into-state).
    Returns (y (B, S, H, P), h_last (B, H, N, P)).
    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t^T;  y_t = C_t h_t.
    """
    b, s, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = s + pad
    nc = sp // chunk
    hg = h // g                                        # heads per group

    def to_chunks(t):
        return t.reshape(t.shape[0], nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    xc, dtc, Bc, Cc = map(to_chunks, (x, dt, Bm, Cm))   # (nc, B, Q, ...)

    def chunk_step(hstate, blk):
        xq, dtq, Bq, Cq = blk                           # (B,Q,H,P),(B,Q,H),(B,Q,G,N)
        a = dtq * A                                     # (B,Q,H) log-decays <= 0
        a_cum = jnp.cumsum(a, axis=1)                   # (B,Q,H)
        # intra-chunk: scores[q1,q2] = C_{q1} . B_{q2} per group
        scores = jnp.einsum("bqgn,bsgn->bgqs", Cq, Bq,
                            preferred_element_type=jnp.float32)
        Lmask = _segsum_decay(a_cum.swapaxes(1, 2))     # (B,H,Q,Q)
        Lmask = Lmask.reshape(b, g, hg, chunk, chunk)
        M = scores[:, :, None] * Lmask                  # (B,G,hg,Q,Q)
        xdt = xq * dtq[..., None]                       # (B,Q,H,P)
        xdtg = xdt.reshape(b, chunk, g, hg, p)
        y_intra = jnp.einsum("bghqs,bsghp->bqghp", M, xdtg,
                             preferred_element_type=jnp.float32)
        # inter-chunk: contribution of the carried state
        decay_in = jnp.exp(a_cum)                       # (B,Q,H)
        y_inter = jnp.einsum("bqgn,bghnp->bqghp",
                             Cq, hstate.reshape(b, g, hg, n, p),
                             preferred_element_type=jnp.float32)
        y_inter = y_inter * decay_in.reshape(b, chunk, g, hg)[..., None]
        y = (y_intra + y_inter).reshape(b, chunk, h, p)
        # new boundary state (the carry): decayed old + this chunk's input
        total = a_cum[:, -1]                            # (B,H)
        decay_out = jnp.exp(total[:, None] - a_cum)     # (B,Q,H)
        state_new = jnp.einsum("bqgn,bqghp->bghnp",
                               Bq, (xdtg * decay_out.reshape(
                                   b, chunk, g, hg)[..., None]),
                               preferred_element_type=jnp.float32)
        hstate = hstate * jnp.exp(total).reshape(
            b, h)[..., None, None] + state_new.reshape(b, h, n, p)
        return hstate, y

    if h0 is None:
        h0 = jnp.zeros((b, h, n, p), jnp.float32)
    h_last, ys = jax.lax.scan(chunk_step, h0, (xc, dtc, Bc, Cc))
    y = ys.swapaxes(0, 1).reshape(b, sp, h, p)
    return y[:, :s], h_last


def ssd_seq_parallel(xh, dt, A, Bm, Cm, chunk: int, mesh, rules, h0=None):
    """Sequence-parallel SSD: sequence sharded over the model axis.

    Each rank runs the chunked scan on its sequence shard from a zero
    state; shard-boundary (log-decay, state) summaries then propagate
    across ranks with an exclusive Hillis-Steele ppermute ladder — the
    WF-TiS boundary-carry pattern lifted from VMEM scratch to ICI
    (identical in structure to core/distributed.spatial_sharded_ih) —
    and each rank folds the incoming prefix state into its outputs.

    Returns (y, h_final) with y sequence-sharded like the input.
    """
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    seq_ax = rules.present(mesh, rules.tp_axes)[0]
    batch_axes = rules.present(mesh, rules.batch_axes)
    b_ax = batch_axes if len(batch_axes) > 1 else (
        batch_axes[0] if batch_axes else None)
    d = mesh.shape[seq_ax]

    def inner(xh, dt, Bm, Cm, h_init):
        b, s, h, pdim = xh.shape
        g, n = Bm.shape[2], Bm.shape[3]
        hg = h // g
        # an incoming initial state seeds rank 0's local scan only; its
        # effect reaches later ranks through the boundary-carry prefix.
        first = (lax.axis_index(seq_ax) == 0).astype(h_init.dtype)
        y, h_last = ssd_chunked(xh, dt, A, Bm, Cm, chunk,
                                h0=h_init * first)
        a = dt * A                                      # (B, S_loc, H)
        a_sum = jnp.sum(a, axis=1)                      # (B, H)

        # exclusive prefix of (log-decay, state) across seq ranks.
        # ppermute fills non-destinations with zeros == the identity
        # (decay exp(0)=1, state 0).
        ld = lax.ppermute(a_sum, seq_ax,
                          [(i, i + 1) for i in range(d - 1)])
        hs = lax.ppermute(h_last, seq_ax,
                          [(i, i + 1) for i in range(d - 1)])
        step = 1
        while step < d:
            perm = [(i, i + step) for i in range(d - step)]
            ld_in = lax.ppermute(ld, seq_ax, perm)
            hs_in = lax.ppermute(hs, seq_ax, perm)
            # compose earlier-interval (in) then current: the incoming
            # state decays through the current interval.
            hs = jnp.exp(ld)[..., None, None] * hs_in + hs
            ld = ld + ld_in
            step *= 2

        # fold the prefix state into this shard's outputs
        a_cum = jnp.cumsum(a, axis=1)                   # (B, S_loc, H)
        y_corr = jnp.einsum(
            "bsgn,bghnp->bsghp", Cm,
            hs.reshape(b, g, hg, n, pdim),
            preferred_element_type=jnp.float32)
        y = y + (y_corr * jnp.exp(a_cum).reshape(
            b, s, g, hg)[..., None]).reshape(b, s, h, pdim)

        # global final state (inclusive prefix on the last rank)
        h_inc = jnp.exp(a_sum)[..., None, None] * hs + h_last
        is_last = (lax.axis_index(seq_ax) == d - 1).astype(h_inc.dtype)
        h_fin = lax.psum(h_inc * is_last, seq_ax)
        return y, h_fin

    if h0 is None:
        b, h = xh.shape[0], xh.shape[2]
        n, pdim = Bm.shape[-1], xh.shape[-1]
        h0 = jnp.zeros((b, h, n, pdim), jnp.float32)
    return shard_map(
        inner, mesh=mesh,
        in_specs=(P(b_ax, seq_ax, None, None), P(b_ax, seq_ax, None),
                  P(b_ax, seq_ax, None, None), P(b_ax, seq_ax, None, None),
                  P(b_ax, None, None, None)),
        out_specs=(P(b_ax, seq_ax, None, None), P(b_ax, None, None, None)),
        check_vma=False,
    )(xh, dt, Bm, Cm, h0)


def _mixer(x, p, cfg, state_layer=None):
    """Mamba-2 mixer. x: (B, S, d). Returns (out, new_state_layer)."""
    b, s, d = x.shape
    d_in, nheads, conv_ch = _dims(cfg)
    g, n, phd = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_head_dim

    proj = jnp.einsum("bsd,df->bsf", x, p["in_proj"])
    z = proj[..., :d_in]
    xbc = proj[..., d_in : d_in + conv_ch]
    dt = proj[..., d_in + conv_ch :]
    conv_tail = state_layer["conv"] if state_layer is not None else None
    xbc, new_tail = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_tail)
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x.dtype)
    xs = xbc[..., : d_in]
    Bm = xbc[..., d_in : d_in + g * n].reshape(b, s, g, n)
    Cm = xbc[..., d_in + g * n :].reshape(b, s, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    A = -jnp.exp(p["A_log"])                                       # (H,)
    xh = xs.reshape(b, s, nheads, phd).astype(jnp.float32)
    Bm32, Cm32 = Bm.astype(jnp.float32), Cm.astype(jnp.float32)

    if state_layer is None:
        from repro.sharding.rules import current_context
        ctx = current_context()
        use_sp = (cfg.ssm_seq_parallel and ctx is not None
                  and s % ctx.mesh.shape[
                      ctx.rules.present(ctx.mesh, ctx.rules.tp_axes)[0]] == 0)
        if use_sp:
            y, _ = ssd_seq_parallel(xh, dt, A, Bm32, Cm32, cfg.ssm_chunk,
                                    ctx.mesh, ctx.rules)
        else:
            y, _ = ssd_chunked(xh, dt, A, Bm32, Cm32, cfg.ssm_chunk)
        new_state = None
    elif s > 1:
        # prefill into an existing state: chunked scan seeded with it.
        # Note: prefill assumes an empty conv tail (fresh sequence).
        from repro.sharding.rules import current_context
        ctx = current_context()
        use_sp = (cfg.ssm_seq_parallel and ctx is not None
                  and s % ctx.mesh.shape[
                      ctx.rules.present(ctx.mesh, ctx.rules.tp_axes)[0]] == 0)
        if use_sp:
            y, h_last = ssd_seq_parallel(
                xh, dt, A, Bm32, Cm32, cfg.ssm_chunk, ctx.mesh, ctx.rules,
                h0=state_layer["h"].swapaxes(-1, -2))
        else:
            y, h_last = ssd_chunked(xh, dt, A, Bm32, Cm32, cfg.ssm_chunk,
                                    h0=state_layer["h"].swapaxes(-1, -2))
        new_state = {"h": h_last.swapaxes(-1, -2), "conv": new_tail}
    else:
        # decode: s == 1 single-step recurrence
        h0 = state_layer["h"]                          # (B,H,P,N)
        a = jnp.exp(dt[:, 0] * A)                      # (B,H)
        hg = nheads // g
        xdt = (xh[:, 0] * dt[:, 0][..., None]).reshape(b, g, hg, phd)
        binp = jnp.einsum("bgn,bghp->bghpn", Bm32[:, 0], xdt)
        h1 = h0 * a[..., None, None] + binp.reshape(b, nheads, phd, n)
        y = jnp.einsum("bgn,bghpn->bghp", Cm32[:, 0],
                       h1.reshape(b, g, hg, phd, n)).reshape(b, 1, nheads, phd)
        new_state = {"h": h1, "conv": new_tail}
    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(b, s, d_in).astype(x.dtype)
    y = L.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                   p["ssm_norm"]["scale"], cfg.norm_eps)
    y = constrain(y, "batch", None, "tp")
    return jnp.einsum("bsf,fd->bsd", y, p["out_proj"]), new_state


def _block(x, p, cfg, state_layer=None):
    if cfg.ssm_seq_parallel and x.shape[1] > 1:
        # seq-shard the whole block's activations over the model axis so
        # the projections/conv/gating around the SP scan are also 1/|tp|
        # per chip (conv halo = collective-permute of K-1=3 rows).
        x = constrain(x, "batch", "tp", None)
    h = L.rms_norm(x, p["norm"]["scale"], cfg.norm_eps)
    h, new_state = _mixer(h, p, cfg, state_layer)
    x = x + h
    x = constrain(x, "batch", "tp" if cfg.ssm_seq_parallel and
                  x.shape[1] > 1 else None, None)
    return x, new_state


def forward(params, tokens, cfg, *, prefix_embeds=None, cache=None,
            positions=None):
    """Returns (logits, aux=0, new_cache). cache = ssm_state pytree."""
    params = L.cast_params(params, cfg.dtype)
    b, s = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(cfg.dtype), x], axis=1)
    x = constrain(x, "batch", None, None)

    if cache is None:
        def body(h, p_layer):
            h, _ = _block(h, p_layer, cfg)
            return h, None
        if cfg.remat == "dots":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.checkpoint_dots)
        elif cfg.remat == "full":
            body = jax.checkpoint(body)
        x, _ = L.scan_or_unroll(body, x, params["layers"], cfg.scan_layers)
        new_cache = None
    else:
        ln = cache["len"]
        def body(h, xs):
            p_layer, c = xs
            h, new_state = _block(h, p_layer, cfg, c)
            return h, new_state
        kv = {"h": cache["h"], "conv": cache["conv"]}
        x, new_kv = L.scan_or_unroll(body, x, (params["layers"], kv),
                                     cfg.scan_layers)
        new_cache = {"h": new_kv["h"], "conv": new_kv["conv"], "len": ln + s}

    x = L.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(cfg.dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(cfg.dtype))
    logits = constrain(logits.astype(jnp.float32), "batch", None, "tp")
    return logits, jnp.zeros((), jnp.float32), new_cache


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    from repro.models.cache import ssm_state

    d_in, nheads, conv_ch = _dims(cfg)
    return ssm_state(cfg.num_layers, batch, nheads, cfg.ssm_head_dim,
                     cfg.ssm_state, conv_ch, cfg.conv_kernel)
