"""Model zoo: the 10 assigned architectures as composable JAX modules.

transformer.py — decoder-only LMs (dense + MoE + multimodal prefix stubs)
encdec.py      — encoder-decoder (SeamlessM4T backbone)
ssm.py         — Mamba-2 (SSD chunked scan)
griffin.py     — RecurrentGemma (RG-LRU + local attention hybrid)

Every model exposes:  init_params(rng, cfg), forward(params, batch, cfg),
and the family-appropriate decode path via models/api.py dispatch.
"""

from repro.models.api import (
    init_params, forward, init_cache, prefill, decode_step, loss_fn,
)

__all__ = [
    "init_params", "forward", "init_cache", "prefill", "decode_step", "loss_fn",
]
