"""Griffin / RecurrentGemma: RG-LRU recurrent blocks + local attention.

Layer pattern (cfg.block_pattern, e.g. ("rec", "rec", "attn")) tiles the
depth; full pattern-groups are stacked and lax.scan'ed, the remainder is a
short unstacked tail (38 = 12 x (rec,rec,attn) + (rec,rec)).

The RG-LRU prefill is a 1-D gated linear recurrence computed CHUNK-WISE:
intra-chunk associative scan + a sequential carry over chunk boundaries —
the same tiled-scan-plus-carry structure as the paper's WF-TiS kernel
(DESIGN.md §4).  Decode is a single-step recurrence; the local-attention
layers use ring-buffer KV caches of exactly `sliding_window` slots, which
is what makes long_500k decode runnable (2k live keys at position 512k).

Gates are per-channel (diagonal) rather than block-diagonal dense — noted
in DESIGN.md §7 deviations; parameter counts follow config.param_count.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.sharding.rules import constrain

_C = 8.0  # RG-LRU exponent scale (Griffin paper)


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------
def _rec_mixer_params(key, cfg, dtype) -> dict:
    d, w = cfg.d_model, cfg.rnn_width
    ks = jax.random.split(key, 3)
    return {
        "w_branch_gate": L.dense_init(ks[0], (d, w), in_axis=0, dtype=dtype),
        "w_branch_x": L.dense_init(ks[1], (d, w), in_axis=0, dtype=dtype),
        "conv_w": L.dense_init(jax.random.fold_in(key, 7), (cfg.conv_kernel, w),
                               in_axis=0, dtype=dtype),
        "conv_b": jnp.zeros((w,), dtype),
        # a = sigmoid(lam); init so a^c ~ 0.9..0.999 (long memory)
        "lam": jnp.linspace(2.0, 6.0, w, dtype=jnp.float32),
        "g_a": jnp.zeros((w,), jnp.float32),
        "b_a": jnp.zeros((w,), jnp.float32),
        "g_x": jnp.zeros((w,), jnp.float32),
        "b_x": jnp.zeros((w,), jnp.float32),
        "w_out": L.dense_init(ks[2], (w, d), in_axis=0, dtype=dtype),
    }


def _layer_params(key, cfg, kind: str, dtype) -> dict:
    k_mix, k_mlp = jax.random.split(key)
    d = cfg.d_model
    p = {
        "norm1": L.norm_params(d, False, dtype),
        "norm2": L.norm_params(d, False, dtype),
        "mlp": L.mlp_params(k_mlp, d, cfg.d_ff, dtype=dtype),
    }
    if kind == "rec":
        p["rec"] = _rec_mixer_params(k_mix, cfg, dtype)
    else:
        p["attn"] = L.attention_params(k_mix, cfg, dtype=dtype)
    return p


def _pattern(cfg):
    p = cfg.block_pattern or ("rec", "rec", "attn")
    n_groups = cfg.num_layers // len(p)
    rem = cfg.num_layers - n_groups * len(p)
    return p, n_groups, p[:rem]


def init_params(key, cfg, dtype=jnp.float32) -> dict:
    pat, n_groups, tail = _pattern(cfg)
    ks = jax.random.split(key, 4)

    def group_params(k):
        gks = jax.random.split(k, len(pat))
        return {f"b{j}": _layer_params(gks[j], cfg, kind, dtype)
                for j, kind in enumerate(pat)}

    params = {
        "embed": L.embed_init(ks[0], (cfg.padded_vocab, cfg.d_model), dtype),
        "final_norm": L.norm_params(cfg.d_model, False, dtype),
        "groups": {"layers": jax.vmap(group_params)(
            jax.random.split(ks[1], n_groups))},
    }
    if tail:
        tks = jax.random.split(ks[2], len(tail))
        params["tail"] = {f"b{j}": _layer_params(tks[j], cfg, kind, dtype)
                          for j, kind in enumerate(tail)}
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(
            ks[3], (cfg.d_model, cfg.padded_vocab), in_axis=0, dtype=dtype)
    return params


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------
def _rglru_gates(u, p):
    """u: (B, S, w) fp32. Returns (log_a, b) of the linear recurrence
    h_t = exp(log_a_t) h_{t-1} + b_t."""
    r = jax.nn.sigmoid(u * p["g_a"] + p["b_a"])
    i = jax.nn.sigmoid(u * p["g_x"] + p["b_x"])
    log_a = -_C * r * jax.nn.softplus(-p["lam"])          # <= 0
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * u)
    return log_a, b


def _rglru_chunked(u, p, chunk: int, h0):
    """Chunked linear scan. u: (B, S, w) fp32; h0: (B, w).

    Returns (h_seq (B, S, w), h_last).  Intra-chunk associative scan,
    sequential carry across chunks (tiled-scan-with-carry pattern).
    """
    bsz, s, w = u.shape
    # gates BEFORE padding: padded steps get (log_a=0, b=0) = identity,
    # so the carried state is exact for any (s % chunk).
    log_a, bgate = _rglru_gates(u, p)
    pad = (-s) % chunk
    if pad:
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))
        bgate = jnp.pad(bgate, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // chunk

    def to_chunks(t):
        return t.reshape(bsz, nc, chunk, w).swapaxes(0, 1)   # (nc, B, Q, w)

    def combine(x, y):
        (la1, b1), (la2, b2) = x, y
        return la1 + la2, jnp.exp(la2) * b1 + b2

    def chunk_step(h, blk):
        la_blk, b_blk = blk
        la_cum, b_cum = jax.lax.associative_scan(
            combine, (la_blk, b_blk), axis=1)
        h_seq = b_cum + jnp.exp(la_cum) * h[:, None, :]
        return h_seq[:, -1, :], h_seq

    h_last, hs = jax.lax.scan(chunk_step, h0,
                              (to_chunks(log_a), to_chunks(bgate)))
    hs = hs.swapaxes(0, 1).reshape(bsz, nc * chunk, w)
    return hs[:, :s], h_last


def _rglru_seq_parallel(u, p, chunk: int, mesh, rules, h0=None):
    """Sequence-parallel RG-LRU: S sharded over the model axis.

    Same structure as models/ssm.ssd_seq_parallel — each rank scans its
    shard locally, then (log-decay, state) boundary summaries propagate
    with an exclusive ppermute Hillis-Steele ladder (the WF-TiS carry at
    ICI scale; states here are the diagonal (B, w) RG-LRU hiddens).
    Returns (h_seq, h_last), h_seq sequence-sharded like u.
    """
    from jax import lax
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    seq_ax = rules.present(mesh, rules.tp_axes)[0]
    batch_axes = rules.present(mesh, rules.batch_axes)
    b_ax = batch_axes if len(batch_axes) > 1 else (
        batch_axes[0] if batch_axes else None)
    d = mesh.shape[seq_ax]

    def inner(u_shard, h_init):
        first = (lax.axis_index(seq_ax) == 0).astype(h_init.dtype)
        # local scan needs per-position cumulative decay for the prefix
        # correction, so run the gate+scan here rather than reusing the
        # chunked helper's outputs alone.
        log_a, bgate = _rglru_gates(u_shard, p)
        la_cum = jnp.cumsum(log_a, axis=1)               # (B, S_loc, w)

        def combine(xc, yc):
            (la1, b1), (la2, b2) = xc, yc
            return la1 + la2, jnp.exp(la2) * b1 + b2

        _, b_cum = jax.lax.associative_scan(
            combine, (log_a, bgate), axis=1)
        hs = b_cum + jnp.exp(la_cum) * (h_init * first)[:, None, :]
        h_last = hs[:, -1, :]
        la_sum = la_cum[:, -1, :]                        # (B, w)

        # exclusive prefix of (log-decay, state) across seq ranks
        ld = lax.ppermute(la_sum, seq_ax,
                          [(i, i + 1) for i in range(d - 1)])
        hp = lax.ppermute(h_last, seq_ax,
                          [(i, i + 1) for i in range(d - 1)])
        step = 1
        while step < d:
            perm = [(i, i + step) for i in range(d - step)]
            ld_in = lax.ppermute(ld, seq_ax, perm)
            hp_in = lax.ppermute(hp, seq_ax, perm)
            hp = jnp.exp(ld) * hp_in + hp
            ld = ld + ld_in
            step *= 2

        hs = hs + jnp.exp(la_cum) * hp[:, None, :]
        h_fin_local = hs[:, -1, :]
        is_last = (lax.axis_index(seq_ax) == d - 1).astype(hs.dtype)
        h_fin = lax.psum(h_fin_local * is_last, seq_ax)
        return hs, h_fin

    if h0 is None:
        h0 = jnp.zeros((u.shape[0], u.shape[-1]), jnp.float32)
    return shard_map(
        inner, mesh=mesh,
        in_specs=(P(b_ax, seq_ax, None), P(b_ax, None)),
        out_specs=(P(b_ax, seq_ax, None), P(b_ax, None)),
        check_vma=False,
    )(u, h0)


def _rec_mixer(x, p, cfg, state_layer=None):
    """Griffin recurrent block mixer. Returns (out, new_state)."""
    gate = jax.nn.gelu(
        jnp.einsum("bsd,dw->bsw", x, p["w_branch_gate"]).astype(jnp.float32))
    u = jnp.einsum("bsd,dw->bsw", x, p["w_branch_x"])
    conv_tail = state_layer["conv"] if state_layer is not None else None
    from repro.models.ssm import _causal_conv
    u, new_tail = _causal_conv(u, p["conv_w"], p["conv_b"], conv_tail)
    u = u.astype(jnp.float32)
    u = constrain(u, "batch", None, "tp")

    from repro.sharding.rules import current_context
    ctx = current_context()
    s_len = u.shape[1]
    use_sp = (cfg.rnn_seq_parallel and ctx is not None and s_len > 1
              and s_len % ctx.mesh.shape[
                  ctx.rules.present(ctx.mesh, ctx.rules.tp_axes)[0]] == 0)

    if state_layer is None:
        h0 = jnp.zeros((x.shape[0], u.shape[-1]), jnp.float32)
        if use_sp:
            h, _ = _rglru_seq_parallel(u, p, cfg.rnn_scan_chunk,
                                       ctx.mesh, ctx.rules, h0)
        else:
            h, _ = _rglru_chunked(u, p, cfg.rnn_scan_chunk, h0)
        new_state = None
    elif u.shape[1] > 1:
        # prefill into an existing state: scan seeded with it
        if use_sp:
            h, h_last = _rglru_seq_parallel(u, p, cfg.rnn_scan_chunk,
                                            ctx.mesh, ctx.rules,
                                            state_layer["h"])
        else:
            h, h_last = _rglru_chunked(u, p, cfg.rnn_scan_chunk,
                                       state_layer["h"])
        new_state = {"h": h_last, "conv": new_tail}
    else:
        log_a, b = _rglru_gates(u, p)                      # (B, 1, w)
        h1 = jnp.exp(log_a[:, 0]) * state_layer["h"] + b[:, 0]
        h = h1[:, None, :]
        new_state = {"h": h1, "conv": new_tail}
    out = (h * gate).astype(x.dtype)
    out = constrain(out, "batch", None, "tp")
    return jnp.einsum("bsw,wd->bsd", out, p["w_out"]), new_state


# ---------------------------------------------------------------------------
# Blocks / forward
# ---------------------------------------------------------------------------
def _layer(x, p, cfg, kind, *, positions, cache_layer=None):
    h = L.rms_norm(x, p["norm1"]["scale"], cfg.norm_eps)
    if kind == "rec":
        h, new_cache = _rec_mixer(h, p["rec"], cfg, cache_layer)
    else:
        h, new_cache = L.attention_block(
            h, p["attn"], cfg, positions=positions, causal=True,
            sliding_window=cfg.sliding_window, cache=cache_layer,
        )
    x = x + h
    h = L.rms_norm(x, p["norm2"]["scale"], cfg.norm_eps)
    x = x + L.geglu(h, p["mlp"])
    return constrain(x, "batch", None, None), new_cache


def forward(params, tokens, cfg, *, prefix_embeds=None, cache=None,
            positions=None):
    params = L.cast_params(params, cfg.dtype)
    pat, n_groups, tail = _pattern(cfg)
    b, s = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(cfg.dtype), x], axis=1)
        s = x.shape[1]
    if cfg.scale_embeddings:
        x = x * jnp.sqrt(cfg.d_model).astype(cfg.dtype)
    if positions is None:
        base = cache["len"] if cache is not None else 0
        positions = jnp.broadcast_to(base + jnp.arange(s)[None, :], (b, s))
    x = constrain(x, "batch", None, None)

    if cache is None:
        def body(h, p_group):
            for j, kind in enumerate(pat):
                h, _ = _layer(h, p_group[f"b{j}"], cfg, kind,
                              positions=positions)
            return h, None
        if cfg.remat == "dots":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.checkpoint_dots)
        elif cfg.remat == "full":
            body = jax.checkpoint(body)
        x, _ = L.scan_or_unroll(body, x, params["groups"]["layers"],
                                cfg.scan_layers)
        for j, kind in enumerate(tail):
            x, _ = _layer(x, params["tail"][f"b{j}"], cfg, kind,
                          positions=positions)
        new_cache = None
    else:
        ln = cache["len"]
        def body(h, xs):
            p_group, c_group = xs
            new_c = {}
            for j, kind in enumerate(pat):
                cl = dict(c_group[f"b{j}"])
                if kind == "attn":
                    cl["len"] = ln
                h, nc = _layer(h, p_group[f"b{j}"], cfg, kind,
                               positions=positions, cache_layer=cl)
                if kind == "attn":
                    nc = {k: v for k, v in nc.items() if k != "len"}
                new_c[f"b{j}"] = nc
            return h, new_c
        group_cache = cache["groups"]
        x, new_groups = L.scan_or_unroll(
            body, x, (params["groups"]["layers"], group_cache),
            cfg.scan_layers)
        new_cache = {"groups": new_groups, "len": ln + s}
        if tail:
            new_cache["tail"] = {}
            for j, kind in enumerate(tail):
                cl = dict(cache["tail"][f"b{j}"])
                if kind == "attn":
                    cl["len"] = ln
                x, nc = _layer(x, params["tail"][f"b{j}"], cfg, kind,
                               positions=positions, cache_layer=cl)
                if kind == "attn":
                    nc = {k: v for k, v in nc.items() if k != "len"}
                new_cache["tail"][f"b{j}"] = nc

    x = L.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(cfg.dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(cfg.dtype))
    logits = L.softcap(logits.astype(jnp.float32), cfg.logits_softcap)
    logits = constrain(logits, "batch", None, "tp")
    return logits, jnp.zeros((), jnp.float32), (
        new_cache if cache is not None else None)


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    from repro.models.cache import ring_kv_cache, rglru_state

    pat, n_groups, tail = _pattern(cfg)
    window = min(cfg.sliding_window or max_len, max_len)

    def layer_cache(kind, n):
        if kind == "attn":
            c = ring_kv_cache(n, batch, window, cfg.num_kv_heads,
                              cfg.head_dim, dtype)
            return {k: v for k, v in c.items() if k != "len"}
        c = rglru_state(n, batch, cfg.rnn_width, cfg.conv_kernel)
        return c

    cache = {
        "groups": {f"b{j}": layer_cache(kind, n_groups)
                   for j, kind in enumerate(pat)},
        "len": jnp.zeros((), jnp.int32),
    }
    if tail:
        cache["tail"] = {f"b{j}": jax.tree.map(lambda t: t[0], layer_cache(kind, 1))
                         for j, kind in enumerate(tail)}
    return cache
