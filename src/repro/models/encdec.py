"""Encoder-decoder backbone (SeamlessM4T-v2 assignment).

The audio frontend is a STUB per the assignment: `src_embeds` are
precomputed frame embeddings (B, S_src, d_model) delivered by
input_specs; the backbone is the conformer-less transformer enc-dec.

Decode-time cross-attention K/V are computed once from the encoder memory
at prefill and cached (cache["cross_k"/"cross_v"], (L, B, S_src, Hkv, D));
decoder self-attention uses the standard stacked KV cache.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.sharding.rules import constrain


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------
def _enc_layer(key, cfg, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    d = cfg.d_model
    return {
        "norm1": L.norm_params(d, cfg.use_layer_norm, dtype),
        "attn": L.attention_params(k1, cfg, dtype=dtype),
        "norm2": L.norm_params(d, cfg.use_layer_norm, dtype),
        "mlp": L.mlp_params(k2, d, cfg.d_ff, dtype=dtype),
    }


def _dec_layer(key, cfg, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.d_model
    return {
        "norm1": L.norm_params(d, cfg.use_layer_norm, dtype),
        "self_attn": L.attention_params(k1, cfg, dtype=dtype),
        "norm_c": L.norm_params(d, cfg.use_layer_norm, dtype),
        "cross_attn": L.attention_params(k2, cfg, dtype=dtype),
        "norm2": L.norm_params(d, cfg.use_layer_norm, dtype),
        "mlp": L.mlp_params(k3, d, cfg.d_ff, dtype=dtype),
    }


def init_params(key, cfg, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 5)
    d, v = cfg.d_model, cfg.padded_vocab
    params = {
        "embed": L.embed_init(ks[0], (v, d), dtype),
        "encoder": {"layers": jax.vmap(lambda k: _enc_layer(k, cfg, dtype))(
            jax.random.split(ks[1], cfg.num_encoder_layers))},
        "enc_norm": L.norm_params(d, cfg.use_layer_norm, dtype),
        "decoder": {"layers": jax.vmap(lambda k: _dec_layer(k, cfg, dtype))(
            jax.random.split(ks[2], cfg.num_decoder_layers))},
        "final_norm": L.norm_params(d, cfg.use_layer_norm, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(ks[3], (d, v), in_axis=0, dtype=dtype)
    return params


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------
def encode(params, src_embeds, cfg):
    """src_embeds: (B, S_src, d) stub frontend output -> memory."""
    b, s, _ = src_embeds.shape
    x = src_embeds.astype(cfg.dtype)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    x = constrain(x, "batch", None, None)

    def body(h, p):
        hh = L.norm(h, p["norm1"], cfg.norm_eps, cfg.use_layer_norm)
        hh, _ = L.attention_block(hh, p["attn"], cfg, positions=positions,
                                  causal=False)
        h = h + hh
        hh = L.norm(h, p["norm2"], cfg.norm_eps, cfg.use_layer_norm)
        h = h + L.swiglu(hh, p["mlp"])
        return constrain(h, "batch", None, None), None

    if cfg.remat == "dots":
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.checkpoint_dots)
    elif cfg.remat == "full":
        body = jax.checkpoint(body)
    x, _ = L.scan_or_unroll(body, x, params["encoder"]["layers"],
                            cfg.scan_layers)
    return L.norm(x, params["enc_norm"], cfg.norm_eps, cfg.use_layer_norm)


# ---------------------------------------------------------------------------
# Decoder
# ---------------------------------------------------------------------------
def _cross_kv(memory, p):
    k = jnp.einsum("bsd,dhe->bshe", memory, p["wk"])
    v = jnp.einsum("bsd,dhe->bshe", memory, p["wv"])
    return k, v


def _cross_attend(x, p, ck, cv, cfg):
    """Cross-attention with precomputed memory K/V (no rope, full mask).
    Long memories use the online-softmax path (dense tgt x src scores at
    32k x 32k would be ~8 GiB/device)."""
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    zq = jnp.zeros((b, s), jnp.int32)
    zk = jnp.zeros((b, ck.shape[1]), jnp.int32)
    long = s * ck.shape[1] >= cfg.flash_min_seq ** 2
    attn_fn = L.attention_chunked if long else L.attention
    kw = {"block_kv": cfg.attn_block_kv} if long else {}
    out = attn_fn(q, ck, cv, positions_q=zq, positions_kv=zk, causal=False,
                  **kw)
    return jnp.einsum("bshe,hed->bsd", out, p["wo"])


def _dec_block(x, p, cfg, *, positions, memory=None, cache_layer=None,
               cross_kv=None):
    h = L.norm(x, p["norm1"], cfg.norm_eps, cfg.use_layer_norm)
    h, new_self = L.attention_block(h, p["self_attn"], cfg,
                                    positions=positions, causal=True,
                                    cache=cache_layer)
    x = x + h
    h = L.norm(x, p["norm_c"], cfg.norm_eps, cfg.use_layer_norm)
    if cross_kv is not None:
        ck, cv = cross_kv
    else:
        ck, cv = _cross_kv(memory, p["cross_attn"])
    x = x + _cross_attend(h, p["cross_attn"], ck, cv, cfg)
    h = L.norm(x, p["norm2"], cfg.norm_eps, cfg.use_layer_norm)
    x = x + L.swiglu(h, p["mlp"])
    return constrain(x, "batch", None, None), new_self, (ck, cv)


def forward(params, tokens, cfg, *, src_embeds=None, memory=None,
            cache=None, positions=None):
    """Train/prefill: pass src_embeds (or precomputed memory).
    Decode: pass cache only (cross K/V come from the cache).

    Returns (logits, aux=0, new_cache or None).
    """
    params = L.cast_params(params, cfg.dtype)
    b, s = tokens.shape
    if memory is None and src_embeds is not None:
        memory = encode(params, src_embeds, cfg)
    x = params["embed"][tokens].astype(cfg.dtype)
    if cfg.scale_embeddings:
        x = x * jnp.sqrt(cfg.d_model).astype(cfg.dtype)
    if positions is None:
        base = cache["len"] if cache is not None else 0
        positions = jnp.broadcast_to(base + jnp.arange(s)[None, :], (b, s))
    x = constrain(x, "batch", None, None)

    if cache is None:
        def body(h, p):
            h, _, _ = _dec_block(h, p, cfg, positions=positions, memory=memory)
            return h, None
        if cfg.remat == "dots":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.checkpoint_dots)
        elif cfg.remat == "full":
            body = jax.checkpoint(body)
        x, _ = L.scan_or_unroll(body, x, params["decoder"]["layers"],
                                cfg.scan_layers)
        new_cache = None
    else:
        ln = cache["len"]
        build_cross = memory is not None          # prefill

        def body(h, xs):
            p, c = xs
            cl = {"k": c["k"], "v": c["v"], "len": ln}
            ckv = None if build_cross else (c["cross_k"], c["cross_v"])
            h, new_self, (ck, cv) = _dec_block(
                h, p, cfg, positions=positions, memory=memory,
                cache_layer=cl, cross_kv=ckv)
            out = {"k": new_self["k"], "v": new_self["v"],
                   "cross_k": ck.astype(c["cross_k"].dtype),
                   "cross_v": cv.astype(c["cross_v"].dtype)}
            return h, out

        xs_cache = {k: cache[k] for k in ("k", "v", "cross_k", "cross_v")}
        x, new_kv = L.scan_or_unroll(
            body, x, (params["decoder"]["layers"], xs_cache),
            cfg.scan_layers)
        new_cache = dict(new_kv)
        new_cache["len"] = ln + s

    x = L.norm(x, params["final_norm"], cfg.norm_eps, cfg.use_layer_norm)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(cfg.dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(cfg.dtype))
    logits = constrain(logits.astype(jnp.float32), "batch", None, "tp")
    return logits, jnp.zeros((), jnp.float32), new_cache


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16,
               src_len: int | None = None) -> dict:
    lyr, hkv, hd = cfg.num_decoder_layers, cfg.num_kv_heads, cfg.head_dim
    src_len = src_len or max_len
    return {
        "k": jnp.zeros((lyr, batch, max_len, hkv, hd), dtype),
        "v": jnp.zeros((lyr, batch, max_len, hkv, hd), dtype),
        "cross_k": jnp.zeros((lyr, batch, src_len, hkv, hd), dtype),
        "cross_v": jnp.zeros((lyr, batch, src_len, hkv, hd), dtype),
        "len": jnp.zeros((), jnp.int32),
    }
