"""Decode-time state pytrees: KV caches, ring buffers, SSM/RG-LRU states.

Conventions:
  * caches are stacked along a leading layer dim L and scanned together
    with the stacked params (keeps decode HLO O(1) in depth);
  * KV caches store bf16 (fp32 accumulation happens in attention);
  * sliding-window layers use a RING buffer of exactly `window` slots —
    a 512k-context decode with a 2k local window holds 2k keys, which is
    what makes long_500k runnable for the hybrid archs;
  * `len` is a scalar int32: number of tokens already written (= absolute
    position of the next token).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Sentinel absolute position for never-written ring slots: larger than any
# real position, so causal masking (pos_kv <= pos_q) hides them.
EMPTY_SLOT: int = 2**30


def kv_cache(num_layers: int, batch: int, max_len: int, num_kv_heads: int,
             head_dim: int, dtype=jnp.bfloat16) -> dict:
    """Standard (non-ring) KV cache for full-attention layers."""
    shape = (num_layers, batch, max_len, num_kv_heads, head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "len": jnp.zeros((), jnp.int32),
    }


def ring_kv_cache(num_layers: int, batch: int, window: int, num_kv_heads: int,
                  head_dim: int, dtype=jnp.bfloat16) -> dict:
    """Ring-buffer KV cache for sliding-window layers.

    Slot for absolute position p is p % window; `pos` tracks absolute
    positions per slot so attention can mask stale/empty slots exactly.
    """
    shape = (num_layers, batch, window, num_kv_heads, head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        # absolute position stored in each slot; EMPTY (a huge sentinel)
        # fails the causal test pos_kv <= pos_q, masking unwritten slots.
        "pos": jnp.full((num_layers, batch, window), EMPTY_SLOT, jnp.int32),
        "len": jnp.zeros((), jnp.int32),
    }


def ring_update(layer_cache: dict, k: jnp.ndarray, v: jnp.ndarray,
                start: jnp.ndarray) -> dict:
    """Write S new steps into a single layer's ring cache (no leading L).

    k, v: (B, S, Hkv, D); start: scalar absolute position of k[:, 0].
    S must be <= window.  Returns the updated layer cache dict (without
    'len', which the caller advances once for all layers).
    """
    b, s, hkv, d = k.shape
    window = layer_cache["k"].shape[1]
    slots = (start + jnp.arange(s)) % window                  # (S,)
    ck = layer_cache["k"].at[:, slots].set(k.astype(layer_cache["k"].dtype))
    cv = layer_cache["v"].at[:, slots].set(v.astype(layer_cache["v"].dtype))
    pos = layer_cache["pos"].at[:, slots].set(
        jnp.broadcast_to(start + jnp.arange(s), (b, s))
    )
    return {"k": ck, "v": cv, "pos": pos}


def ssm_state(num_layers: int, batch: int, num_heads: int, head_dim: int,
              state: int, conv_channels: int, conv_kernel: int,
              dtype=jnp.float32) -> dict:
    """Mamba-2 decode state: SSD state + causal-conv tail."""
    return {
        "h": jnp.zeros((num_layers, batch, num_heads, head_dim, state), dtype),
        "conv": jnp.zeros((num_layers, batch, conv_kernel - 1, conv_channels),
                          dtype),
        "len": jnp.zeros((), jnp.int32),
    }


def rglru_state(num_layers: int, batch: int, width: int,
                conv_kernel: int, dtype=jnp.float32) -> dict:
    """RG-LRU decode state: hidden vector + conv tail (per recurrent layer)."""
    return {
        "h": jnp.zeros((num_layers, batch, width), dtype),
        "conv": jnp.zeros((num_layers, batch, conv_kernel - 1, width), dtype),
    }


def cache_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))
