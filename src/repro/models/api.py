"""Family dispatch: one uniform API over the four model families.

  init_params(rng, cfg)                  -> params pytree
  forward(params, batch, cfg, cache)     -> (logits, aux, new_cache)
  init_cache(cfg, batch, max_len, ...)   -> decode-state pytree
  prefill / decode_step                  -> serving entry points
  loss_fn(params, batch, cfg)            -> (scalar, metrics)

batch keys: "tokens" (B,S) int32, "labels" (B,S) int32, and family extras:
"prefix_embeds" (B,P,d) for vlm, "src_embeds" (B,S_src,d) for audio.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import encdec, griffin, ssm, transformer

_FAMILY = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "ssm": ssm,
    "hybrid": griffin,
    "audio": encdec,
}


def module_for(cfg):
    return _FAMILY[cfg.family]


def init_params(rng, cfg, dtype=jnp.float32):
    return module_for(cfg).init_params(rng, cfg, dtype)


def forward(params, batch, cfg, cache=None):
    mod = module_for(cfg)
    kw = {}
    if cfg.family == "audio":
        if "src_embeds" in batch:
            kw["src_embeds"] = batch["src_embeds"]
        if "memory" in batch:
            kw["memory"] = batch["memory"]
    elif "prefix_embeds" in batch:
        kw["prefix_embeds"] = batch["prefix_embeds"]
    if "positions" in batch:
        kw["positions"] = batch["positions"]
    return mod.forward(params, batch["tokens"], cfg, cache=cache, **kw)


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16,
               src_len: int | None = None):
    mod = module_for(cfg)
    if cfg.family == "audio":
        return mod.init_cache(cfg, batch, max_len, dtype, src_len=src_len)
    return mod.init_cache(cfg, batch, max_len, dtype)


def prefill(params, batch, cfg, cache):
    """Run the prompt through the model, filling the cache.

    Returns (last-position logits (B, V), new_cache).
    """
    logits, _, new_cache = forward(params, batch, cfg, cache=cache)
    return logits[:, -1, :], new_cache


def decode_step(params, tokens, cfg, cache):
    """One decode step. tokens: (B, 1). Returns (logits (B, V), new_cache)."""
    logits, _, new_cache = forward(params, {"tokens": tokens}, cfg,
                                   cache=cache)
    return logits[:, -1, :], new_cache


def loss_fn(params, batch, cfg):
    """Causal-LM cross entropy (fp32), prefix positions masked for VLM.

    Returns (total_loss, metrics dict).
    """
    logits, aux, _ = forward(params, batch, cfg)
    labels = batch["labels"]
    s_total = logits.shape[1]
    if labels.shape[1] < s_total:               # multimodal prefix present
        pad = s_total - labels.shape[1]
        labels = jnp.pad(labels, ((0, 0), (pad, 0)))
        mask = jnp.pad(jnp.ones_like(batch["labels"], jnp.float32),
                       ((0, 0), (pad, 0)))
    else:
        mask = batch.get("loss_mask",
                         jnp.ones_like(labels, jnp.float32))
    logits = logits.astype(jnp.float32)
    # CE without take_along_axis: a gather over the vocab-sharded axis
    # would force GSPMD to all-gather the (B, S, V) fp32 logits (33 GiB
    # for llama3 train_4k).  The masked reduction keeps everything local
    # to the vocab shard; only the tiny (B, S) partial sum is psum'd.
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    tgt_logit = jnp.sum(
        jnp.where(vocab_iota == labels[..., None], logits, 0.0), axis=-1)
    ll = tgt_logit - lse
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    ce = -jnp.sum(ll * mask) / denom
    total = ce + cfg.router_aux_coef * aux
    return total, {"loss": ce, "aux_loss": aux, "tokens": denom}
