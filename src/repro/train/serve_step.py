"""Serving steps: batched prefill + decode with static shapes.

`make_serve_fns(cfg)` returns (prefill_fn, decode_fn), both pure:

  prefill_fn(params, batch, cache)          -> (next_tokens, cache)
  decode_fn(params, tokens, cache)          -> (next_tokens, cache)

Sampling is greedy (argmax) — deterministic and collective-free, which is
what the dry-run lowers; examples/serve_lm.py layers temperature sampling
on top.  `decode_loop` runs N steps under lax.scan for throughput.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import api


def make_serve_fns(cfg):
    def prefill_fn(params, batch, cache):
        logits, cache = api.prefill(params, batch, cfg, cache)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    def decode_fn(params, tokens, cache):
        logits, cache = api.decode_step(params, tokens, cfg, cache)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    return prefill_fn, decode_fn


def decode_loop(params, first_tokens, cache, cfg, num_steps: int):
    """Greedy-decode num_steps tokens under lax.scan.

    Returns (tokens (B, num_steps), final_cache).
    """
    _, decode_fn = make_serve_fns(cfg)

    def step(carry, _):
        toks, cache = carry
        nxt, cache = decode_fn(params, toks[:, None], cache)
        return (nxt, cache), nxt

    (_, cache), toks = jax.lax.scan(
        step, (first_tokens, cache), None, length=num_steps)
    return jnp.swapaxes(toks, 0, 1), cache
