"""Fault-tolerant checkpointing: atomic, sharded, mesh-agnostic.

Layout:   <dir>/step_000123/
            manifest.json      tree structure, shapes, dtypes, step
            <flat-key>.npy     one file per leaf (path '/'-joined)
          <dir>/latest         text file naming the newest complete step

Guarantees:
  * atomic: written to step_X.tmp-<pid>, fsync'd, then os.rename —
    a crash mid-save never corrupts `latest`;
  * mesh-agnostic: leaves are stored as full (unsharded) host arrays and
    restored with jax.device_put against the *current* mesh's shardings —
    elastic restarts onto a different mesh shape just work (tested);
  * async: `save_async` hands the host copy to a writer thread so the
    training loop only blocks on jnp->np transfer, not on disk I/O;
  * bounded: keep_last prunes old steps after each successful save.

At true 1000-node scale each host would write only its addressable
shards (jax.experimental.array_serialization); the manifest/atomic-rename
/latest protocol here is exactly that layout minus per-shard files, and
the restore path (device_put against target shardings) is identical.
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np

_SEP = "/"


def _flatten(tree, prefix=()):
    if isinstance(tree, dict):
        out = {}
        for k in sorted(tree):
            out.update(_flatten(tree[k], prefix + (str(k),)))
        return out
    return {_SEP.join(prefix): tree}


def _unflatten(flat: dict):
    tree: dict = {}
    for key, val in flat.items():
        parts = key.split(_SEP)
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return tree


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------- save --
    def save(self, state, step: int):
        self.wait()
        host = {k: np.asarray(v) for k, v in _flatten(state).items()}
        self._write(host, step)

    def save_async(self, state, step: int):
        """Device->host copy happens now; disk I/O on a writer thread."""
        self.wait()
        host = {k: np.asarray(v) for k, v in _flatten(state).items()}
        self._thread = threading.Thread(
            target=self._write, args=(host, step), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, host: dict, step: int):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = f"{final}.tmp-{os.getpid()}"
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "leaves": {}}
        for key, arr in host.items():
            fname = key.replace(_SEP, "__") + ".npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"][key] = {
                "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        latest_tmp = os.path.join(self.dir, f".latest-{os.getpid()}")
        with open(latest_tmp, "w") as f:
            f.write(os.path.basename(final))
            f.flush()
            os.fsync(f.fileno())
        os.rename(latest_tmp, os.path.join(self.dir, "latest"))
        self._prune()

    def _prune(self):
        steps = sorted(d for d in os.listdir(self.dir)
                       if d.startswith("step_") and ".tmp" not in d)
        for d in steps[: -self.keep_last]:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # ---------------------------------------------------------- restore --
    def latest_step(self) -> int | None:
        latest = os.path.join(self.dir, "latest")
        if not os.path.exists(latest):
            return None
        with open(latest) as f:
            name = f.read().strip()
        if not os.path.exists(os.path.join(self.dir, name, "manifest.json")):
            return None
        return int(name.split("_")[1])

    def restore(self, step: int | None = None, shardings=None):
        """Load a checkpoint.  shardings: optional pytree of NamedShardings
        (same structure as the state) — leaves are device_put against them,
        which reshards onto whatever mesh is current."""
        step = self.latest_step() if step is None else step
        if step is None:
            return None
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        flat = {}
        for key, meta in manifest["leaves"].items():
            arr = np.load(os.path.join(path, meta["file"]))
            flat[key] = arr
        tree = _unflatten(flat)
        if shardings is not None:
            flat_sh = _flatten(shardings)
            tree = _unflatten({
                k: jax.device_put(v, flat_sh[k]) if k in flat_sh
                else jax.numpy.asarray(v)
                for k, v in flat.items()
            })
        else:
            tree = jax.tree.map(jax.numpy.asarray, tree)
        return tree
