"""Gradient machinery: global-norm clipping, microbatch accumulation,
int8 error-feedback compression.

Compression (beyond-paper, §5 of DESIGN.md): gradients quantized to int8
with a persistent error-feedback buffer.  Two uses:
  * `compress_grads` inside the accumulation loop — models compressed
    gradient exchange (the quantization error is re-injected next step,
    so long-run training is unbiased);
  * `compressed_psum` — an explicit shard_map collective that all-reduces
    int8-quantized blocks over a mesh axis (4x fewer DCN bytes on the pod
    axis than bf16); used by the multi-pod experiments.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# Clipping
# ---------------------------------------------------------------------------
def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    """Returns (clipped_grads, pre_clip_norm)."""
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


# ---------------------------------------------------------------------------
# int8 error-feedback quantization
# ---------------------------------------------------------------------------
def _quantize(x: jnp.ndarray):
    """Symmetric per-tensor int8. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def init_error_buffer(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(grads, err):
    """Quantize grads to int8 (error feedback). Returns (deq_grads, new_err).

    deq_grads are the dequantized fp32 values actually applied; the
    residual (g + e) - deq is carried to the next step.
    """
    def leaf(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = _quantize(g32)
        deq = q.astype(jnp.float32) * scale
        return deq, g32 - deq

    flat = jax.tree.map(leaf, grads, err)

    def istup(x):
        return isinstance(x, tuple)
    deq = jax.tree.map(lambda t: t[0], flat, is_leaf=istup)
    new_err = jax.tree.map(lambda t: t[1], flat, is_leaf=istup)
    return deq, new_err


def compressed_psum(partials: jnp.ndarray, mesh, axis: str) -> jnp.ndarray:
    """All-reduce of int8-quantized per-rank partials over a mesh axis.

    partials: (|axis|, ...) — row i is rank i's contribution (e.g. its
    local gradient).  Returns the dequantized sum, replicated.

    Wire bytes: 1 per element + one fp32 scale per shard, vs 4 (fp32) or
    2 (bf16) — the gradient-compression primitive for the DCN pod axis.
    Quantization is per-sender; accuracy is per-tensor int8 (validated
    against the exact sum in tests).
    """
    d = mesh.shape[axis]
    assert partials.shape[0] == d, (partials.shape, d)

    def inner(xs):
        q, scale = _quantize(xs[0].astype(jnp.float32))
        qg = lax.all_gather(q, axis)                 # int8 on the wire
        sg = lax.all_gather(scale, axis)
        return jnp.tensordot(sg, qg.astype(jnp.float32), axes=((0,), (0,)))

    from repro.compat import shard_map

    return shard_map(
        inner, mesh=mesh,
        in_specs=P(axis, *([None] * (partials.ndim - 1))),
        out_specs=P(*([None] * (partials.ndim - 1))),
        check_vma=False,
    )(partials)


# ---------------------------------------------------------------------------
# Microbatch accumulation
# ---------------------------------------------------------------------------
def accumulate_grads(loss_fn, params, batch, num_microbatches: int):
    """Split batch dim into microbatches; lax.scan-accumulate fp32 grads.

    loss_fn: params, batch -> (loss, metrics).  Returns (loss, metrics,
    grads) averaged over microbatches.
    """
    if num_microbatches <= 1:
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def reshape(x):
        b = x.shape[0]
        assert b % num_microbatches == 0, (b, num_microbatches)
        return x.reshape(num_microbatches, b // num_microbatches, *x.shape[1:])

    micro = jax.tree.map(reshape, batch)
    gfn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(acc, mb):
        (loss, metrics), grads = gfn(params, mb)
        acc_g, acc_l = acc
        acc_g = jax.tree.map(
            lambda a, g: a + g.astype(jnp.float32), acc_g, grads)
        return (acc_g, acc_l + loss), metrics

    zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (sum_g, sum_l), metrics_all = jax.lax.scan(
        step, (zero_g, jnp.zeros((), jnp.float32)), micro)
    inv = 1.0 / num_microbatches
    grads = jax.tree.map(lambda g: g * inv, sum_g)
    metrics = jax.tree.map(lambda m: jnp.mean(m), metrics_all)
    return sum_l * inv, metrics, grads
