"""Train step factory: loss -> grads -> clip -> (compress) -> optimizer.

`make_train_step(cfg, ...)` returns (init_state, step_fn) where step_fn is
pure and jit-friendly:  state, batch -> (state, metrics).  State is a flat
dict pytree (params / opt / step / err) so checkpointing and
param_shardings traverse it uniformly.

Under a mesh, build shardings with `state_shardings(state_shape, mesh)`
and jit with those as in_shardings/out_shardings (launch/train.py and
launch/dryrun.py do this); on a single device just jit it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import api
from repro.sharding.rules import ShardingRules, param_shardings
from repro.train import grad as G
from repro.train.optimizer import OPTIMIZERS, Optimizer, warmup_cosine


def make_optimizer(cfg, *, peak_lr: float = 3e-4, warmup: int = 100,
                   total_steps: int = 10_000) -> Optimizer:
    sched = warmup_cosine(peak_lr, warmup, total_steps)
    return OPTIMIZERS[cfg.optimizer](sched)


def init_state(rng, cfg, optimizer: Optimizer, *, compress: bool = False):
    params = api.init_params(rng, cfg)
    state = {
        "params": params,
        "opt": optimizer.init(params),
        "step": jnp.zeros((), jnp.int32),
    }
    if compress:
        state["err"] = G.init_error_buffer(params)
    return state


def make_train_step(cfg, optimizer: Optimizer, *, clip_norm: float = 1.0,
                    num_microbatches: int = 1, compress: bool = False):
    """Returns step_fn(state, batch) -> (new_state, metrics)."""

    def loss_fn(params, batch):
        return api.loss_fn(params, batch, cfg)

    def step_fn(state, batch):
        loss, metrics, grads = G.accumulate_grads(
            loss_fn, state["params"], batch, num_microbatches)
        grads, gnorm = G.clip_by_global_norm(grads, clip_norm)
        if compress:
            grads, new_err = G.compress_grads(grads, state["err"])
        new_params, new_opt = optimizer.update(
            grads, state["opt"], state["params"], state["step"])
        new_state = {
            "params": new_params,
            "opt": new_opt,
            "step": state["step"] + 1,
        }
        if compress:
            new_state["err"] = new_err
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["loss_total"] = loss
        return new_state, metrics

    return step_fn


# ---------------------------------------------------------------------------
# Sharding helpers
# ---------------------------------------------------------------------------
def state_shardings(state_shape, mesh, rules: ShardingRules = ShardingRules()):
    """NamedShardings for a full train state (params/opt mirror; scalars
    replicated).  state_shape: pytree of ShapeDtypeStructs (jax.eval_shape).
    """
    p_shard = param_shardings(state_shape["params"], mesh, rules)
    out = {"params": p_shard, "step": NamedSharding(mesh, P())}

    if "opt" in state_shape:
        # AdamW: mu/nu mirror params exactly. Adafactor: factored moments
        # drop the last/second-to-last dim — shard what still matches.
        def opt_shard(opt_tree, params_shard):
            def walk(o, ps):
                if isinstance(o, dict) and all(
                        k in ("mu", "nu", "v", "vr", "vc") for k in o):
                    res = {}
                    for k, v in o.items():
                        res[k] = walk(v, ps)
                    return res
                if isinstance(o, dict) and isinstance(ps, dict):
                    return {k: walk(v, ps.get(k)) for k, v in o.items()}
                if isinstance(ps, NamedSharding) and hasattr(o, "shape"):
                    if len(ps.spec) == len(o.shape):
                        return ps
                    # factored moment (O(n+m) state): replicate — cheap
                    return NamedSharding(mesh, P())
                if isinstance(o, dict):
                    return {k: walk(v, None) for k, v in o.items()}
                return NamedSharding(mesh, P())
            return walk(opt_tree, params_shard)
        out["opt"] = opt_shard(state_shape["opt"], p_shard)
    if "err" in state_shape:
        out["err"] = p_shard
    return out


def batch_shardings(batch_shape, mesh, rules: ShardingRules = ShardingRules()):
    """Batch-dim sharding over the DP axes for every batch leaf."""
    axes = rules.present(mesh, rules.batch_axes)
    ax = axes if len(axes) > 1 else (axes[0] if axes else None)

    def leaf(x):
        if x.ndim == 0:
            return NamedSharding(mesh, P())
        n = 1
        for a in (axes or ()):
            n *= mesh.shape[a]
        if n > 1 and x.shape[0] % n == 0:
            return NamedSharding(mesh, P(ax, *([None] * (x.ndim - 1))))
        return NamedSharding(mesh, P())

    return jax.tree.map(leaf, batch_shape)
