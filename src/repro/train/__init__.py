"""Training substrate: optimizers, grad machinery, steps, checkpoint, fault."""

from repro.train.optimizer import OPTIMIZERS, adamw, adafactor, warmup_cosine
from repro.train.train_step import (
    init_state, make_optimizer, make_train_step, state_shardings,
    batch_shardings,
)
from repro.train.checkpoint import CheckpointManager
from repro.train.fault import FaultInjector, Watchdog, run_training

__all__ = [
    "OPTIMIZERS", "adamw", "adafactor", "warmup_cosine",
    "init_state", "make_optimizer", "make_train_step", "state_shardings",
    "batch_shardings", "CheckpointManager", "FaultInjector", "Watchdog",
    "run_training",
]
