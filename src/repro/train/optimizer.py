"""Hand-rolled optimizers (no optax): AdamW and Adafactor.

Optimizer state pytrees mirror the param tree leaf-for-leaf, so
`sharding/rules.param_shardings` applies verbatim to the state (ZeRO:
moments inherit the FSDP/TP sharding of their parameter).

All moment math is fp32 regardless of param dtype.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# LR schedules
# ---------------------------------------------------------------------------
def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  floor: float = 0.1) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = peak_lr * (step + 1) / max(warmup_steps, 1)
        t = jnp.clip((step - warmup_steps) /
                     max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup_steps, warm, cos)
    return lr


def constant_lr(lr: float) -> Callable:
    return lambda step: jnp.asarray(lr, jnp.float32)


# ---------------------------------------------------------------------------
# Optimizer interface
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable     # params -> opt_state
    update: Callable   # (grads, opt_state, params, step) -> (new_params, new_state)


def adamw(lr: Callable | float, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1) -> Optimizer:
    lr_fn = lr if callable(lr) else constant_lr(lr)

    def init(params):
        def zeros(p):
            return jnp.zeros(p.shape, jnp.float32)
        return {"mu": jax.tree.map(zeros, params),
                "nu": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        lr_t = lr_fn(step)
        t = (step + 1).astype(jnp.float32)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd(g, mu, nu, p):
            g = g.astype(jnp.float32)
            mu = b1 * mu + (1 - b1) * g
            nu = b2 * nu + (1 - b2) * g * g
            mhat = mu / bc1
            nhat = nu / bc2
            step_ = mhat / (jnp.sqrt(nhat) + eps)
            if weight_decay and p.ndim >= 2:   # no decay on norms/biases
                step_ = step_ + weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr_t * step_
            return new_p.astype(p.dtype), mu, nu

        flat = jax.tree.map(upd, grads, state["mu"], state["nu"], params)
        new_params = jax.tree.map(lambda t3: t3[0], flat,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_mu = jax.tree.map(lambda t3: t3[1], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
        new_nu = jax.tree.map(lambda t3: t3[2], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"mu": new_mu, "nu": new_nu}

    return Optimizer(init=init, update=update)


def adafactor(lr: Callable | float, eps: float = 1e-30,
              decay: float = 0.8, weight_decay: float = 0.0,
              clip_threshold: float = 1.0) -> Optimizer:
    """Factored second moments for >=2-D params: O(n+m) state instead of
    O(nm) — the memory-saving option for the 1T-param cells."""
    lr_fn = lr if callable(lr) else constant_lr(lr)

    def _factored(p) -> bool:
        return p.ndim >= 2 and p.shape[-1] > 1 and p.shape[-2] > 1

    def init(params):
        def leaf(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return jax.tree.map(leaf, params)

    def update(grads, state, params, step):
        lr_t = lr_fn(step)
        t = (step + 1).astype(jnp.float32)
        beta = 1.0 - t ** (-decay)

        def upd(g, s, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(p):
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                rfac = vr / jnp.maximum(
                    jnp.mean(vr, axis=-1, keepdims=True), eps)
                u = g / (jnp.sqrt(rfac)[..., None] * jnp.sqrt(vc)[..., None, :]
                         + eps)
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g / (jnp.sqrt(v) + eps)
                new_s = {"v": v}
            rms = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay and p.ndim >= 2:
                u = u + weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr_t * u
            return new_p.astype(p.dtype), new_s

        # state has one extra dict level below each grad leaf; tree.map
        # flattens up to grads' leaves and passes the state dict whole.
        flat = jax.tree.map(upd, grads, state, params)

        def istup(x):
            return isinstance(x, tuple)
        new_params = jax.tree.map(lambda t2: t2[0], flat, is_leaf=istup)
        new_state = jax.tree.map(lambda t2: t2[1], flat, is_leaf=istup)
        return new_params, new_state

    return Optimizer(init=init, update=update)


OPTIMIZERS = {"adamw": adamw, "adafactor": adafactor}
