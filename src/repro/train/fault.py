"""Fault-tolerant training driver: checkpoint/restart, watchdog, injection.

The loop is deliberately boring — that is the point.  Everything stateful
lives in (state, step); the data stream is seekable (data/synthetic.py),
so crash->restore->replay is bit-exact.  `FaultInjector` simulates node
failures at chosen steps; tests assert the driver recovers and that the
recovered run matches an uninterrupted one exactly.

Straggler policy: the watchdog times every step against an SLO budget
(EMA-relative).  On one CPU we log-and-continue; the hook is where a
fleet controller would trigger slice replacement / hot-spare swap-in.
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Optional

from repro.train.checkpoint import CheckpointManager

log = logging.getLogger("repro.fault")


class InjectedFault(RuntimeError):
    """Simulated node failure."""


@dataclasses.dataclass
class FaultInjector:
    """Raises InjectedFault the first time each listed step is reached."""
    fail_at_steps: tuple = ()
    _fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int):
        if step in self.fail_at_steps and step not in self._fired:
            self._fired.add(step)
            raise InjectedFault(f"injected failure at step {step}")


@dataclasses.dataclass
class Watchdog:
    """EMA step-time SLO: flags steps slower than ratio x EMA."""
    ratio: float = 3.0
    ema: Optional[float] = None
    slow_steps: int = 0

    def observe(self, dt: float, step: int):
        if self.ema is None:
            self.ema = dt
            return False
        slow = dt > self.ratio * self.ema
        if slow:
            self.slow_steps += 1
            log.warning("straggler: step %d took %.3fs (EMA %.3fs)",
                        step, dt, self.ema)
        self.ema = 0.9 * self.ema + 0.1 * dt
        return slow


def run_training(
    *,
    init_state_fn: Callable[[], dict],
    train_step: Callable,                 # (state, batch) -> (state, metrics)
    stream,                               # .batch_at(step)
    ckpt: CheckpointManager,
    num_steps: int,
    ckpt_every: int = 50,
    state_shardings=None,
    injector: Optional[FaultInjector] = None,
    watchdog: Optional[Watchdog] = None,
    max_restarts: int = 10,
    log_every: int = 10,
    metrics_cb: Optional[Callable] = None,
):
    """Run to num_steps with restart-on-failure. Returns (state, history)."""
    restarts = 0
    history = []
    state = None
    while True:
        try:
            if state is None:
                restored = ckpt.restore(shardings=state_shardings)
                if restored is not None:
                    state = restored
                    log.info("restored checkpoint at step %d",
                             int(state["step"]))
                else:
                    state = init_state_fn()
            step = int(state["step"])
            while step < num_steps:
                if injector is not None:
                    injector.check(step)
                batch = stream.batch_at(step)
                t0 = time.perf_counter()
                state, metrics = train_step(state, batch)
                if watchdog is not None:
                    # block so the watchdog times real work, not dispatch
                    metrics = {k: v.block_until_ready() if hasattr(
                        v, "block_until_ready") else v
                        for k, v in metrics.items()}
                dt = time.perf_counter() - t0
                if watchdog is not None:
                    watchdog.observe(dt, step)
                step = int(state["step"])
                if step % log_every == 0 or step == num_steps:
                    loss = float(metrics.get("loss", float("nan")))
                    history.append({"step": step, "loss": loss, "dt": dt})
                    if metrics_cb:
                        metrics_cb(step, metrics)
                if step % ckpt_every == 0 or step == num_steps:
                    ckpt.save_async(state, step)
            ckpt.wait()
            return state, history
        except InjectedFault as e:
            restarts += 1
            log.warning("%s -> restart %d/%d", e, restarts, max_restarts)
            if restarts > max_restarts:
                raise
            ckpt.wait()
            state = None        # force restore-from-latest on re-entry
