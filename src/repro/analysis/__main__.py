"""``python -m repro.analysis`` — run the project lint rules.

Exit codes: 0 clean (or informational modes), 1 gating findings,
2 usage error.

Typical invocations (from the repo root):

    PYTHONPATH=src python -m repro.analysis --check
    PYTHONPATH=src python -m repro.analysis --check --json report.json
    PYTHONPATH=src python -m repro.analysis --write-baseline
    PYTHONPATH=src python -m repro.analysis --list-rules
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.analysis.lint import (
    BASELINE_DEFAULT,
    RULES,
    gate,
    lint_paths,
    load_baseline,
    render_json,
    render_text,
    write_baseline,
)

DEFAULT_PATHS = ("src/repro", "benchmarks", "examples")


def find_root(start: Path) -> Path:
    """Nearest ancestor holding the repo markers (so the CLI works from
    subdirectories too); falls back to ``start``."""
    for p in (start, *start.parents):
        if (p / "src" / "repro").is_dir():
            return p
    return start


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Lint the tree against the project invariant rules.",
    )
    parser.add_argument(
        "paths", nargs="*",
        help=f"files/dirs to lint (default: {' '.join(DEFAULT_PATHS)})",
    )
    parser.add_argument(
        "--root", default=None,
        help="repo root for relative paths and the baseline "
             "(default: auto-detected from cwd)",
    )
    parser.add_argument(
        "--check", action="store_true",
        help="exit 1 if any non-baselined, non-suppressed finding remains",
    )
    parser.add_argument(
        "--json", metavar="FILE", default=None,
        help="also write the full JSON report to FILE ('-' for stdout)",
    )
    parser.add_argument(
        "--baseline", metavar="FILE", default=None,
        help=f"baseline file (default: <root>/{BASELINE_DEFAULT})",
    )
    parser.add_argument(
        "--write-baseline", action="store_true",
        help="record current unsuppressed findings as the new baseline",
    )
    parser.add_argument(
        "--list-rules", action="store_true",
        help="print the registered rules and exit",
    )
    args = parser.parse_args(argv)

    if args.list_rules:
        for name, rule in sorted(RULES.items()):
            print(f"{name:18s} allow-{rule.pragma:18s} {rule.description}")
        return 0

    root = find_root(Path(args.root or ".").resolve())
    paths = args.paths or [p for p in DEFAULT_PATHS if (root / p).exists()]
    if not paths:
        print(f"no default paths exist under {root}", file=sys.stderr)
        return 2
    baseline_path = Path(args.baseline) if args.baseline \
        else root / BASELINE_DEFAULT

    findings = lint_paths(paths, root=root)

    if args.write_baseline:
        n = write_baseline(findings, baseline_path)
        print(f"wrote {n} fingerprint(s) to {baseline_path}")
        return 0

    baseline = load_baseline(baseline_path)
    gating = gate(findings, baseline)

    print(render_text(findings, gating, baseline))
    if args.json:
        report = render_json(findings, gating, baseline)
        if args.json == "-":
            print(report)
        else:
            Path(args.json).write_text(report + "\n")

    if args.check and gating:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
