"""``python -m repro.analysis`` — run the project lint rules.

Exit codes: 0 clean (or informational modes), 1 gating findings,
2 usage error.

Typical invocations (from the repo root):

    PYTHONPATH=src python -m repro.analysis --check
    PYTHONPATH=src python -m repro.analysis --check --json report.json
    PYTHONPATH=src python -m repro.analysis --write-baseline
    PYTHONPATH=src python -m repro.analysis --list-rules
    PYTHONPATH=src python -m repro.analysis --check-kernels

``--check`` also fails on *stale* baseline entries (fingerprints whose
finding no longer exists): the committed baseline is a ratchet that may
only shrink, and ``--write-baseline`` prunes it.

``--check-kernels`` runs :mod:`repro.analysis.kernelcheck` — the
symbolic-grid verification of the Pallas kernels' declared contracts
(carry happens-before, output coverage, in-bounds index maps, VMEM
fit).  It is a separate mode because it needs jax (the kernel modules
define the specs); the lint rules stay importable without it.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.analysis.lint import (
    BASELINE_DEFAULT,
    RULES,
    gate,
    lint_paths,
    load_baseline,
    render_json,
    render_text,
    stale_fingerprints,
    write_baseline,
)

DEFAULT_PATHS = ("src/repro", "benchmarks", "examples")


def find_root(start: Path) -> Path:
    """Nearest ancestor holding the repo markers (so the CLI works from
    subdirectories too); falls back to ``start``."""
    for p in (start, *start.parents):
        if (p / "src" / "repro").is_dir():
            return p
    return start


def _run_check_kernels(args) -> int:
    """The ``--check-kernels`` mode: verify every registered KernelSpec,
    print the verdicts, optionally write the JSON report; exit 1 on any
    failed check."""
    import json

    try:
        from repro.analysis import kernelcheck
    except ImportError as e:  # jax not installed: the lint-only env
        print(f"--check-kernels needs jax (kernel modules define the "
              f"specs): {e}", file=sys.stderr)
        return 2
    verdicts = kernelcheck.check_kernels()
    for v in verdicts:
        print(v.render())
    failed = [v for v in verdicts if not v.ok]
    print(f"{len(verdicts)} kernel verdict(s), {len(failed)} failed")
    if args.json:
        report = json.dumps({
            "version": 1,
            "verdicts": [v.to_json() for v in verdicts],
            "counts": {"total": len(verdicts), "failed": len(failed)},
        }, indent=2)
        if args.json == "-":
            print(report)
        else:
            Path(args.json).write_text(report + "\n")
    return 1 if failed else 0


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Lint the tree against the project invariant rules.",
    )
    parser.add_argument(
        "paths", nargs="*",
        help=f"files/dirs to lint (default: {' '.join(DEFAULT_PATHS)})",
    )
    parser.add_argument(
        "--root", default=None,
        help="repo root for relative paths and the baseline "
             "(default: auto-detected from cwd)",
    )
    parser.add_argument(
        "--check", action="store_true",
        help="exit 1 if any non-baselined, non-suppressed finding "
             "remains, or if the baseline holds stale fingerprints",
    )
    parser.add_argument(
        "--json", metavar="FILE", default=None,
        help="also write the full JSON report to FILE ('-' for stdout)",
    )
    parser.add_argument(
        "--baseline", metavar="FILE", default=None,
        help=f"baseline file (default: <root>/{BASELINE_DEFAULT})",
    )
    parser.add_argument(
        "--write-baseline", action="store_true",
        help="seed the baseline (first write), or prune stale entries "
             "from it (the baseline only ever shrinks)",
    )
    parser.add_argument(
        "--list-rules", action="store_true",
        help="print the registered rules and exit",
    )
    parser.add_argument(
        "--check-kernels", action="store_true",
        help="verify the Pallas kernel contracts (KernelSpec grid/carry/"
             "coverage/VMEM proofs; needs jax), exit 1 on any failure",
    )
    args = parser.parse_args(argv)

    modes = [args.check, args.write_baseline, args.list_rules,
             args.check_kernels]
    if sum(bool(m) for m in modes) > 1:
        print("--check, --write-baseline, --list-rules and "
              "--check-kernels are mutually exclusive modes",
              file=sys.stderr)
        return 2

    if args.list_rules:
        for name, rule in sorted(RULES.items()):
            print(f"{name:18s} allow-{rule.pragma:18s} {rule.description}")
        return 0

    if args.check_kernels:
        if args.paths:
            print("--check-kernels verifies the registered KernelSpecs; "
                  "it takes no paths", file=sys.stderr)
            return 2
        return _run_check_kernels(args)

    root = find_root(Path(args.root or ".").resolve())
    paths = args.paths or [p for p in DEFAULT_PATHS if (root / p).exists()]
    if not paths:
        print(f"no default paths exist under {root}", file=sys.stderr)
        return 2
    baseline_path = Path(args.baseline) if args.baseline \
        else root / BASELINE_DEFAULT

    findings = lint_paths(paths, root=root)

    if args.write_baseline:
        n = write_baseline(findings, baseline_path)
        print(f"wrote {n} fingerprint(s) to {baseline_path}")
        return 0

    baseline = load_baseline(baseline_path)
    gating = gate(findings, baseline)
    stale = stale_fingerprints(findings, baseline)

    print(render_text(findings, gating, baseline, stale))
    if args.json:
        report = render_json(findings, gating, baseline, stale)
        if args.json == "-":
            print(report)
        else:
            Path(args.json).write_text(report + "\n")

    if args.check and (gating or stale):
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
