"""Static analysis for the project's invariants.

Two layers:

  * :mod:`repro.analysis.lint` + :mod:`repro.analysis.rules` — the
    AST lint engine and the six project rules (sharded-concat,
    host-sync, carry-contract, no-shim-use, overflow-policy,
    lock-discipline).  Stdlib-only: CI runs ``python -m repro.analysis
    --check`` without installing jax.
  * :mod:`repro.analysis.plancheck` — the static plan validator
    (``jax.eval_shape`` abstract interpretation over an
    ``ExecutionPlan``); imported lazily because it needs jax.
    ``HistogramEngine.validate(plan)`` is the wired-in entry point.
"""

from repro.analysis import rules as rules          # registers the rule set
from repro.analysis.lint import (
    BASELINE_DEFAULT,
    Finding,
    FileContext,
    Rule,
    RULES,
    gate,
    lint_paths,
    lint_source,
    load_baseline,
    render_json,
    render_text,
    write_baseline,
)

__all__ = [
    "BASELINE_DEFAULT",
    "Finding",
    "FileContext",
    "Rule",
    "RULES",
    "gate",
    "lint_paths",
    "lint_source",
    "load_baseline",
    "render_json",
    "render_text",
    "write_baseline",
    "check_plan",
    "PlanVerdict",
    "PlanCheck",
]


def __getattr__(name):
    # plancheck needs jax; load it only when asked for.
    if name in ("check_plan", "PlanVerdict", "PlanCheck", "plancheck"):
        from repro.analysis import plancheck

        if name == "plancheck":
            return plancheck
        return getattr(plancheck, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
