"""Static analysis for the project's invariants.

Two layers:

  * :mod:`repro.analysis.lint` + :mod:`repro.analysis.rules` — the
    AST lint engine and the six project rules (sharded-concat,
    host-sync, carry-contract, no-shim-use, overflow-policy,
    lock-discipline).  Stdlib-only: CI runs ``python -m repro.analysis
    --check`` without installing jax.
  * :mod:`repro.analysis.plancheck` — the static plan validator
    (``jax.eval_shape`` abstract interpretation over an
    ``ExecutionPlan``); imported lazily because it needs jax.
    ``HistogramEngine.validate(plan)`` is the wired-in entry point
    (``deep=True`` folds in the kernel checks below).
  * :mod:`repro.analysis.kernelcheck` — symbolic-grid verification of
    the Pallas kernels' declared :class:`~repro.kernels.specs.KernelSpec`
    contracts (carry happens-before, output coverage, in-bounds index
    maps, VMEM fit); also lazy — the kernel modules defining the specs
    import jax.  ``python -m repro.analysis --check-kernels`` is the
    CLI entry point.
"""

from repro.analysis import rules as rules          # registers the rule set
from repro.analysis.lint import (
    BASELINE_DEFAULT,
    Finding,
    FileContext,
    Rule,
    RULES,
    gate,
    lint_paths,
    lint_source,
    load_baseline,
    render_json,
    render_text,
    stale_fingerprints,
    write_baseline,
)

__all__ = [
    "BASELINE_DEFAULT",
    "Finding",
    "FileContext",
    "Rule",
    "RULES",
    "gate",
    "lint_paths",
    "lint_source",
    "load_baseline",
    "render_json",
    "render_text",
    "stale_fingerprints",
    "write_baseline",
    "check_plan",
    "PlanVerdict",
    "PlanCheck",
    "check_kernels",
    "check_method",
    "KernelVerdict",
    "KernelCheck",
]

#: names resolved lazily (they need jax): attr -> providing submodule.
_LAZY = {
    "check_plan": "plancheck",
    "PlanVerdict": "plancheck",
    "PlanCheck": "plancheck",
    "plancheck": "plancheck",
    "check_kernels": "kernelcheck",
    "check_method": "kernelcheck",
    "KernelVerdict": "kernelcheck",
    "KernelCheck": "kernelcheck",
    "kernelcheck": "kernelcheck",
}


def __getattr__(name):
    modname = _LAZY.get(name)
    if modname is not None:
        import importlib

        mod = importlib.import_module(f"repro.analysis.{modname}")
        return mod if name == modname else getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
