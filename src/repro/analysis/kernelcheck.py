"""Static verification of the Pallas kernels' grid/carry/VMEM contracts.

The WF-TiS wavefront is only *correct* because each tile's carries are
produced by its up/left predecessors under the sequential grid walk
(arXiv:1711.01919 §4.2-4.5), and only *fast* because the tile +
bin-block working set fits per-core VMEM (the memory-budget framing of
Ehsan et al., arXiv:1510.05142).  Those invariants used to live in
comments in ``kernels/wf_tis.py``/``cw_tis.py`` and a hand-maintained
VMEM formula in ``plancheck.py``; this module proves them from the
declarative :class:`~repro.kernels.specs.KernelSpec` each kernel module
exports next to its ``pallas_call``.

Four checks, each evaluated by symbolically enumerating the grid in the
spec's declared sequential order (last dimension innermost — Pallas TPU
execution order):

  * **carry-order** — every VMEM-scratch value a grid step consumes was
    last written by exactly the producer step the spec declares.  This
    is strictly stronger than "written earlier": a shared scratch cell
    overwritten every step (cw_tis's single strip carry) is "written
    earlier" under ANY grid order, but only the declared order makes
    the *last* writer the declared producer.  Catches the
    grid-dimension-reordering bug class — cw_tis pass 2 deliberately
    swaps ``ntw``/``nth`` and the verifier proves that order rather
    than assuming row-major.
  * **out-coverage** — the out-spec index maps write every output block
    exactly once over the whole grid.  A gap is garbage rows in the
    result; an overlap is a write race on backends that run grid steps
    concurrently (the GPU wavefront this kernel family comes from).
  * **in-bounds** — every in/out block index stays inside the padded
    operand at every grid point (block-index units: ``0 <= i`` and
    ``(i + 1) * block <= shape`` per dimension).
  * **vmem-fit** — the double-buffered operand blocks + persistent
    scratch fit the 16 MiB per-core budget, derived from the spec
    (``KernelSpec.vmem_bytes``).  ``plancheck``'s vmem-fit check
    delegates here, so the engine-level and kernel-level estimates
    cannot diverge (a conformance test asserts equality anyway).

Enumeration runs on ``KernelGeometry.canonical()`` — every grid
dimension clamped to 3 blocks and the frame count pinned to 2 (the
frame-boundary carry resets need a second frame to exercise) — so the
walk is O(100) steps at any frame size; vmem-fit uses the real
geometry.  Entry points: ``check_method`` (one method, one geometry),
``check_kernels`` (the whole registry — the ``--check-kernels`` CLI),
and ``plan_geometry``/``vmem_required`` (the plancheck bridge).
"""

from __future__ import annotations

import dataclasses
import functools

from repro.kernels.specs import KernelGeometry, KernelSpec

#: per-core VMEM budget the kernels must fit (bytes).
VMEM_LIMIT_BYTES = 16 << 20

#: how many violations a failing check reports before truncating.
_MAX_VIOLATIONS = 3


@dataclasses.dataclass(frozen=True)
class KernelCheck:
    """One verified kernel property: ``status`` is ok | fail."""

    kernel: str                 # KernelSpec name, e.g. "cw_tis/vscan"
    name: str                   # carry-order | out-coverage | in-bounds | vmem-fit
    status: str
    detail: str

    @property
    def ok(self) -> bool:
        return self.status == "ok"

    def render(self) -> str:
        return (f"{self.status.upper():4s} {self.name:12s} "
                f"[{self.kernel}] {self.detail}")

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class KernelVerdict:
    """All checks for one method at one geometry."""

    method: str
    geometry: KernelGeometry
    checks: tuple[KernelCheck, ...]

    @property
    def ok(self) -> bool:
        return not self.failures

    @property
    def failures(self) -> tuple[KernelCheck, ...]:
        return tuple(c for c in self.checks if c.status == "fail")

    def render(self) -> str:
        g = self.geometry
        head = (
            f"kernelcheck {self.method} @ {g.n}x{g.h}x{g.w}/{g.num_bins} "
            f"bins (tile {g.tile}, bin_block {g.bin_block}): "
            + ("OK" if self.ok else f"REJECTED ({len(self.failures)})")
        )
        return "\n".join([head] + [f"  {c.render()}" for c in self.checks])

    def to_json(self) -> dict:
        g = self.geometry
        return {
            "method": self.method,
            "geometry": {
                "n": g.n, "h": g.h, "w": g.w, "num_bins": g.num_bins,
                "tile": g.tile, "bin_block": g.bin_block,
            },
            "ok": self.ok,
            "checks": [c.to_json() for c in self.checks],
        }


# ---------------------------------------------------------------------------
# grid enumeration
# ---------------------------------------------------------------------------
def iter_grid(spec: KernelSpec):
    """Grid points as {dim: index} dicts, in the spec's declared
    sequential order (last dimension innermost)."""
    names = spec.dim_names
    sizes = spec.grid_sizes
    total = 1
    for s in sizes:
        total *= s
    for flat in range(total):
        rev = []
        rem = flat
        for size in reversed(sizes):
            rev.append(rem % size)
            rem //= size
        yield dict(zip(names, reversed(rev)))


def _step_key(spec: KernelSpec, g) -> tuple[int, ...]:
    """A grid point as a comparable tuple in grid order."""
    return tuple(g[name] for name in spec.dim_names)


def _fmt_point(g) -> str:
    return "(" + ", ".join(f"{k}={v}" for k, v in g.items()) + ")"


# ---------------------------------------------------------------------------
# check (1): carry happens-before
# ---------------------------------------------------------------------------
def check_carry_order(spec: KernelSpec) -> KernelCheck:
    """Walk the grid in declared order; every declared scratch read must
    see a value whose *last* writer is exactly the declared producer."""
    name = "carry-order"
    if spec.carry_reads is None:
        return KernelCheck(spec.name, name, "ok",
                           "no scratch carries declared")
    last_writer: dict[tuple, tuple[int, ...]] = {}
    violations: list[str] = []
    steps = 0
    edges = 0
    for g in iter_grid(spec):
        steps += 1
        here = _step_key(spec, g)
        for cell, producer in spec.carry_reads(g):
            edges += 1
            want = tuple(producer[n] for n in spec.dim_names)
            got = last_writer.get(cell)
            if got is None:
                violations.append(
                    f"step {_fmt_point(g)} reads scratch cell {cell!r} "
                    f"before any write (declared producer "
                    f"{tuple(want)})")
            elif got != want:
                violations.append(
                    f"step {_fmt_point(g)} reads scratch cell {cell!r} "
                    f"expecting the value from step {want}, but the "
                    f"last write under this grid order was at {got} — "
                    "the declared sequential order does not realize "
                    "the carry chain")
            if len(violations) >= _MAX_VIOLATIONS:
                return KernelCheck(
                    spec.name, name, "fail",
                    "; ".join(violations) + " ... (truncated)")
        if spec.carry_writes is not None:
            for cell in spec.carry_writes(g):
                last_writer[cell] = here
    if violations:
        return KernelCheck(spec.name, name, "fail", "; ".join(violations))
    order = " > ".join(spec.dim_names)
    return KernelCheck(
        spec.name, name, "ok",
        f"{edges} carry edge(s) proven over {steps} sequential steps "
        f"(grid order {order}, last innermost)")


# ---------------------------------------------------------------------------
# check (2): output coverage / race-freedom
# ---------------------------------------------------------------------------
def check_out_coverage(spec: KernelSpec) -> KernelCheck:
    """Every out-spec must tile its output exactly once: the multiset of
    block indices over the grid equals the output's block grid."""
    name = "out-coverage"
    problems: list[str] = []
    for op in spec.out_specs:
        blocks_per_dim = []
        for dim, (size, blk) in enumerate(zip(op.shape, op.block)):
            if size % blk:
                problems.append(
                    f"{op.name}: dim {dim} size {size} not a multiple "
                    f"of block {blk}")
            blocks_per_dim.append(max(1, size // blk))
        seen: dict[tuple, int] = {}
        for g in iter_grid(spec):
            idx = tuple(op.index_map(*_step_key(spec, g)))
            seen[idx] = seen.get(idx, 0) + 1
        total = 1
        for b in blocks_per_dim:
            total *= b
        overlaps = {i: c for i, c in seen.items() if c > 1}
        gaps = total - len(seen)
        if overlaps:
            worst = sorted(overlaps.items())[:_MAX_VIOLATIONS]
            problems.append(
                f"{op.name}: {len(overlaps)} output block(s) written "
                f"more than once (a write race on concurrent-grid "
                f"backends), e.g. "
                + ", ".join(f"{i} x{c}" for i, c in worst))
        if gaps > 0:
            missing = [
                i for i in _iter_block_grid(blocks_per_dim)
                if i not in seen
            ][:_MAX_VIOLATIONS]
            problems.append(
                f"{op.name}: {gaps} of {total} output block(s) never "
                f"written (garbage rows), e.g. {missing}")
    if problems:
        return KernelCheck(spec.name, name, "fail", "; ".join(problems))
    covered = ", ".join(
        f"{op.name}: {_num_blocks(op)} blocks exactly once"
        for op in spec.out_specs)
    return KernelCheck(spec.name, name, "ok", covered)


def _num_blocks(op) -> int:
    total = 1
    for size, blk in zip(op.shape, op.block):
        total *= max(1, size // blk)
    return total


def _iter_block_grid(blocks_per_dim):
    idx = [0] * len(blocks_per_dim)
    while True:
        yield tuple(idx)
        for d in range(len(idx) - 1, -1, -1):
            idx[d] += 1
            if idx[d] < blocks_per_dim[d]:
                break
            idx[d] = 0
        else:
            return


# ---------------------------------------------------------------------------
# check (3): in-bounds index maps
# ---------------------------------------------------------------------------
def check_in_bounds(spec: KernelSpec) -> KernelCheck:
    """Every operand's block index must stay inside the padded operand
    for all grid points: ``0 <= i`` and ``(i + 1) * block <= shape``."""
    name = "in-bounds"
    violations: list[str] = []
    operands = spec.in_specs + spec.out_specs
    points = 0
    for g in iter_grid(spec):
        points += 1
        key = _step_key(spec, g)
        for op in operands:
            idx = tuple(op.index_map(*key))
            if len(idx) != len(op.block):
                violations.append(
                    f"{op.name}: index map yields rank {len(idx)} for a "
                    f"rank-{len(op.block)} block")
            else:
                for d, (i, blk, size) in enumerate(
                        zip(idx, op.block, op.shape)):
                    if i < 0 or (i + 1) * blk > size:
                        violations.append(
                            f"{op.name}: step {_fmt_point(g)} maps dim "
                            f"{d} to block {i} — elements "
                            f"[{i * blk}, {(i + 1) * blk}) outside the "
                            f"padded extent {size}")
            if len(violations) >= _MAX_VIOLATIONS:
                return KernelCheck(
                    spec.name, name, "fail",
                    "; ".join(violations) + " ... (truncated)")
    if violations:
        return KernelCheck(spec.name, name, "fail", "; ".join(violations))
    return KernelCheck(
        spec.name, name, "ok",
        f"{len(operands)} operand(s) in bounds at all {points} grid "
        "points")


# ---------------------------------------------------------------------------
# check (4): VMEM fit
# ---------------------------------------------------------------------------
def check_vmem_fit(spec: KernelSpec) -> KernelCheck:
    name = "vmem-fit"
    nbytes = spec.vmem_bytes()
    detail = f"{nbytes} B ({spec.vmem_detail()})"
    if nbytes > VMEM_LIMIT_BYTES:
        return KernelCheck(
            spec.name, name, "fail",
            f"{detail} exceeds the {VMEM_LIMIT_BYTES} B per-core VMEM "
            "budget — shrink tile/bin_block")
    return KernelCheck(
        spec.name, name, "ok", f"{detail} of {VMEM_LIMIT_BYTES} B")


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------
def check_spec(spec: KernelSpec, *, enum_spec: KernelSpec | None = None
               ) -> tuple[KernelCheck, ...]:
    """All four checks for one pass.  ``enum_spec`` (the same pass built
    at the canonical clamped geometry) runs the enumeration checks;
    ``spec`` (real geometry) prices vmem-fit."""
    e = enum_spec if enum_spec is not None else spec
    return (
        check_carry_order(e),
        check_out_coverage(e),
        check_in_bounds(e),
        check_vmem_fit(spec),
    )


def specs_for(method: str, geom: KernelGeometry) -> tuple[KernelSpec, ...]:
    from repro.kernels.ops import KERNEL_SPECS

    builder = KERNEL_SPECS.get(method)
    if builder is None:
        raise KeyError(
            f"method {method!r} has no registered KernelSpec "
            f"(registry: {sorted(KERNEL_SPECS)})")
    return builder(geom)


@functools.lru_cache(maxsize=64)
def check_method(method: str, geom: KernelGeometry) -> KernelVerdict:
    """Verify every pass of ``method`` at ``geom``: enumeration on the
    canonical clamped geometry, vmem on the real one."""
    real = specs_for(method, geom)
    canon = specs_for(method, geom.canonical())
    checks: list[KernelCheck] = []
    for spec, enum_spec in zip(real, canon):
        checks.extend(check_spec(spec, enum_spec=enum_spec))
    return KernelVerdict(method=method, geometry=geom,
                         checks=tuple(checks))


def check_kernels(methods=None, geometries=None) -> list[KernelVerdict]:
    """The ``--check-kernels`` sweep: every registered method (or
    ``methods``) at each geometry (default: the 640x480/32-bin serving
    shape and the paper's §4.6 8192x8192/128-bin scale)."""
    from repro.kernels.ops import KERNEL_SPECS

    if methods is None:
        methods = sorted(KERNEL_SPECS)
    if geometries is None:
        geometries = DEFAULT_GEOMETRIES
    return [
        check_method(m, g) for g in geometries for m in methods
    ]


DEFAULT_GEOMETRIES = (
    KernelGeometry(n=2, h=480, w=640, num_bins=32),
    KernelGeometry(n=1, h=8192, w=8192, num_bins=128),
)


# ---------------------------------------------------------------------------
# plancheck bridge
# ---------------------------------------------------------------------------
def plan_method(plan) -> str:
    """The kernel a plan actually dispatches: a query-fused plan runs
    the fused-rows kernel (kernels/fused_rows.py) no matter which scan
    method it names — verify THAT spec, not the full-H one."""
    return "fused_rows" if plan.representation == "fused" else plan.method


def plan_geometry(plan) -> KernelGeometry:
    """The launch geometry an ExecutionPlan's dispatches use: microbatch
    frames per dispatch (floor 2 — the canonical enumeration needs the
    frame-boundary resets exercised either way), band height rather than
    frame height when the plan streams bands.  Fused plans get a
    :class:`~repro.kernels.specs.FusedRowsGeometry` carrying the real
    per-strip emission width and the early-exit height (the scan stops
    after the strip holding the last requested row)."""
    s = plan.spec
    n = max(plan.microbatch, 1)
    if plan.representation == "fused":
        from repro.kernels.fused_rows import fused_geometry

        rows = s.query_rows
        h_cut = min(s.height, (max(rows) // plan.tile + 1) * plan.tile)
        return fused_geometry(
            rows, n, h_cut, s.width, s.num_bins,
            tile=plan.tile, bin_block=plan.bin_block,
        )
    h = s.height
    if plan.band_plan is not None:
        h = plan.band_plan.band_h
    return KernelGeometry(n=n, h=h, w=s.width, num_bins=s.num_bins,
                          tile=plan.tile, bin_block=plan.bin_block)


def vmem_required(method: str, geom: KernelGeometry
                  ) -> tuple[int, str] | None:
    """Peak per-core VMEM bytes across the method's passes (passes run
    sequentially, so the peak is the max), with a detail string — what
    ``plancheck``'s vmem-fit check prices.  ``None`` when the method has
    no registered KernelSpec (no Pallas kernel to model)."""
    from repro.kernels.ops import KERNEL_SPECS

    if method not in KERNEL_SPECS:
        return None
    specs = specs_for(method, geom)
    peak = max(specs, key=lambda sp: sp.vmem_bytes())
    label = f" (peak pass {peak.name})" if len(specs) > 1 else ""
    return peak.vmem_bytes(), peak.vmem_detail() + label
