"""The project's invariant rules.

Each rule encodes one contract the engine rests on — previously only a
docstring, a one-off test monkeypatch, or a run-time failure:

  * ``sharded-concat``   — the jax-0.4.37 hazard (core/hsource.py:28):
    ``jnp.concatenate``/``jnp.stack`` over device bands or shards
    silently mis-assembles; cross-band/shard assembly must be host-side
    (``np.asarray`` per piece, then ``np.concatenate``).
  * ``host-sync``        — a host sync (``np.asarray``,
    ``block_until_ready``, ``.item()``, ``device_get``) inside
    ``FrameRuntime`` dispatch or a kernel wrapper serializes the §4.4
    double-buffering overlap.  Sanctioned sync points carry a pragma.
  * ``carry-contract``   — any function passed as a runtime ``step``
    must be ``step(chunk, carry) -> (out, carry)``.
  * ``no-shim-use``      — internal code must not call the deprecated
    ``banded_*`` shims; the unified HSource entry points replace them.
  * ``overflow-policy``  — every storage policy must declare a
    statically-known validity bound (the §4.6 uint16/fp32 regime), and
    a storage-policy HSource must expose ``exact_region_bound``.
  * ``lock-discipline``  — attributes a class declares in
    ``_LOCK_PROTECTED`` may only be mutated under ``with self._lock:``
    (the close()/drain race class fixed in PR 5).
  * ``lock-order``       — per class, the lock-acquisition graph
    (nested ``with self.<lock>:`` blocks plus ``self.method()`` calls
    made while holding a lock, followed into the callee) must be
    acyclic, non-reentrant locks must not be re-acquired, and no
    blocking call (``.join()``, ``.result()``, blocking queue
    get/put, ``time.sleep``, or future completion — inline done
    callbacks) may run under a held lock.

Suppress a deliberate exception with
``# analysis: allow-<rule>(reason)`` on (or directly above) the line.
"""

from __future__ import annotations

import ast
from typing import Iterable, Iterator

from repro.analysis.lint import (
    FileContext,
    Rule,
    const_int,
    dotted_name,
    module_int_env,
    register,
)

# deprecated shims defined (and allowed) only in core/region_query.py
SHIM_NAMES = frozenset({
    "banded_region_histogram",
    "banded_sliding_window_histograms",
    "banded_likelihood_map",
})

# modules whose whole job is cross-band/cross-shard assembly: any
# device-side concat there is on the hazard path.
ASSEMBLY_FILES = frozenset({"hsource.py", "bands.py", "distributed.py"})

_CONCAT_FNS = frozenset({
    "jnp.concatenate", "jnp.stack",
    "jax.numpy.concatenate", "jax.numpy.stack",
})

_SYNC_CALLS = frozenset({
    "np.asarray", "numpy.asarray",
    "jax.block_until_ready", "jax.device_get",
})

# container mutators always treated as writes on a protected attribute
_MUTATORS = frozenset({
    "append", "extend", "insert", "remove", "pop", "popitem", "clear",
    "update", "setdefault", "move_to_end", "add", "discard", "appendleft",
})


def _walk_calls(tree: ast.AST) -> Iterator[ast.Call]:
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            yield node


@register
class ShardedConcatRule(Rule):
    name = "sharded-concat"
    pragma = "sharded-concat"
    description = (
        "no jnp.concatenate/jnp.stack over band or shard operands in "
        "core/ assembly paths — under jax 0.4.37 a device-side concat of "
        "row-sharded bands silently mis-assembles; go host-side "
        "(np.asarray each piece, np.concatenate) as hsource.py does"
    )

    def applies_to(self, ctx: FileContext) -> bool:
        return "core" in ctx.parts

    def check(self, ctx: FileContext) -> Iterable[tuple[int, str]]:
        assembly = ctx.filename in ASSEMBLY_FILES
        for call in _walk_calls(ctx.tree):
            dn = dotted_name(call.func)
            if dn not in _CONCAT_FNS:
                continue
            operands = " ".join(
                ast.unparse(a) for a in list(call.args) + [
                    kw.value for kw in call.keywords
                ]
            ).lower()
            banded = "band" in operands or "shard" in operands
            if assembly or banded:
                what = "band/shard operands" if banded else \
                    f"an assembly module ({ctx.filename})"
                yield call.lineno, (
                    f"{dn} over {what}: device-side concat of banded or "
                    "sharded pieces is the jax-0.4.37 silent-mis-assembly "
                    "hazard — assemble host-side (np.asarray per piece, "
                    "then np.concatenate)"
                )


@register
class HostSyncRule(Rule):
    name = "host-sync"
    pragma = "host-sync"
    description = (
        "no np.asarray / block_until_ready / .item() / device_get in "
        "FrameRuntime dispatch or kernel wrappers — a host sync there "
        "serializes the double-buffered overlap; sanctioned sync points "
        "need `# analysis: allow-host-sync(reason)`"
    )

    def applies_to(self, ctx: FileContext) -> bool:
        return (
            ctx.relpath.endswith("core/runtime.py")
            or "kernels" in ctx.parts
        )

    def check(self, ctx: FileContext) -> Iterable[tuple[int, str]]:
        for call in _walk_calls(ctx.tree):
            dn = dotted_name(call.func)
            if dn in _SYNC_CALLS:
                yield call.lineno, (
                    f"{dn} is a host sync in a hot path — it stalls the "
                    "dispatch pipeline until the device catches up"
                )
                continue
            if isinstance(call.func, ast.Attribute) and \
                    call.func.attr in ("item", "block_until_ready"):
                yield call.lineno, (
                    f".{call.func.attr}() is a host sync in a hot "
                    "path — it stalls the dispatch pipeline"
                )


@register
class CarryContractRule(Rule):
    name = "carry-contract"
    pragma = "carry-contract"
    description = (
        "a function passed as a runtime `step` must satisfy "
        "step(chunk, carry) -> (out, carry): take exactly two arguments "
        "and return a two-tuple on every path"
    )

    def check(self, ctx: FileContext) -> Iterable[tuple[int, str]]:
        # local function definitions, for resolving `step` by name
        defs: dict[str, ast.FunctionDef] = {}
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                defs[node.name] = node

        for call in _walk_calls(ctx.tree):
            dn = dotted_name(call.func)
            if dn is None:
                continue
            leaf = dn.split(".")[-1]
            if leaf == "FrameRuntime":
                step = call.args[0] if call.args else next(
                    (kw.value for kw in call.keywords if kw.arg == "step"),
                    None,
                )
            elif leaf == "runtime_for":
                step = call.args[1] if len(call.args) > 1 else next(
                    (kw.value for kw in call.keywords if kw.arg == "step"),
                    None,
                )
            else:
                continue
            if step is None:
                continue
            yield from self._check_step(step, defs)

    def _check_step(self, step: ast.AST, defs: dict) -> Iterator[tuple[int, str]]:
        # FrameRuntime.stateless(fn) lifts fn into the contract — fine.
        if isinstance(step, ast.Call):
            dn = dotted_name(step.func)
            if dn is not None and dn.split(".")[-1] == "stateless":
                return
            return  # other call results are unresolvable — skip
        if isinstance(step, ast.Lambda):
            sig = list(self._check_signature(step, step.args, "lambda"))
            if sig:
                yield from sig     # wrong arity subsumes the return check
                return
            params = {a.arg for a in step.args.args}
            if not self._returns_pair(step.body, params):
                yield step.lineno, (
                    "step lambda must return a two-tuple (out, carry)"
                )
            return
        if isinstance(step, ast.Name) and step.id in defs:
            fn = defs[step.id]
            sig = list(self._check_signature(fn, fn.args, f"def {fn.name}"))
            if sig:
                yield from sig     # wrong arity subsumes the return check
                return
            params = {a.arg for a in fn.args.args}
            returns = [
                n for n in ast.walk(fn)
                if isinstance(n, ast.Return) and n.value is not None
            ]
            for ret in returns:
                if not self._returns_pair(ret.value, params):
                    yield ret.lineno, (
                        f"step `{fn.name}` must return a two-tuple "
                        "(out, carry) on every path"
                    )
        # anything else (parameter, attribute, comprehension) — skip

    @staticmethod
    def _check_signature(node, args: ast.arguments, label: str):
        n_pos = len(args.args) + len(args.posonlyargs)
        if n_pos != 2 or args.vararg or args.kwonlyargs:
            yield node.lineno, (
                f"step {label} must take exactly (chunk, carry), "
                f"got {n_pos} positional arg(s)"
            )

    @staticmethod
    def _returns_pair(expr: ast.AST, params: set) -> bool:
        if isinstance(expr, ast.Tuple):
            return len(expr.elts) == 2
        if isinstance(expr, ast.Name):
            # returning a bare parameter is the classic carry-drop bug;
            # other names (locals built as tuples) are unresolvable
            return expr.id not in params
        # non-literal returns (calls, attributes) are unresolvable — trust
        return not isinstance(expr, (ast.Constant, ast.List, ast.Dict))


@register
class NoShimUseRule(Rule):
    name = "no-shim-use"
    pragma = "shim-use"
    description = (
        "internal code must not import or call the deprecated banded_* "
        "shims (banded_region_histogram & co.) — the unified HSource "
        "entry points in core/region_query.py accept a BandedH directly"
    )

    def applies_to(self, ctx: FileContext) -> bool:
        # the defining module keeps the shims until their removal release
        return ctx.filename != "region_query.py"

    def check(self, ctx: FileContext) -> Iterable[tuple[int, str]]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ImportFrom):
                for alias in node.names:
                    if alias.name in SHIM_NAMES:
                        yield node.lineno, (
                            f"imports deprecated shim `{alias.name}` — "
                            "use the unified entry point on an HSource"
                        )
            elif isinstance(node, ast.Attribute) and node.attr in SHIM_NAMES:
                yield node.lineno, (
                    f"references deprecated shim `{node.attr}` — use the "
                    "unified entry point on an HSource"
                )
            elif isinstance(node, ast.Name) and node.id in SHIM_NAMES \
                    and isinstance(node.ctx, ast.Load):
                yield node.lineno, (
                    f"uses deprecated shim `{node.id}` — use the unified "
                    "entry point on an HSource"
                )


@register
class OverflowPolicyRule(Rule):
    name = "overflow-policy"
    pragma = "overflow-policy"
    description = (
        "every STORAGE_POLICIES entry must be (dtype, bound) with a "
        "statically-known integer validity bound (§4.6 exact-count "
        "regime), and any HSource carrying a `storage` policy field "
        "must expose exact_region_bound()"
    )

    def check(self, ctx: FileContext) -> Iterable[tuple[int, str]]:
        env = module_int_env(ctx.tree)
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Assign):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name) and \
                            tgt.id == "STORAGE_POLICIES":
                        yield from self._check_policies(node.value, env)
            elif isinstance(node, ast.ClassDef):
                yield from self._check_storage_class(node)

    @staticmethod
    def _check_policies(value: ast.AST, env: dict) -> Iterator[tuple[int, str]]:
        if not isinstance(value, ast.Dict):
            yield value.lineno, (
                "STORAGE_POLICIES must be a literal dict so the bounds "
                "are statically checkable"
            )
            return
        for key, val in zip(value.keys, value.values):
            name = ast.unparse(key) if key is not None else "?"
            if not (isinstance(val, ast.Tuple) and len(val.elts) == 2):
                yield val.lineno, (
                    f"storage policy {name} must be a (dtype, bound) "
                    "pair declaring its validity bound"
                )
                continue
            bound = const_int(val.elts[1], env)
            if bound is None:
                yield val.lineno, (
                    f"storage policy {name}: validity bound must fold to "
                    "a compile-time integer (plancheck depends on it)"
                )
            elif bound <= 0:
                yield val.lineno, (
                    f"storage policy {name}: validity bound {bound} "
                    "must be positive"
                )

    @staticmethod
    def _check_storage_class(cls: ast.ClassDef) -> Iterator[tuple[int, str]]:
        # only HSource subclasses answer queries; plan/spec dataclasses
        # carry `storage` as metadata and are validated by plancheck.
        is_hsource = any(
            (dotted_name(base) or "").split(".")[-1] == "HSource"
            for base in cls.bases
        )
        has_storage = any(
            isinstance(stmt, ast.AnnAssign)
            and isinstance(stmt.target, ast.Name)
            and stmt.target.id == "storage"
            for stmt in cls.body
        )
        if not (is_hsource and has_storage):
            return
        has_bound = any(
            isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef))
            and stmt.name == "exact_region_bound"
            for stmt in cls.body
        )
        if not has_bound:
            yield cls.lineno, (
                f"class {cls.name} carries a `storage` policy field but "
                "does not define exact_region_bound() — queries cannot "
                "enforce the policy's validity bound"
            )


@register
class LockDisciplineRule(Rule):
    name = "lock-discipline"
    pragma = "lock-discipline"
    description = (
        "attributes a class lists in _LOCK_PROTECTED may only be "
        "mutated inside `with self._lock:` (outside __init__) — "
        "declared mutator methods (_LOCK_PROTECTED_MUTATORS) and "
        "container mutators count as mutations"
    )

    def check(self, ctx: FileContext) -> Iterable[tuple[int, str]]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ClassDef):
                yield from self._check_class(node)

    def _check_class(self, cls: ast.ClassDef) -> Iterator[tuple[int, str]]:
        protected = self._declared(cls, "_LOCK_PROTECTED")
        if not protected:
            return
        mutators = _MUTATORS | self._declared(cls, "_LOCK_PROTECTED_MUTATORS")
        for stmt in cls.body:
            if not isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if stmt.name == "__init__":   # construction precedes sharing
                continue
            yield from self._scan(stmt.body, protected, mutators, False)

    @staticmethod
    def _declared(cls: ast.ClassDef, name: str) -> frozenset:
        for stmt in cls.body:
            targets = []
            if isinstance(stmt, ast.Assign):
                targets = stmt.targets
            elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
                targets = [stmt.target]
            for tgt in targets:
                if isinstance(tgt, ast.Name) and tgt.id == name:
                    try:
                        value = ast.literal_eval(stmt.value)
                    except (ValueError, TypeError):
                        return frozenset()
                    return frozenset(
                        v for v in value if isinstance(v, str)
                    )
        return frozenset()

    def _scan(self, body, protected, mutators, locked) -> Iterator:
        for node in body:
            if isinstance(node, ast.With):
                inner = locked or any(
                    self._is_self_lock(item.context_expr)
                    for item in node.items
                )
                yield from self._scan(node.body, protected, mutators, inner)
                continue
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
                continue            # nested callables judged on their own
            if not locked:
                yield from self._check_stmt(node, protected, mutators)
            # recurse into compound statements preserving lock state
            for field in ("body", "orelse", "finalbody"):
                sub = getattr(node, field, None)
                if sub:
                    yield from self._scan(sub, protected, mutators, locked)
            for handler in getattr(node, "handlers", []) or []:
                yield from self._scan(handler.body, protected, mutators,
                                      locked)

    def _check_stmt(self, node, protected, mutators) -> Iterator:
        # only inspect this statement's own expressions, not nested blocks
        if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for tgt in targets:
                attr = self._protected_base(tgt, protected)
                if attr is not None:
                    yield node.lineno, (
                        f"`self.{attr}` is declared lock-protected but is "
                        "written outside `with self._lock:`"
                    )
        exprs = []
        if isinstance(node, ast.Expr):
            exprs = [node.value]
        elif isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)) \
                and node.value is not None:
            exprs = [node.value]
        elif isinstance(node, (ast.If, ast.While)):
            exprs = [node.test]
        elif isinstance(node, ast.Return) and node.value is not None:
            exprs = [node.value]
        for expr in exprs:
            for call in _walk_calls(expr):
                if not isinstance(call.func, ast.Attribute):
                    continue
                if call.func.attr not in mutators:
                    continue
                attr = self._protected_base(call.func.value, protected)
                if attr is not None:
                    yield call.lineno, (
                        f"`self.{attr}.{call.func.attr}(...)` mutates a "
                        "lock-protected attribute outside "
                        "`with self._lock:`"
                    )

    @staticmethod
    def _protected_base(node: ast.AST, protected) -> str | None:
        """The protected attr name if `node` roots at self.<protected>."""
        while isinstance(node, (ast.Attribute, ast.Subscript)):
            base = node.value
            if isinstance(node, ast.Attribute) and \
                    isinstance(base, ast.Name) and base.id == "self" and \
                    node.attr in protected:
                return node.attr
            node = base
        return None

    @staticmethod
    def _is_self_lock(expr: ast.AST) -> bool:
        dn = dotted_name(expr)
        return dn is not None and dn.endswith("self._lock")


# lock-constructor callables recognized by the lock-order rule; RLock is
# reentrant (re-acquisition is legal), the rest are not.
_LOCK_FACTORIES = {
    "threading.Lock": "lock",
    "threading.RLock": "rlock",
    "threading.Condition": "condition",
    "Lock": "lock",
    "RLock": "rlock",
    "Condition": "condition",
}

# attribute calls that block the calling thread outright
_BLOCKING_ATTRS = frozenset({"join", "result"})
# completing a future runs its done-callbacks inline on this thread —
# arbitrary foreign code under a held lock
_FUTURE_COMPLETERS = frozenset({"set_result", "set_exception"})
# queue methods that can block (get_nowait/put_nowait cannot)
_QUEUE_BLOCKERS = frozenset({"get", "put"})


@register
class LockOrderRule(Rule):
    name = "lock-order"
    pragma = "lock-order"
    description = (
        "per class: the lock-acquisition graph (nested `with self.X:` "
        "plus self.method() calls made while holding a lock, followed "
        "into the callee) must be acyclic; non-reentrant locks must not "
        "be re-acquired; no blocking call (.join/.result/blocking queue "
        "get/put/time.sleep/future completion) under a held lock"
    )

    def check(self, ctx: FileContext) -> Iterable[tuple[int, str]]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ClassDef):
                yield from self._check_class(node)

    # -- per-class analysis --------------------------------------------------
    def _check_class(self, cls: ast.ClassDef) -> Iterator[tuple[int, str]]:
        locks = self._lock_attrs(cls)
        if not locks:
            return
        methods = {
            stmt.name: stmt for stmt in cls.body
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef))
        }
        info = {
            name: self._scan_method(fn, locks)
            for name, fn in methods.items()
        }

        # Fixpoint closures: every lock a method may acquire and every
        # blocking call it may make, following self.method() calls.
        acq = {m: {a for a, _, _ in info[m]["acquires"]} for m in info}
        blk = {m: {d for d, _, _ in info[m]["blocks"]} for m in info}
        changed = True
        while changed:
            changed = False
            for m in info:
                for callee, _, _ in info[m]["calls"]:
                    if callee not in info:
                        continue
                    if not acq[callee] <= acq[m]:
                        acq[m] |= acq[callee]
                        changed = True
                    if not blk[callee] <= blk[m]:
                        blk[m] |= blk[callee]
                        changed = True

        # edge (a, b): b acquired while a held; remember one witness site
        edges: dict[tuple[str, str], tuple[int, str]] = {}
        for m in info:
            for lock, line, held in info[m]["acquires"]:
                for h in held:
                    if h == lock:
                        if locks[lock] != "rlock":
                            yield line, (
                                f"`{m}` re-acquires non-reentrant "
                                f"`self.{lock}` it already holds — "
                                "threading.Lock self-deadlocks"
                            )
                    else:
                        edges.setdefault((h, lock), (line, m))
            for callee, line, held in info[m]["calls"]:
                if not held or callee not in info:
                    continue
                for lock in acq[callee]:
                    for h in held:
                        if h == lock:
                            if locks[lock] != "rlock":
                                yield line, (
                                    f"`{m}` holds `self.{lock}` and calls "
                                    f"`self.{callee}()`, which acquires it "
                                    "again — threading.Lock self-deadlocks"
                                )
                        else:
                            edges.setdefault((h, lock), (line, m))
                for desc in blk[callee]:
                    yield line, (
                        f"`{m}` holds {self._held_str(held)} and calls "
                        f"`self.{callee}()`, which blocks ({desc}) — the "
                        "lock is held across the wait"
                    )
            for desc, line, held in info[m]["blocks"]:
                if held:
                    yield line, (
                        f"`{m}` blocks ({desc}) while holding "
                        f"{self._held_str(held)} — every other thread "
                        "needing the lock stalls behind the wait"
                    )

        yield from self._cycles(edges)

    @staticmethod
    def _held_str(held) -> str:
        return " + ".join(f"`self.{h}`" for h in held)

    def _cycles(self, edges) -> Iterator[tuple[int, str]]:
        graph: dict[str, list[str]] = {}
        for a, b in edges:
            graph.setdefault(a, []).append(b)
        reported: set[frozenset] = set()
        for start in sorted(graph):
            path: list[str] = []

            def dfs(node):
                if node in path:
                    cycle = path[path.index(node):] + [node]
                    key = frozenset(cycle)
                    if key not in reported:
                        reported.add(key)
                        line, meth = edges[(cycle[0], cycle[1])]
                        yield line, (
                            "lock-order cycle "
                            + " -> ".join(f"self.{c}" for c in cycle)
                            + f" (one edge acquired in `{meth}`) — two "
                            "threads taking the locks in opposite order "
                            "deadlock"
                        )
                    return
                path.append(node)
                for nxt in graph.get(node, ()):
                    yield from dfs(nxt)
                path.pop()

            yield from dfs(start)

    # -- method scan ---------------------------------------------------------
    @staticmethod
    def _lock_attrs(cls: ast.ClassDef) -> dict[str, str]:
        """``self.<attr>`` assignments whose value is a lock constructor
        call, anywhere in the class body: attr -> kind."""
        locks: dict[str, str] = {}
        for node in ast.walk(cls):
            if not isinstance(node, ast.Assign):
                continue
            if not isinstance(node.value, ast.Call):
                continue
            kind = _LOCK_FACTORIES.get(dotted_name(node.value.func) or "")
            if kind is None:
                continue
            for tgt in node.targets:
                if isinstance(tgt, ast.Attribute) and \
                        isinstance(tgt.value, ast.Name) and \
                        tgt.value.id == "self":
                    locks[tgt.attr] = kind
        return locks

    def _scan_method(self, fn, locks) -> dict:
        out: dict = {"acquires": [], "calls": [], "blocks": []}
        self._scan_body(fn.body, locks, (), out)
        return out

    def _scan_body(self, body, locks, held, out) -> None:
        for node in body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue            # nested callables judged on their own
            if isinstance(node, ast.With):
                new_held = held
                for item in node.items:
                    attr = self._self_lock_attr(item.context_expr, locks)
                    if attr is not None:
                        out["acquires"].append((attr, node.lineno, new_held))
                        new_held = new_held + (attr,)
                    else:
                        self._scan_exprs([item.context_expr], locks,
                                         held, out)
                self._scan_body(node.body, locks, new_held, out)
                continue
            # this statement's own expressions (not nested blocks)
            self._scan_exprs(self._stmt_exprs(node), locks, held, out)
            for field in ("body", "orelse", "finalbody"):
                sub = getattr(node, field, None)
                if sub:
                    self._scan_body(sub, locks, held, out)
            for handler in getattr(node, "handlers", []) or []:
                self._scan_body(handler.body, locks, held, out)

    @staticmethod
    def _stmt_exprs(node) -> list:
        exprs = []
        for field, value in ast.iter_fields(node):
            if field in ("body", "orelse", "finalbody", "handlers"):
                continue
            if isinstance(value, ast.expr):
                exprs.append(value)
            elif isinstance(value, list):
                exprs.extend(v for v in value if isinstance(v, ast.expr))
        return exprs

    def _scan_exprs(self, exprs, locks, held, out) -> None:
        for expr in exprs:
            for call in _walk_calls(expr):
                if not isinstance(call.func, ast.Attribute):
                    if dotted_name(call.func) == "time.sleep":
                        out["blocks"].append(
                            ("time.sleep(...)", call.lineno, held))
                    continue
                attr = call.func.attr
                base = dotted_name(call.func.value) or ""
                if base == "self" and attr not in locks:
                    out["calls"].append((attr, call.lineno, held))
                    continue
                if dotted_name(call.func) == "time.sleep":
                    out["blocks"].append(
                        ("time.sleep(...)", call.lineno, held))
                elif attr in _BLOCKING_ATTRS:
                    out["blocks"].append(
                        (f"{base or '...'}.{attr}()", call.lineno, held))
                elif attr in _FUTURE_COMPLETERS:
                    out["blocks"].append(
                        (f"{base or '...'}.{attr}() runs done-callbacks "
                         "inline", call.lineno, held))
                elif attr in _QUEUE_BLOCKERS and self._queue_like(base):
                    out["blocks"].append(
                        (f"{base}.{attr}() can block on the queue",
                         call.lineno, held))

    @staticmethod
    def _queue_like(base: str) -> bool:
        leaf = base.split(".")[-1].lower()
        return "queue" in leaf or leaf.endswith("_q")

    @staticmethod
    def _self_lock_attr(expr: ast.AST, locks) -> str | None:
        """`self.<lock attr>` in a with-item, else None."""
        if isinstance(expr, ast.Attribute) and \
                isinstance(expr.value, ast.Name) and \
                expr.value.id == "self" and expr.attr in locks:
            return expr.attr
        return None
