"""Static plan validation: decide feasibility before a dispatch runs.

Ehsan et al.'s embedded integral-image work (arXiv:1510.05138) frames
compute-vs-store feasibility as a *decidable, up-front* check.  This
module is that check for an :class:`~repro.core.engine.ExecutionPlan`:
an abstract interpretation over the plan using ``jax.eval_shape`` (no
FLOPs, no device memory) plus the planner's own metadata.

``check_plan(plan, queries=())`` verifies, without executing:

  * **representation** — the plan's decision is internally consistent
    (known representation, mesh-axis divisibility for sharded plans);
  * **h-shape** — the kernel the plan selects produces the (..., b, h, w)
    fp32 H the representation expects, via ``jax.eval_shape``;
  * **carry-chain** — every band height in the band plan accepts and
    re-emits the (..., b, w) bottom-row carry (again by eval_shape);
  * **memory-budget** — the peak *live* H footprint (microbatch x
    per-frame H for dense, the largest band for banded/spilled) fits
    ``memory_budget_bytes``;
  * **vmem-fit** — Pallas plans: the per-core VMEM working set
    (double-buffered in/out blocks + carry + scratch) fits the ~16 MiB
    budget, from the kernels' block specs;
  * **count-validity** — the §4.6 exactness regime: storage-policy
    plans hard-fail when the frame's pixel count exceeds the fp32
    exact-integer range (mirroring ``validate_storage_policy``);
    plain fp32 plans get a warning, since per-query bounds are
    enforced at query time;
  * **query-validity** — when queries are supplied: each query's
    largest region/window area fits the plan's exact-count bound
    (``uint16``: 65535 px of modular arithmetic);
  * **incremental** — video-delta plans only: the dirty-fraction
    decision input is present and in range, the representation can
    update in place, and the line prices the recomputed-vs-reused
    bytes per frame.

The structural verdict is cached per plan (plans are frozen,
hashable dataclasses), so ``HistogramEngine.validate`` — run before
every dispatch — costs a dict lookup after the first call.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import numpy as np

from repro.core.bands import FP32_EXACT_COUNT, STORAGE_POLICIES

#: per-core VMEM budget the Pallas kernels must fit (bytes).
VMEM_LIMIT_BYTES = 16 << 20

_STATUS_ORDER = ("fail", "warn", "ok", "skip")


@dataclasses.dataclass(frozen=True)
class PlanCheck:
    """One verified property: ``status`` is ok | warn | fail | skip."""

    name: str
    status: str
    detail: str

    def render(self) -> str:
        return f"{self.status.upper():4s} {self.name:15s} {self.detail}"


@dataclasses.dataclass(frozen=True)
class PlanVerdict:
    """The static feasibility verdict for one plan."""

    checks: tuple[PlanCheck, ...]

    @property
    def ok(self) -> bool:
        return not self.failures

    @property
    def failures(self) -> tuple[PlanCheck, ...]:
        return tuple(c for c in self.checks if c.status == "fail")

    def render(self) -> str:
        head = "plan verdict    : " + (
            "OK (statically feasible)" if self.ok
            else f"REJECTED ({len(self.failures)} infeasible)"
        )
        lines = [head]
        lines += [f"  {c.render()}" for c in self.checks]
        return "\n".join(lines)

    def summary(self) -> str:
        counts = {s: 0 for s in _STATUS_ORDER}
        for c in self.checks:
            counts[c.status] = counts.get(c.status, 0) + 1
        return ", ".join(f"{v} {k}" for k, v in counts.items() if v)


# ---------------------------------------------------------------------------
# the checks
# ---------------------------------------------------------------------------
def _lead(plan) -> tuple:
    nf = plan.spec.num_frames
    return () if nf is None or nf == 1 else (int(nf),)


def _eval_kernel(plan, h: int, w: int, *, with_carry: bool):
    """``jax.eval_shape`` the plan's kernel on an abstract (lead, h, w)
    frame; returns the output ShapeDtypeStruct."""
    from repro.kernels.ops import integral_histogram

    s = plan.spec
    lead = _lead(plan)
    img = jax.ShapeDtypeStruct((*lead, h, w), np.dtype(s.dtype))
    carry = (
        jax.ShapeDtypeStruct((*lead, s.num_bins, w), np.float32)
        if with_carry else None
    )

    def fn(image, carry_in):
        return integral_histogram(
            image, s.num_bins, method=plan.method, backend=plan.backend,
            tile=plan.tile, bin_block=plan.bin_block, use_mxu=s.use_mxu,
            interpret=s.interpret, value_range=s.value_range,
            carry_in=carry_in,
        )

    return jax.eval_shape(fn, img, carry)


def _check_representation(plan) -> PlanCheck:
    name = "representation"
    s = plan.spec
    known = ("dense", "banded", "spilled", "sharded", "fused")
    if plan.representation not in known:
        return PlanCheck(name, "fail",
                         f"unknown representation {plan.representation!r}")
    if plan.representation == "fused":
        if not s.query_rows:
            return PlanCheck(
                name, "fail",
                "fused plan without query_rows — nothing declares which "
                "corner rows to emit")
        return PlanCheck(
            name, "ok",
            f"fused: {len(s.query_rows)} corner row(s), H never stored")
    if plan.representation == "sharded":
        if s.mesh is None:
            return PlanCheck(name, "fail", "sharded plan without a mesh")
        shape = dict(s.mesh.shape)
        axis = s.bin_axis if plan.sharding == "bin" else s.row_axis
        size = shape.get(axis)
        if size is None:
            return PlanCheck(
                name, "fail",
                f"mesh has no {axis!r} axis (axes: {sorted(shape)})")
        extent = s.num_bins if plan.sharding == "bin" else s.height
        what = "num_bins" if plan.sharding == "bin" else "height"
        if extent % size != 0:
            return PlanCheck(
                name, "fail",
                f"{what}={extent} not divisible by mesh axis "
                f"{axis!r} ({size} devices)")
        return PlanCheck(
            name, "ok",
            f"sharded[{plan.sharding}]: {what}={extent} over "
            f"{size} devices")
    if plan.storage is not None and plan.representation != "spilled":
        return PlanCheck(
            name, "fail",
            f"storage policy {plan.storage!r} on a "
            f"{plan.representation!r} plan (must spill)")
    return PlanCheck(name, "ok", plan.representation)


def _eval_fused(plan):
    """``jax.eval_shape`` the fused corner-row dispatch."""
    from repro.kernels.ops import fused_corner_rows

    s = plan.spec
    lead = _lead(plan)
    img = jax.ShapeDtypeStruct((*lead, s.height, s.width), np.dtype(s.dtype))
    rows = np.asarray(s.query_rows, np.int64)

    def fn(image):
        return fused_corner_rows(
            image, s.num_bins, rows, method=plan.method,
            backend=plan.backend, tile=plan.tile, bin_block=plan.bin_block,
            use_mxu=s.use_mxu, interpret=s.interpret,
            value_range=s.value_range,
        )

    return jax.eval_shape(fn, img)


def _check_h_shape(plan) -> PlanCheck:
    name = "h-shape"
    s = plan.spec
    if plan.representation == "fused":
        try:
            out = _eval_fused(plan)
        except Exception as e:
            return PlanCheck(name, "fail", f"fused abstract eval: {e}")
        expect = (*_lead(plan), s.num_bins, len(s.query_rows), s.width)
        if tuple(out.shape) != expect:
            return PlanCheck(
                name, "fail",
                f"fused dispatch yields {tuple(out.shape)}, plan expects "
                f"the corner-row slab {expect}")
        if out.dtype != np.float32:
            return PlanCheck(
                name, "fail",
                f"fused dispatch yields {out.dtype}, engine arithmetic "
                "is fp32")
        return PlanCheck(
            name, "ok",
            f"corner-row slab {expect} float32 via fused "
            f"{plan.method}/{plan.backend}")
    try:
        out = _eval_kernel(plan, s.height, s.width, with_carry=False)
    except Exception as e:  # abstract eval surfaces kernel/shape errors
        return PlanCheck(name, "fail", f"kernel abstract eval: {e}")
    expect = (*_lead(plan), s.num_bins, s.height, s.width)
    if tuple(out.shape) != expect:
        return PlanCheck(
            name, "fail",
            f"kernel yields {tuple(out.shape)}, plan expects {expect}")
    if out.dtype != np.float32:
        return PlanCheck(
            name, "fail",
            f"kernel yields {out.dtype}, engine arithmetic is fp32")
    return PlanCheck(
        name, "ok", f"{expect} float32 via {plan.method}/{plan.backend}")


def _check_carry_chain(plan) -> PlanCheck:
    name = "carry-chain"
    s = plan.spec
    if plan.band_plan is None:
        return PlanCheck(name, "skip", "single-band plan has no carry")
    heights = sorted({r1 - r0 for r0, r1 in plan.band_plan.spans})
    carry_shape = (*_lead(plan), s.num_bins, s.width)
    for bh in heights:
        try:
            out = _eval_kernel(plan, bh, s.width, with_carry=True)
        except Exception as e:
            return PlanCheck(
                name, "fail",
                f"{bh}-row band rejects the {carry_shape} carry: {e}")
        band_expect = (*_lead(plan), s.num_bins, bh, s.width)
        if tuple(out.shape) != band_expect:
            return PlanCheck(
                name, "fail",
                f"{bh}-row band yields {tuple(out.shape)}, "
                f"expected {band_expect}")
        # next carry = H_band[..., -1, :]; shape follows from band_expect
        emitted = band_expect[:-2] + band_expect[-1:]
        if emitted != carry_shape:
            return PlanCheck(
                name, "fail",
                f"{bh}-row band re-emits carry {emitted}, "
                f"chain needs {carry_shape}")
    return PlanCheck(
        name, "ok",
        f"{plan.band_plan.num_bands} bands (heights {heights}) thread a "
        f"{carry_shape} carry")


def _check_memory_budget(plan) -> PlanCheck:
    name = "memory-budget"
    s = plan.spec
    budget = s.memory_budget_bytes
    if budget is None:
        return PlanCheck(name, "skip", "no memory budget declared")
    if plan.representation == "fused":
        k = len(s.query_rows)
        nf = 1 if s.num_frames is None else s.num_frames
        live = 4 * nf * s.num_bins * k * s.width
        what = f"fused corner-row slab ({k} row(s))"
    elif plan.band_plan is not None:
        live = plan.band_plan.band_bytes
        what = f"largest band ({plan.band_plan.band_h} rows)"
    else:
        live = plan.microbatch * s.per_frame_h_bytes
        what = f"microbatch of {plan.microbatch} frame(s)"
    if live > budget:
        return PlanCheck(
            name, "fail",
            f"{what} holds {live} B of live H > {budget} B budget")
    return PlanCheck(name, "ok", f"{what}: {live} B <= {budget} B budget")


def _vmem_estimate(plan) -> tuple[int, str] | None:
    """Per-core VMEM bytes for the plan's Pallas kernel, or ``None`` for
    methods without one.  Delegates to the kernel's own
    :class:`~repro.kernels.specs.KernelSpec` via ``kernelcheck`` — ONE
    model, priced from the same metadata the deep kernel checks verify,
    instead of the hand-maintained per-method formula this function used
    to duplicate (which had already drifted: it omitted the
    double-buffering of the carry operand)."""
    from repro.analysis import kernelcheck

    return kernelcheck.vmem_required(
        kernelcheck.plan_method(plan), kernelcheck.plan_geometry(plan))


def _check_vmem_fit(plan) -> PlanCheck:
    name = "vmem-fit"
    if plan.backend != "pallas":
        return PlanCheck(name, "skip", f"{plan.backend} backend uses HBM")
    if plan.spec.interpret:
        return PlanCheck(name, "skip", "interpret mode runs on host")
    est = _vmem_estimate(plan)
    if est is None:
        return PlanCheck(
            name, "skip", f"no VMEM model for method {plan.method!r}")
    nbytes, detail = est
    if nbytes > VMEM_LIMIT_BYTES:
        return PlanCheck(
            name, "fail",
            f"~{nbytes} B ({detail}) exceeds the {VMEM_LIMIT_BYTES} B "
            f"per-core VMEM budget — shrink tile/bin_block")
    return PlanCheck(
        name, "ok", f"~{nbytes} B of {VMEM_LIMIT_BYTES} B ({detail})")


def _plan_exact_bound(plan) -> int:
    """Largest region pixel count queries on this plan read back exactly."""
    if plan.storage is not None:
        return int(STORAGE_POLICIES[plan.storage][1])
    return FP32_EXACT_COUNT - 1


def _check_count_validity(plan) -> PlanCheck:
    name = "count-validity"
    s = plan.spec
    px = s.height * s.width
    if plan.storage is not None:
        bound = _plan_exact_bound(plan)
        if px >= FP32_EXACT_COUNT:
            return PlanCheck(
                name, "fail",
                f"{s.height}x{s.width} frame accumulates up to {px} "
                f"counts, beyond fp32 exact range {FP32_EXACT_COUNT} — "
                f"no storage policy recovers exactness; shard spatially")
        return PlanCheck(
            name, "ok",
            f"{plan.storage} spill: regions <= {bound} px exact "
            f"(modular arithmetic)")
    if px >= FP32_EXACT_COUNT:
        return PlanCheck(
            name, "warn",
            f"{px}-px frame exceeds the fp32 exact range "
            f"{FP32_EXACT_COUNT}; only regions <= "
            f"{FP32_EXACT_COUNT - 1} px are exact (enforced per query)")
    return PlanCheck(
        name, "ok", f"{px}-px frame within fp32 exact range")


def _check_incremental(plan) -> PlanCheck:
    """Price and validate an incremental (video-delta) plan: the
    dirty-fraction decision input must be present and sane, and the
    representation must expose the ``update_bands`` hook (fused plans
    never store H; sharded plans re-shard per frame)."""
    name = "incremental"
    s = plan.spec
    df = s.dirty_fraction
    if df is None:
        return PlanCheck(
            name, "fail",
            "incremental plan without a dirty_fraction — nothing measured "
            "the frame delta that justifies an update")
    if not 0.0 <= df <= 1.0:
        return PlanCheck(
            name, "fail", f"dirty_fraction {df} outside [0, 1]")
    if plan.representation in ("fused", "sharded"):
        return PlanCheck(
            name, "fail",
            f"{plan.representation!r} representation cannot update in "
            "place (no cached H to repair)")
    per_frame = s.per_frame_h_bytes
    recomputed = int(round(df * per_frame))
    return PlanCheck(
        name, "ok",
        f"dirty fraction {df:.2f}: recompute ~{recomputed} B/frame, "
        f"reuse ~{per_frame - recomputed} B/frame of cached H")


def _check_layout(plan) -> PlanCheck:
    """Validate the planner's replica x shard mesh layout: the shard
    axis and every replica axis must exist in the mesh, be disjoint, and
    their product must cover the whole device set — a layout that
    silently strands devices would report phantom scaling headroom."""
    name = "mesh-layout"
    s = plan.spec
    lay = plan.layout
    if plan.representation != "sharded" or s.mesh is None:
        return PlanCheck(
            name, "fail",
            f"layout on a {plan.representation!r} plan without a mesh")
    shape = dict(s.mesh.shape)
    if lay.shard_axis not in shape:
        return PlanCheck(
            name, "fail",
            f"shard axis {lay.shard_axis!r} not in mesh axes "
            f"{tuple(shape)}")
    if lay.kind != plan.sharding:
        return PlanCheck(
            name, "fail",
            f"layout kind {lay.kind!r} disagrees with plan sharding "
            f"{plan.sharding!r}")
    if lay.shard_axis in lay.replica_axes:
        return PlanCheck(
            name, "fail",
            f"shard axis {lay.shard_axis!r} doubles as a replica axis")
    missing = [a for a in lay.replica_axes if a not in shape]
    if missing:
        return PlanCheck(
            name, "fail", f"replica axes {missing} not in mesh")
    mesh_devices = 1
    for v in shape.values():
        mesh_devices *= v
    covered = lay.num_groups * lay.shards_per_group
    if covered != mesh_devices or lay.shards_per_group != shape[lay.shard_axis]:
        return PlanCheck(
            name, "fail",
            f"layout covers {covered} of {mesh_devices} mesh devices")
    return PlanCheck(name, "ok", lay.describe())


def _query_area(query) -> int | None:
    """Largest region/window pixel area a query touches, else None."""
    rects = getattr(query, "rects", None)
    if rects is not None:
        r = np.asarray(rects).reshape(-1, 4)
        if r.size == 0:
            return 0
        return int(((r[:, 2] - r[:, 0] + 1)
                    * (r[:, 3] - r[:, 1] + 1)).max())
    windows = getattr(query, "windows", None)
    if windows is not None:
        return max((int(wh) * int(ww) for wh, ww in windows), default=0)
    window = getattr(query, "window", None)
    if window is not None:
        wh, ww = window
        return int(wh) * int(ww)
    return None


def _check_queries(plan, queries) -> PlanCheck:
    name = "query-validity"
    bound = _plan_exact_bound(plan)
    worst = 0
    opaque = 0
    for q in queries:
        area = _query_area(q)
        if area is None:
            opaque += 1
            continue
        if area > bound:
            return PlanCheck(
                name, "fail",
                f"{type(q).__name__} touches a {area}-px region, beyond "
                f"the plan's exact-count bound {bound} px"
                + (f" ({plan.storage} modular arithmetic wraps)"
                   if plan.storage else " (fp32 exactness)"))
        worst = max(worst, area)
    detail = f"largest region {worst} px <= {bound} px bound"
    if opaque:
        detail += f" ({opaque} query(ies) undeclared — checked at run time)"
    return PlanCheck(name, "ok", detail)


# ---------------------------------------------------------------------------
# deep checks: kernelcheck's grid/carry/coverage proofs, as PlanChecks
# ---------------------------------------------------------------------------
#: kernelcheck check name -> the PlanCheck name it merges under.
_KERNEL_CHECK_NAMES = {
    "carry-order": "kernel-carry",
    "out-coverage": "kernel-coverage",
    "in-bounds": "kernel-bounds",
    "vmem-fit": "kernel-vmem",
}


@functools.lru_cache(maxsize=256)
def _kernel_checks(plan) -> tuple[PlanCheck, ...]:
    """The four kernelcheck properties for the plan's Pallas kernel,
    folded across passes (a multi-pass method fails a property when any
    pass does).  One skip line when the plan dispatches no Pallas
    kernel."""
    from repro.analysis import kernelcheck

    if plan.backend != "pallas":
        return (PlanCheck(
            "kernel-checks", "skip",
            f"{plan.backend} backend dispatches no Pallas kernel"),)
    geom = kernelcheck.plan_geometry(plan)
    try:
        verdict = kernelcheck.check_method(
            kernelcheck.plan_method(plan), geom)
    except KeyError as e:
        return (PlanCheck(
            "kernel-checks", "fail",
            f"pallas plan without a KernelSpec contract: {e}"),)
    merged = []
    for kname, pname in _KERNEL_CHECK_NAMES.items():
        per_pass = [c for c in verdict.checks if c.name == kname]
        bad = [c for c in per_pass if not c.ok]
        if bad:
            merged.append(PlanCheck(pname, "fail", "; ".join(
                f"[{c.kernel}] {c.detail}" for c in bad)))
        else:
            merged.append(PlanCheck(pname, "ok", "; ".join(
                f"[{c.kernel}] {c.detail}" for c in per_pass)))
    return tuple(merged)


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=256)
def _structural_checks(plan) -> tuple[PlanCheck, ...]:
    checks = (
        _check_representation(plan),
        _check_h_shape(plan),
        _check_carry_chain(plan),
        _check_memory_budget(plan),
        _check_vmem_fit(plan),
        _check_count_validity(plan),
    )
    # Only incremental plans carry the extra line, so rendered verdicts
    # for every pre-existing plan stay byte-identical.
    if getattr(plan, "incremental", False):
        checks = checks + (_check_incremental(plan),)
    # Same pattern for the mesh layout: only sharded plans carry one.
    if getattr(plan, "layout", None) is not None:
        checks = checks + (_check_layout(plan),)
    return checks


def check_plan(plan, queries=(), *, deep: bool = False) -> PlanVerdict:
    """Statically verify a plan (and optionally its queries).

    ``deep=True`` additionally runs ``repro.analysis.kernelcheck``'s
    symbolic-grid proofs (carry happens-before, output coverage,
    in-bounds index maps, spec-derived VMEM fit) for Pallas plans and
    merges them into the verdict.  The default stays shallow so
    ``validate()``'s rendered verdict is unchanged for existing callers;
    the engine's pre-dispatch gate (``_validate_or_raise``) always runs
    deep.

    Structural and deep checks are cached per plan; the query check is
    cheap arithmetic computed fresh (queries carry unhashable arrays)."""
    checks = _structural_checks(plan)
    if deep:
        checks = checks + _kernel_checks(plan)
    queries = tuple(queries) if not isinstance(queries, tuple) else queries
    if queries:
        checks = checks + (_check_queries(plan, queries),)
    return PlanVerdict(checks=checks)
