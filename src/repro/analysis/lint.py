"""The project lint engine: AST rules over the repo's own invariant set.

The engine is deliberately dependency-free (stdlib ``ast`` only) so the
CI ``analysis`` job runs it without installing jax.  It provides what
every rule shares:

  * **Rule registry** — rules register a ``name`` (finding id), a
    ``pragma`` (the ``allow-<pragma>`` suppression token) and a
    ``check(ctx)`` over the parsed file.
  * **Pragma suppressions** — ``# analysis: allow-<pragma>(reason)`` on
    the offending line, or on a comment-only line directly above it.
    The reason is mandatory: an empty ``allow-x()`` does not suppress
    and is itself reported (rule id ``pragma``), as is an ``allow-``
    token no registered rule owns.
  * **Baseline** — a committed JSON file of finding fingerprints
    (rule + path + a hash of the offending source line, so findings
    don't churn when unrelated lines move).  ``--check`` fails only on
    findings that are neither suppressed nor baselined.
  * **Output** — human text or a JSON report (the CI artifact).

``python -m repro.analysis`` is the CLI (``__main__.py``); the project
rules themselves live in ``rules.py``.
"""

from __future__ import annotations

import ast
import dataclasses
import hashlib
import io
import json
import re
import tokenize
from pathlib import Path, PurePosixPath
from typing import Callable, Iterable

#: an analysis pragma comment, anywhere on a line.
_PRAGMA_COMMENT = re.compile(r"#\s*analysis:\s*(?P<body>.+?)\s*$")
#: one ``allow-<name>(<reason>)`` token inside the pragma body.
_ALLOW_TOKEN = re.compile(r"allow-(?P<name>[A-Za-z0-9_-]+)\((?P<reason>[^()]*)\)")

#: findings the engine itself emits about malformed pragmas — these are
#: not suppressible (a broken suppression must not hide itself).
PRAGMA_RULE = "pragma"

BASELINE_DEFAULT = "analysis-baseline.json"


# ---------------------------------------------------------------------------
# findings
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    rule: str
    path: str                    # root-relative, posix separators
    line: int                    # 1-indexed
    message: str
    snippet: str = ""            # the stripped offending source line
    suppressed: bool = False
    suppression_reason: str | None = None

    @property
    def fingerprint(self) -> str:
        """Baseline key: stable across unrelated line moves (hashes the
        offending line's text, not its number)."""
        digest = hashlib.sha1(self.snippet.encode()).hexdigest()[:12]
        return f"{self.rule}:{self.path}:{digest}"

    def render(self) -> str:
        tag = f" [suppressed: {self.suppression_reason}]" if self.suppressed else ""
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}{tag}"

    def to_json(self) -> dict:
        return {
            "rule": self.rule,
            "path": self.path,
            "line": self.line,
            "message": self.message,
            "snippet": self.snippet,
            "fingerprint": self.fingerprint,
            "suppressed": self.suppressed,
            "suppression_reason": self.suppression_reason,
        }


# ---------------------------------------------------------------------------
# per-file context
# ---------------------------------------------------------------------------
class FileContext:
    """Everything a rule sees for one file: source, AST, pragma map."""

    def __init__(self, relpath: str, source: str):
        self.relpath = relpath
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source)
        # line -> {pragma-name: reason}; filled by _collect_pragmas.
        self.pragmas: dict[int, dict[str, str]] = {}
        self.pragma_findings: list[Finding] = []
        self._collect_pragmas()

    # -- pragmas -------------------------------------------------------------
    def _iter_comments(self):
        """(lineno, comment_text, comment_only_line) for real COMMENT
        tokens — docstrings quoting the pragma syntax don't count."""
        try:
            tokens = tokenize.generate_tokens(io.StringIO(self.source).readline)
            for tok in tokens:
                if tok.type == tokenize.COMMENT:
                    lineno = tok.start[0]
                    prefix = self.lines[lineno - 1][: tok.start[1]]
                    yield lineno, tok.string, not prefix.strip()
        except tokenize.TokenizeError:
            return

    def _collect_pragmas(self) -> None:
        known = {r.pragma for r in RULES.values()}
        for lineno, text, comment_only in self._iter_comments():
            m = _PRAGMA_COMMENT.search(text)
            if m is None:
                continue
            body = m.group("body")
            tokens = list(_ALLOW_TOKEN.finditer(body))
            if not tokens:
                self.pragma_findings.append(Finding(
                    rule=PRAGMA_RULE, path=self.relpath, line=lineno,
                    message=f"unparseable analysis pragma {body!r} "
                            "(want allow-<rule>(reason))",
                    snippet=self.snippet_at(lineno),
                ))
                continue
            # A comment-only pragma line covers the next line; an inline
            # pragma covers its own line.
            target = lineno + 1 if comment_only else lineno
            for tok in tokens:
                name, reason = tok.group("name"), tok.group("reason").strip()
                if name not in known:
                    self.pragma_findings.append(Finding(
                        rule=PRAGMA_RULE, path=self.relpath, line=lineno,
                        message=f"pragma allow-{name} matches no registered "
                                f"rule (known: {sorted(known)})",
                        snippet=self.snippet_at(lineno),
                    ))
                    continue
                if not reason:
                    self.pragma_findings.append(Finding(
                        rule=PRAGMA_RULE, path=self.relpath, line=lineno,
                        message=f"pragma allow-{name} has no reason — a "
                                "suppression must say why it is safe",
                        snippet=self.snippet_at(lineno),
                    ))
                    continue
                self.pragmas.setdefault(target, {})[name] = reason

    def suppression_for(self, pragma: str, line: int) -> str | None:
        return self.pragmas.get(line, {}).get(pragma)

    # -- helpers rules share -------------------------------------------------
    def snippet_at(self, line: int) -> str:
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1].strip()
        return ""

    @property
    def parts(self) -> tuple:
        return PurePosixPath(self.relpath).parts

    @property
    def filename(self) -> str:
        return PurePosixPath(self.relpath).name


# ---------------------------------------------------------------------------
# rules
# ---------------------------------------------------------------------------
class Rule:
    """Base class: subclass, set the class attributes, implement check().

    ``check`` yields ``(line, message)`` pairs; the engine turns them
    into :class:`Finding` objects and applies pragma suppression.
    """

    name: str = ""
    pragma: str = ""             # suppression token: allow-<pragma>(reason)
    description: str = ""

    def applies_to(self, ctx: FileContext) -> bool:
        return True

    def check(self, ctx: FileContext) -> Iterable[tuple[int, str]]:
        raise NotImplementedError


#: global registry (name -> rule instance), filled by ``register``.
RULES: dict[str, Rule] = {}


def register(rule_cls: type[Rule]) -> type[Rule]:
    """Class decorator: instantiate and register a rule."""
    rule = rule_cls()
    if not rule.name or not rule.pragma:
        raise ValueError(f"rule {rule_cls.__name__} needs name and pragma")
    if rule.name in RULES:
        raise ValueError(f"duplicate rule name {rule.name!r}")
    RULES[rule.name] = rule
    return rule_cls


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------
def lint_source(
    source: str, relpath: str, rules: Iterable[Rule] | None = None
) -> list[Finding]:
    """Lint one file's source; returns every finding (suppressed ones
    included, marked)."""
    if rules is None:
        rules = list(RULES.values())
    try:
        ctx = FileContext(relpath, source)
    except SyntaxError as e:
        return [Finding(
            rule=PRAGMA_RULE, path=relpath, line=e.lineno or 1,
            message=f"file does not parse: {e.msg}", snippet="",
        )]
    findings = list(ctx.pragma_findings)
    for rule in rules:
        if not rule.applies_to(ctx):
            continue
        for line, message in rule.check(ctx):
            reason = ctx.suppression_for(rule.pragma, line)
            findings.append(Finding(
                rule=rule.name, path=relpath, line=line, message=message,
                snippet=ctx.snippet_at(line),
                suppressed=reason is not None,
                suppression_reason=reason,
            ))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def iter_python_files(paths: Iterable[str | Path], root: Path) -> Iterable[Path]:
    for p in paths:
        p = (root / p) if not Path(p).is_absolute() else Path(p)
        if p.is_dir():
            yield from sorted(p.rglob("*.py"))
        elif p.suffix == ".py":
            yield p


def lint_paths(
    paths: Iterable[str | Path],
    *,
    root: str | Path | None = None,
    rules: Iterable[Rule] | None = None,
) -> list[Finding]:
    """Lint every ``*.py`` under ``paths`` (files or directories)."""
    root = Path(root) if root is not None else Path.cwd()
    findings: list[Finding] = []
    for path in iter_python_files(paths, root):
        try:
            rel = path.resolve().relative_to(root.resolve())
        except ValueError:
            rel = path
        relpath = PurePosixPath(rel).as_posix()
        findings.extend(
            lint_source(path.read_text(), relpath, rules=rules)
        )
    return findings


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------
def load_baseline(path: str | Path) -> set[str]:
    path = Path(path)
    if not path.exists():
        return set()
    data = json.loads(path.read_text())
    return set(data.get("fingerprints", []))


def write_baseline(findings: Iterable[Finding], path: str | Path) -> int:
    """Persist the baseline; returns how many fingerprints it now holds.

    The baseline is a RATCHET: once a file exists, rewriting it can only
    *shrink* it (new = old ∩ current unsuppressed findings — fixed debt
    is pruned, new debt is refused, so ``--write-baseline`` can never
    launder a fresh violation).  Only when no baseline file exists yet
    does this seed it with the full current set.  Regenerate with
    ``python -m repro.analysis --write-baseline`` after fixing baselined
    debt, and commit the file."""
    path = Path(path)
    current = {f.fingerprint for f in findings if not f.suppressed}
    if path.exists():
        fps = sorted(load_baseline(path) & current)
    else:
        fps = sorted(current)
    path.write_text(json.dumps(
        {"version": 1, "fingerprints": fps}, indent=2,
    ) + "\n")
    return len(fps)


def stale_fingerprints(
    findings: Iterable[Finding], baseline: set[str]
) -> set[str]:
    """Baseline entries no current unsuppressed finding matches — fixed
    (or vanished) debt still recorded.  ``--check`` fails on these so
    the committed baseline only ever shrinks (run ``--write-baseline``
    to prune them)."""
    current = {f.fingerprint for f in findings if not f.suppressed}
    return baseline - current


def gate(findings: Iterable[Finding], baseline: set[str]) -> list[Finding]:
    """The findings ``--check`` fails on: unsuppressed and not baselined."""
    return [
        f for f in findings
        if not f.suppressed and f.fingerprint not in baseline
    ]


# ---------------------------------------------------------------------------
# reports
# ---------------------------------------------------------------------------
def render_text(
    findings: list[Finding], gating: list[Finding], baseline: set[str],
    stale: Iterable[str] = (),
) -> str:
    lines = [f.render() for f in findings if not f.suppressed]
    for fp in sorted(stale):
        lines.append(
            f"stale baseline entry {fp} — the finding is gone; prune "
            "with --write-baseline")
    n_sup = sum(f.suppressed for f in findings)
    n_base = sum(
        1 for f in findings
        if not f.suppressed and f.fingerprint in baseline
    )
    lines.append(
        f"{len(gating)} finding(s) ({n_sup} suppressed by pragma, "
        f"{n_base} baselined)"
    )
    return "\n".join(lines)


def render_json(
    findings: list[Finding], gating: list[Finding], baseline: set[str],
    stale: Iterable[str] = (),
) -> str:
    return json.dumps({
        "version": 1,
        "rules": {
            name: {"pragma": f"allow-{r.pragma}",
                   "description": r.description}
            for name, r in sorted(RULES.items())
        },
        "findings": [f.to_json() for f in findings],
        "gating": [f.fingerprint for f in gating],
        "baselined": sorted(
            f.fingerprint for f in findings
            if not f.suppressed and f.fingerprint in baseline
        ),
        "stale_baseline": sorted(stale),
        "counts": {
            "total": len(findings),
            "suppressed": sum(f.suppressed for f in findings),
            "gating": len(gating),
            "stale_baseline": len(set(stale)),
        },
    }, indent=2)


# helpers for rules -----------------------------------------------------------
def dotted_name(node: ast.AST) -> str | None:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def const_int(node: ast.AST, env: dict[str, int]) -> int | None:
    """Constant-fold an int expression over module-level int bindings."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return node.value
    if isinstance(node, ast.Name):
        return env.get(node.id)
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        v = const_int(node.operand, env)
        return None if v is None else -v
    if isinstance(node, ast.BinOp):
        left = const_int(node.left, env)
        right = const_int(node.right, env)
        if left is None or right is None:
            return None
        ops: dict[type, Callable[[int, int], int]] = {
            ast.Add: lambda a, b: a + b,
            ast.Sub: lambda a, b: a - b,
            ast.Mult: lambda a, b: a * b,
            ast.FloorDiv: lambda a, b: a // b,
            ast.LShift: lambda a, b: a << b,
            ast.RShift: lambda a, b: a >> b,
            ast.Pow: lambda a, b: a ** b,
        }
        fn = ops.get(type(node.op))
        return None if fn is None else fn(left, right)
    return None


def module_int_env(tree: ast.AST) -> dict[str, int]:
    """Module-level ``NAME = <int expr>`` bindings, const-folded in order."""
    env: dict[str, int] = {}
    for stmt in getattr(tree, "body", []):
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Name):
            v = const_int(stmt.value, env)
            if v is not None:
                env[stmt.targets[0].id] = v
    return env
