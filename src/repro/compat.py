"""Version-compat shims over the moving parts of the JAX API surface.

The repo targets a range of jax releases (see README "Supported JAX
versions"); three API moves matter to us:

  * ``shard_map``: ``jax.experimental.shard_map.shard_map`` (<= 0.5) ->
    ``jax.shard_map`` (>= 0.6).  The old entry point spells the
    replication-check kwarg ``check_rep``; the new one ``check_vma``.
    ``compat.shard_map`` accepts ``check_vma`` everywhere and translates.
  * ``jax.sharding.AxisType``: introduced with explicit-sharding meshes
    (jax >= 0.6).  Older ``jax.make_mesh`` has no ``axis_types`` kwarg at
    all, and every axis behaves as Auto — so on old jax we simply drop
    the argument.
  * ``jax.make_mesh`` itself predates ``axis_types``; ``compat.make_mesh``
    forwards it only when supported.

Everything in the repo imports these names from here, never from jax
directly, so a version bump is a one-file audit.
"""

from __future__ import annotations

import inspect

import jax

# ---------------------------------------------------------------------------
# shard_map
# ---------------------------------------------------------------------------
if hasattr(jax, "shard_map"):  # jax >= 0.6: top-level, takes check_vma
    _shard_map_impl = jax.shard_map
else:  # jax <= 0.5: experimental, takes check_rep
    from jax.experimental.shard_map import shard_map as _shard_map_impl

_SHARD_MAP_PARAMS = frozenset(
    inspect.signature(_shard_map_impl).parameters
)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kwargs):
    """``jax.shard_map`` with the modern (check_vma) spelling on any jax.

    ``check_vma`` maps onto the legacy ``check_rep`` kwarg when running on
    a jax whose shard_map predates the rename.  ``None`` means "library
    default" on either version.
    """
    if check_vma is not None:
        if "check_vma" in _SHARD_MAP_PARAMS:
            kwargs["check_vma"] = check_vma
        elif "check_rep" in _SHARD_MAP_PARAMS:
            kwargs["check_rep"] = check_vma
        # else: the kwarg vanished entirely; the check is advisory — drop it.
    return _shard_map_impl(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
    )


# ---------------------------------------------------------------------------
# Mesh construction / AxisType
# ---------------------------------------------------------------------------
#: ``jax.sharding.AxisType.Auto`` when the running jax has explicit-sharding
#: axis types, else ``None`` (old meshes are implicitly all-Auto).
AXIS_TYPE_AUTO = getattr(jax.sharding, "AxisType", None)
if AXIS_TYPE_AUTO is not None:
    AXIS_TYPE_AUTO = AXIS_TYPE_AUTO.Auto

_MAKE_MESH_PARAMS = frozenset(inspect.signature(jax.make_mesh).parameters)


def make_mesh(axis_shapes, axis_names, **kwargs):
    """``jax.make_mesh`` that tolerates jax versions without axis_types.

    On jax >= 0.6 every axis is created as AxisType.Auto (matching the old
    implicit behaviour) unless the caller passes ``axis_types`` explicitly;
    on older jax the kwarg is dropped because Auto is the only behaviour.
    """
    if "axis_types" in _MAKE_MESH_PARAMS:
        if "axis_types" not in kwargs and AXIS_TYPE_AUTO is not None:
            kwargs["axis_types"] = (AXIS_TYPE_AUTO,) * len(axis_names)
    else:
        kwargs.pop("axis_types", None)
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)
