"""Production meshes (TPU v5e): 16x16 per pod, pods stacked on a DCN axis.

A FUNCTION, not a module constant — importing this module must never touch
jax device state (smoke tests see 1 device; only dryrun forces 512).

Mesh construction goes through repro.compat.make_mesh so the axis_types
handling (jax.sharding.AxisType only exists on jax >= 0.6) stays in one
place.
"""

from __future__ import annotations

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(shape=None, axes=("data", "model")):
    """Mesh over whatever devices exist (tests / single host)."""
    n = len(jax.devices())
    if shape is None:
        shape = (1, n)
    return make_mesh(shape, axes)
