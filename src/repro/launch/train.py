"""End-to-end training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b \
      --steps 200 --batch 8 --seq 256 [--smoke] [--mesh host]

On the CPU container use --smoke (reduced config).  On a real fleet the
same entry point runs the full config under the production mesh: state and
batch shardings come from sharding/rules.py, the data stream is seekable,
checkpoints are atomic, and the loop restarts on failure (train/fault.py).
"""

from __future__ import annotations

import argparse
import logging

import jax

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.data import make_stream
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.sharding.rules import ShardingRules, sharding_context
from repro.train import (
    CheckpointManager, FaultInjector, Watchdog, init_state, make_optimizer,
    make_train_step, state_shardings, batch_shardings,
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", choices=("none", "host", "pod", "multipod"),
                    default="none")
    ap.add_argument("--fail-at", type=int, nargs="*", default=(),
                    help="inject failures at these steps (demo/testing)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    logging.basicConfig(level=logging.INFO, format="%(message)s")
    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    opt = make_optimizer(cfg, peak_lr=args.lr, warmup=max(args.steps // 20, 5),
                         total_steps=args.steps)
    stream = make_stream(cfg, args.batch, args.seq, args.seed)

    mesh = None
    if args.mesh == "host":
        mesh = make_host_mesh()
    elif args.mesh in ("pod", "multipod"):
        mesh = make_production_mesh(multi_pod=(args.mesh == "multipod"))

    step_fn = make_train_step(cfg, opt, num_microbatches=args.microbatches,
                              compress=args.compress_grads)

    def init_fn():
        return init_state(jax.random.PRNGKey(args.seed), cfg, opt,
                          compress=args.compress_grads)

    st_sh = None
    if mesh is not None:
        rules = ShardingRules()
        state_shape = jax.eval_shape(init_fn)
        st_sh = state_shardings(state_shape, mesh, rules)
        b_sh = batch_shardings(
            jax.eval_shape(lambda: stream.batch_at(0)), mesh, rules)
        ctx = sharding_context(mesh, rules)
        with ctx:
            jitted = jax.jit(step_fn, in_shardings=(st_sh, b_sh),
                             out_shardings=(st_sh, None),
                             donate_argnums=(0,))

        def sharded_step(state, batch):
            with sharding_context(mesh, rules):
                return jitted(state, batch)
        run_step = sharded_step
    else:
        run_step = jax.jit(step_fn, donate_argnums=(0,))

    ckpt = CheckpointManager(args.ckpt_dir, keep_last=3)
    state, history = run_with(init_fn, run_step, stream, ckpt, args, st_sh)
    print(f"done: step={int(state['step'])} "
          f"final loss={history[-1]['loss'] if history else float('nan'):.4f}")
    return state, history


def run_with(init_fn, step_fn, stream, ckpt, args, st_sh):
    from repro.train.fault import run_training
    return run_training(
        init_state_fn=init_fn,
        train_step=step_fn,
        stream=stream,
        ckpt=ckpt,
        num_steps=args.steps,
        ckpt_every=args.ckpt_every,
        state_shardings=st_sh,
        injector=FaultInjector(tuple(args.fail_at)) if args.fail_at else None,
        watchdog=Watchdog(),
    )


if __name__ == "__main__":
    main()
