"""Roofline terms from a compiled dry-run artifact.

Three terms per (arch x shape x mesh), per the assignment:

  compute    = HLO_FLOPs / (chips x 197e12 bf16 FLOP/s)
  memory     = HLO_bytes / (chips x 819e9 B/s HBM)
  collective = collective_bytes / (chips x 50e9 B/s per ICI link)

`compiled.cost_analysis()` yields per-device FLOPs/bytes (the partitioned
module).  collective_bytes is parsed from the compiled HLO text: for every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
we take the RESULT shape bytes and convert to per-device wire bytes with
ring formulas (all-reduce 2x, others 1x of the data each device handles).
The parse also returns a per-op-kind breakdown — the §Perf iterations are
driven by which collective dominates.
"""

from __future__ import annotations

import re
from collections import defaultdict

from repro.config import HW

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# `%x = TYPE opname(` — TYPE may be a tuple; capture up to the op name.
_OP_RE = re.compile(
    r"=\s+(?P<type>\(.*?\)|\S+?)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(",
)
_SHAPE_RE = re.compile(r"(?P<dt>[a-z]+\d*[a-z0-9]*)\[(?P<dims>[\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device wire bytes by collective kind (+ 'total')."""
    out: dict = defaultdict(int)
    counts: dict = defaultdict(int)
    for m in _OP_RE.finditer(hlo_text):
        op = m.group("op")
        nbytes = _shape_bytes(m.group("type"))
        # ring cost per device, relative to the result bytes R:
        #   all-reduce: 2R (reduce-scatter + all-gather phases)
        #   others:     1R (each element crosses links ~once per device)
        wire = 2 * nbytes if op == "all-reduce" else nbytes
        out[op] += wire
        counts[op] += 1
    out_d = dict(out)
    out_d["total"] = sum(out.values())
    out_d["counts"] = dict(counts)
    return out_d


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float) -> dict:
    """Seconds per step for each roofline term (per-chip quantities)."""
    t_compute = flops_per_dev / HW["peak_flops_bf16"]
    t_memory = bytes_per_dev / HW["hbm_bw"]
    t_coll = coll_bytes_per_dev / HW["ici_link_bw"]
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom.replace("_s", "")
    total = max(t_compute, t_memory, t_coll)
    terms["bound_s"] = total
    terms["roofline_fraction"] = (t_compute / total) if total > 0 else 0.0
    return terms


def model_flops(cfg, shape, kind: str) -> float:
    """6*N*D with N = active params, D = tokens processed this step."""
    n = cfg.active_param_count()
    if kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n * d
    if kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n * d              # forward only
    d = shape.global_batch * 1          # decode: one token per request
    return 2.0 * n * d


def summarize(result: dict) -> str:
    """One text row for EXPERIMENTS.md tables."""
    t = result["terms"]
    return (
        f"| {result['arch']} | {result['shape']} | {result['mesh']} "
        f"| {t['compute_s']*1e3:9.3f} | {t['memory_s']*1e3:9.3f} "
        f"| {t['collective_s']*1e3:9.3f} | {t['dominant']:10s} "
        f"| {result.get('useful_flops_ratio', 0):5.2f} "
        f"| {result['memory'].get('per_device_total_gb', -1):7.2f} |"
    )
