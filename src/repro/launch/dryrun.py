"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: 512 host
"devices" stand in for 2 pods x 256 v5e chips.  For each cell we lower
train_step (train shapes) or prefill/decode (serve shapes) with full-size
ShapeDtypeStructs (no allocation), compile under the production mesh, and
record memory_analysis / cost_analysis / collective bytes for §Roofline.

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""

# The VERY FIRST lines, before any jax import: 512 placeholder devices.
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS_EXTRA", "")
)

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.config import SHAPES, cell_is_runnable
from repro.configs import ARCH_IDS, get_config
from repro.launch import analysis
from repro.launch.mesh import make_production_mesh
from repro.models import api
from repro.sharding.rules import (
    ShardingRules, cache_shardings, param_shardings, sharding_context,
)
from repro.train import train_step as TS
from repro.train.serve_step import make_serve_fns


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStructs — never allocated)
# ---------------------------------------------------------------------------
def input_specs(cfg, shape, kind: str) -> dict:
    """Batch ShapeDtypeStructs for an (arch x shape) cell."""
    gb, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    bf16 = jnp.bfloat16
    if kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((gb, 1), i32)}
    batch = {}
    if cfg.family == "vlm":
        p = cfg.num_prefix_embeds
        batch["prefix_embeds"] = jax.ShapeDtypeStruct((gb, p, cfg.d_model),
                                                      bf16)
        batch["tokens"] = jax.ShapeDtypeStruct((gb, s - p), i32)
        if kind == "train":
            batch["labels"] = jax.ShapeDtypeStruct((gb, s - p), i32)
    elif cfg.family == "audio":
        batch["src_embeds"] = jax.ShapeDtypeStruct((gb, s, cfg.d_model), bf16)
        batch["tokens"] = jax.ShapeDtypeStruct((gb, s), i32)
        if kind == "train":
            batch["labels"] = jax.ShapeDtypeStruct((gb, s), i32)
    else:
        batch["tokens"] = jax.ShapeDtypeStruct((gb, s), i32)
        if kind == "train":
            batch["labels"] = jax.ShapeDtypeStruct((gb, s), i32)
    return batch


def _cache_specs(cfg, shape):
    gb, s = shape.global_batch, shape.seq_len
    kw = {"src_len": s} if cfg.family == "audio" else {}
    return jax.eval_shape(
        lambda: api.init_cache(cfg, gb, s, **kw))


# ---------------------------------------------------------------------------
# One compile + measurement
# ---------------------------------------------------------------------------
def _measure(cfg, shape, mesh, rules, kind, microbatches: int = 1) -> dict:
    """Lower + compile one variant; return cost/memory/collective record."""
    if kind != "train":
        # Inference layout: no optimizer state, so no FSDP — params are
        # sharded on the model axis only and replicated over DP (the
        # standard serving layout; per-layer weight all-gathers would
        # dominate decode otherwise — measured 10.8 s for scout).
        rules = dataclasses.replace(rules, fsdp_axes=())
    t0 = time.perf_counter()
    with mesh, sharding_context(mesh, rules):
        if kind == "train":
            opt = TS.make_optimizer(cfg)
            state_shape = jax.eval_shape(
                lambda: TS.init_state(jax.random.PRNGKey(0), cfg, opt))
            state_sh = TS.state_shardings(state_shape, mesh, rules)
            batch = input_specs(cfg, shape, "train")
            batch_sh = TS.batch_shardings(batch, mesh, rules)
            step = TS.make_train_step(cfg, opt,
                                      num_microbatches=microbatches)
            jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_shape, batch)
        else:
            params_shape = jax.eval_shape(
                lambda: api.init_params(jax.random.PRNGKey(0), cfg))
            p_sh = param_shardings(params_shape, mesh, rules)
            cache_shape = _cache_specs(cfg, shape)
            c_sh = cache_shardings(cache_shape, mesh, rules)
            prefill_fn, decode_fn = make_serve_fns(cfg)
            if kind == "prefill":
                batch = input_specs(cfg, shape, "prefill")
                batch_sh = TS.batch_shardings(batch, mesh, rules)
                jitted = jax.jit(prefill_fn,
                                 in_shardings=(p_sh, batch_sh, c_sh),
                                 out_shardings=(None, c_sh),
                                 donate_argnums=(2,))
                lowered = jitted.lower(params_shape, batch, cache_shape)
            else:  # decode
                toks = input_specs(cfg, shape, "decode")["tokens"]
                toks_sh = TS.batch_shardings({"t": toks}, mesh, rules)["t"]
                jitted = jax.jit(decode_fn,
                                 in_shardings=(p_sh, toks_sh, c_sh),
                                 out_shardings=(None, c_sh),
                                 donate_argnums=(2,))
                lowered = jitted.lower(params_shape, toks, cache_shape)

        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = analysis.collective_bytes(hlo)
    mem_rec = {}
    for field in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
        mem_rec[field] = int(getattr(mem, field, -1))
    live = (mem_rec["argument_size_in_bytes"]
            + mem_rec["temp_size_in_bytes"]
            - max(mem_rec["alias_size_in_bytes"], 0))
    mem_rec["per_device_total_gb"] = live / 2**30
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": coll,
        "memory": mem_rec,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "hlo_bytes": len(hlo),
    }


def _layer_scaled(cfg):
    """Two reduced-depth variants with the SAME shapes whose scanned
    segments scale linearly in num_layers.

    XLA's HloCostAnalysis counts a while-loop body once, ignoring trip
    count — so scanned-layer FLOPs/bytes/collectives are invisible in the
    full-depth compile at ANY depth.  The variants here are compiled
    UNROLLED (scan_layers=False): every layer's ops appear in the module
    and are fully counted.  f(L) is linear in L (fixed embed/logits cost
    + L x per-layer cost), so two unrolled compiles at La < Lb recover
    the slope exactly; the full-depth scanned compile still provides
    memory_analysis (allocations are not trip-count-blind).
    """
    if cfg.family == "hybrid":
        # keep tail length == num_layers % len(pattern) so f is linear
        tail = cfg.num_layers % len(cfg.block_pattern or ("r", "r", "a"))
        pat = len(cfg.block_pattern or ("r", "r", "a"))
        la, lb = 1 * pat + tail, 2 * pat + tail
    elif cfg.is_encoder_decoder:
        la, lb = 2, 4
    elif cfg.is_moe and cfg.first_k_dense:
        la, lb = cfg.first_k_dense + 1, cfg.first_k_dense + 2
    else:
        la, lb = 2, 4

    def mk(num):
        if cfg.is_encoder_decoder:
            return dataclasses.replace(
                cfg, num_layers=num, num_encoder_layers=num,
                num_decoder_layers=num, scan_layers=False)
        return dataclasses.replace(cfg, num_layers=num, scan_layers=False)
    return mk(la), la, mk(lb), lb


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             rules: ShardingRules = ShardingRules(), *,
             cfg_overrides: dict | None = None,
             microbatches: int = 1,
             verbose: bool = True) -> dict:
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    ok, why = cell_is_runnable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    chips = mesh.devices.size
    kind = shape.kind

    full = _measure(cfg, shape, mesh, rules, kind, microbatches)
    if mesh_kind == "multipod":
        # Multi-pod cells prove the pod axis shards (compile success +
        # per-device memory); the roofline table is scored single-pod per
        # the assignment, so the 2 extra unrolled cost compiles are
        # skipped here.  Terms below are trip-count-UNcorrected.
        flops_dev, bytes_dev = full["flops"], full["bytes"]
        coll_dev = full["coll"]["total"]
        coll_kinds = {k: v for k, v in full["coll"].items()
                      if k not in ("total", "counts")}
        la = lb = ma = mb = None
    else:
        cfg_a, la, cfg_b, lb = _layer_scaled(cfg)
        ma = _measure(cfg_a, shape, mesh, rules, kind, microbatches)
        mb = _measure(cfg_b, shape, mesh, rules, kind, microbatches)
    L = cfg.num_layers

    def extrap(fa, fb):
        slope = (fb - fa) / (lb - la)
        return max(fa + slope * (L - la), 0.0)

    if mesh_kind != "multipod":
        flops_dev = max(extrap(ma["flops"], mb["flops"]), full["flops"])
        bytes_dev = max(extrap(ma["bytes"], mb["bytes"]), full["bytes"])
        coll_dev = max(extrap(ma["coll"]["total"], mb["coll"]["total"]),
                       full["coll"]["total"])
        coll_kinds = {}
        for k in set(ma["coll"]) | set(mb["coll"]):
            if k in ("total", "counts"):
                continue
            coll_kinds[k] = extrap(ma["coll"].get(k, 0), mb["coll"].get(k, 0))

    terms = analysis.roofline_terms(flops_dev, bytes_dev, coll_dev)
    mflops = analysis.model_flops(cfg, shape, kind)
    hlo_flops_global = flops_dev * chips

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "status": "ok", "kind": kind, "chips": int(chips),
        "lower_s": full["lower_s"], "compile_s": full["compile_s"],
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll_dev,
        "collectives": coll_kinds,
        "collective_counts": full["coll"].get("counts", {}),
        "flops_uncorrected": full["flops"],
        "scan_correction": (
            {"la": la, "lb": lb, "flops_a": ma["flops"],
             "flops_b": mb["flops"]} if ma is not None
            else "none (multipod: compile+memory cell)"),
        "memory": full["memory"],
        "terms": terms,
        "model_flops_6nd": mflops,
        "useful_flops_ratio": (mflops / hlo_flops_global
                               if hlo_flops_global else 0.0),
        "hlo_bytes": full["hlo_bytes"],
    }
    if verbose:
        t = terms
        print(f"[{arch} x {shape_name} x {mesh_kind}] "
              f"compile={full['compile_s']:.1f}s "
              f"compute={t['compute_s']*1e3:.2f}ms "
              f"memory={t['memory_s']*1e3:.2f}ms "
              f"collective={t['collective_s']*1e3:.2f}ms "
              f"dominant={t['dominant']} "
              f"useful={result['useful_flops_ratio']:.2f} "
              f"mem/dev={full['memory']['per_device_total_gb']:.2f}GiB")
        print("  memory_analysis:", full["memory"])
        print("  cost_analysis: flops=%.3e bytes=%.3e coll=%.3e" %
              (flops_dev, bytes_dev, coll_dev))
    return result


# ---------------------------------------------------------------------------
# Sweep driver
# ---------------------------------------------------------------------------
def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("pod", "multipod", "both"),
                    default="pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="",
                    help="suffix for result filenames (perf variants)")
    ap.add_argument("--override", nargs="*", default=(),
                    help="ModelConfig overrides, e.g. remat=dots "
                         "flash_min_seq=4096 ssm_seq_parallel=true")
    ap.add_argument("--cache-layout", choices=("heads", "seq"),
                    default="heads")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    overrides = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        if v.lower() in ("true", "false"):
            overrides[k] = v.lower() == "true"
        else:
            try:
                overrides[k] = int(v)
            except ValueError:
                try:
                    overrides[k] = float(v)
                except ValueError:
                    overrides[k] = v
    rules = ShardingRules(decode_cache_layout=args.cache_layout)

    os.makedirs(args.out, exist_ok=True)
    meshes = ("pod", "multipod") if args.mesh == "both" else (args.mesh,)
    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                for m in meshes:
                    cells.append((a, s, m))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch/--shape required unless --all")
        cells = [(args.arch, args.shape, m) for m in meshes]

    failures = 0
    tag = f"__{args.tag}" if args.tag else ""
    for a, s, m in cells:
        path = os.path.join(args.out, f"{a}__{s}__{m}{tag}.json")
        if os.path.exists(path) and not args.force:
            print(f"[{a} x {s} x {m}] exists, skipping")
            continue
        try:
            res = run_cell(a, s, m, rules, cfg_overrides=overrides,
                           microbatches=args.microbatches)
            if overrides or args.cache_layout != "heads" or args.tag \
                    or args.microbatches != 1:
                res["variant"] = {"overrides": overrides,
                                  "cache_layout": args.cache_layout,
                                  "microbatches": args.microbatches,
                                  "tag": args.tag}
        except Exception as e:  # record the failure, keep sweeping
            failures += 1
            res = {"arch": a, "shape": s, "mesh": m, "status": "error",
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
            print(f"[{a} x {s} x {m}] FAILED: {res['error']}")
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
