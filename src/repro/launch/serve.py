"""Serving launcher: batched prefill + greedy decode loop.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
      --batch 4 --prompt-len 32 --gen 16

Demonstrates the full serving path (cache init -> prefill -> decode scan)
with the same family dispatch the dry-run lowers at production scale.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.models import api
from repro.train.serve_step import decode_loop, make_serve_fns


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    rng = jax.random.PRNGKey(args.seed)
    params = api.init_params(rng, cfg)
    max_len = args.prompt_len + args.gen

    batch = {"tokens": jax.random.randint(
        rng, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["prefix_embeds"] = 0.02 * jax.random.normal(
            rng, (args.batch, cfg.num_prefix_embeds, cfg.d_model)
        ).astype(jnp.bfloat16)
    if cfg.family == "audio":
        batch["src_embeds"] = 0.02 * jax.random.normal(
            rng, (args.batch, args.prompt_len, cfg.d_model)
        ).astype(jnp.bfloat16)

    kw = {"src_len": args.prompt_len} if cfg.family == "audio" else {}
    cache = api.init_cache(cfg, args.batch, max_len, **kw)
    prefill_fn, _ = make_serve_fns(cfg)

    t0 = time.perf_counter()
    first, cache = jax.jit(prefill_fn)(params, batch, cache)
    first.block_until_ready()
    t_prefill = time.perf_counter() - t0

    t0 = time.perf_counter()
    toks, cache = jax.jit(
        lambda p, f, c: decode_loop(p, f, c, cfg, args.gen)
    )(params, first, cache)
    toks.block_until_ready()
    t_decode = time.perf_counter() - t0

    print(f"arch={cfg.name} batch={args.batch} "
          f"prompt={args.prompt_len} gen={args.gen}")
    print(f"prefill: {t_prefill*1e3:.1f} ms "
          f"({args.batch*args.prompt_len/t_prefill:.0f} tok/s)")
    print(f"decode:  {t_decode*1e3:.1f} ms "
          f"({args.batch*args.gen/t_decode:.0f} tok/s)")
    print("sample continuations:", jax.device_get(toks)[:2].tolist())
    return toks


if __name__ == "__main__":
    main()
