"""Launchers: mesh, dry-run, roofline analysis, train/serve CLIs."""
