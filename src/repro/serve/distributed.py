"""`DistributedAnalyticsService`: the planner's replica x shard mesh
layout (core/engine.MeshLayout) run as a serving system — paper §4.6's
"4 GPUs behind a task queue" generalized to a mesh.

One `AnalyticsService` per frame-parallel **replica group**
(`core/distributed.replica_meshes` slices the mesh along
``replica_axis``); within each group the engine shards bins or row
strips over the group's submesh exactly as a single-service deployment
would over the whole mesh.  A group whose submesh is one device gets a
plain single-device engine (``engine_factory(None)``), which keeps the
PR 9 incremental video-delta path alive — mesh plans recompute whole.

On top of the per-group services this facade owns exactly three things:

  * **Consistent-hash routing with chain stickiness** — a frame ref is
    routed by a hash ring over the replica groups, EXCEPT when one of
    its recent predecessors (the ``predecessor`` chain PR 9 introduced)
    was already routed: then the frame follows its chain.  Incremental
    updates need the predecessor's H in the *local* cache, so a video
    chain that straddled two replicas would silently degrade every
    frame to a full recompute.  Routes are memoized (bounded LRU), so
    chains stay put for as long as the ring remembers them.
  * **Aggregate backpressure** — ``max_pending`` bounds the
    *total* outstanding submits across all replicas; a hot replica
    cannot hide behind idle ones.  Rejections raise the same
    ``ServiceOverloaded`` the single service does.
  * **Aggregate stats** — ``snapshot()`` sums the counters, recomputes
    the rates over the union, and keeps the per-replica snapshots under
    ``"replicas"`` (the load-balance view: routing skew shows up as
    per-replica request counts, chain pinning as one replica owning all
    the ``updated`` runs).

The per-replica HSource caches split one aggregate byte budget:
``cache_bytes`` is divided evenly across groups, so the deployment's
total cache residency is bounded no matter how traffic skews.
"""

from __future__ import annotations

import hashlib
import threading
import time
from collections import OrderedDict
from typing import Any, Callable, Iterable, Mapping

import numpy as np

from repro.serve.service import (
    AnalyticsService,
    ServiceOverloaded,
    _int_predecessor,
)


def _ring_hash(token: str) -> int:
    """Stable 64-bit point on the ring (blake2b — never Python's
    ``hash``, which is salted per process and would re-route every
    frame on restart)."""
    return int.from_bytes(
        hashlib.blake2b(token.encode(), digest_size=8).digest(), "big"
    )


class HashRing:
    """Consistent hashing over replica indices with virtual nodes.

    ``weight`` virtual nodes per replica smooth the load split; lookup
    is a binary search over the sorted ring.  Deterministic across
    processes and instances (the 8-device parity test relies on two
    independently built services routing identically)."""

    def __init__(self, num_replicas: int, weight: int = 64):
        if num_replicas < 1 or weight < 1:
            raise ValueError("num_replicas >= 1, weight >= 1")
        points = []
        for idx in range(num_replicas):
            for v in range(weight):
                points.append((_ring_hash(f"replica:{idx}:{v}"), idx))
        points.sort()
        self._points = np.asarray([p for p, _ in points], np.uint64)
        self._owners = [i for _, i in points]

    def lookup(self, frame_ref) -> int:
        h = _ring_hash(f"frame:{frame_ref!r}")
        pos = int(np.searchsorted(self._points, np.uint64(h), side="left"))
        return self._owners[pos % len(self._owners)]


class DistributedAnalyticsService:
    """Serve ``(frame_ref, query)`` traffic across replica groups.

    Args:
      engine_factory: ``submesh -> HistogramEngine`` — called once per
        replica group with that group's submesh (a ``jax.sharding.Mesh``
        over the non-replica axes), or ``None`` for a bare single-device
        group.  ``serve.sharded_engine_factory`` covers the common case.
      frames: frame resolver, shared by every replica (a mapping or a
        callable, as in ``AnalyticsService``).
      mesh: the full device mesh.  ``None`` (with ``num_replicas``) runs
        N single-device replica groups on the default device — the
        degenerate frame-parallel layout, also what the in-process unit
        tests exercise.
      replica_axis: the mesh axis replicated over frames; every other
        axis shards within the group.  An axis absent from the mesh
        means one group spanning the whole mesh.
      num_replicas: group count when ``mesh`` is None.
      cache_size: per-replica HSource LRU entries.
      cache_bytes: AGGREGATE byte budget, split evenly across groups.
      max_pending: AGGREGATE bound on outstanding submits.
      max_coalesce / predecessor: forwarded to every replica service;
        ``predecessor`` also drives chain-sticky routing here.
      ring_weight: virtual nodes per replica on the hash ring.
      chain_depth: how many predecessors the router walks looking for an
        already-routed chain member before falling back to the ring.
    """

    # Routing memo + aggregate backpressure counters are shared between
    # submit() callers and the replicas' worker threads (via the future
    # done-callbacks); the lock-discipline rule enforces the declaration.
    _LOCK_PROTECTED = ("_routes", "_inflight", "_rejected")

    def __init__(
        self,
        engine_factory: Callable,
        frames: Mapping | Callable,
        *,
        mesh=None,
        replica_axis: str = "data",
        num_replicas: int | None = None,
        cache_size: int = 8,
        cache_bytes: int | None = None,
        max_pending: int = 64,
        max_coalesce: int = 32,
        predecessor: Callable | None = None,
        ring_weight: int = 64,
        chain_depth: int = 8,
        max_routes: int = 4096,
    ):
        if mesh is not None and num_replicas is not None:
            raise ValueError("pass mesh or num_replicas, not both")
        if mesh is None:
            groups: list = [None] * (num_replicas or 1)
        else:
            from repro.core.distributed import replica_meshes

            groups = replica_meshes(mesh, replica_axis)
        if max_pending < 1:
            raise ValueError("max_pending must be >= 1")
        n = len(groups)
        per_bytes = None if cache_bytes is None else cache_bytes // n
        self._predecessor = (
            predecessor if predecessor is not None else _int_predecessor
        )
        self.replicas: list[AnalyticsService] = []
        for sub in groups:
            if sub is not None and _mesh_devices(sub) == 1:
                # A 1-device submesh plans exactly like no mesh but
                # disables the incremental path; hand the factory None
                # so single-device groups keep video-delta updates.
                sub = None
            self.replicas.append(
                AnalyticsService(
                    engine_factory(sub), frames,
                    cache_size=cache_size, cache_bytes=per_bytes,
                    max_pending=max_pending, max_coalesce=max_coalesce,
                    predecessor=predecessor,
                )
            )
        self.max_pending = max_pending
        self._ring = HashRing(n, weight=ring_weight)
        self._chain_depth = chain_depth
        self._max_routes = max_routes
        self._routes: OrderedDict = OrderedDict()
        self._lock = threading.Lock()
        self._inflight = 0
        self._rejected = 0
        self._started = False
        self._started_at = time.perf_counter()

    # -- routing ------------------------------------------------------------
    def replica_for(self, frame_ref) -> int:
        """The replica group ``frame_ref`` routes to (memoized).

        A ref whose recent predecessor chain already routed follows the
        chain — the locality PR 9's incremental updates need; otherwise
        the consistent-hash ring decides."""
        with self._lock:
            hit = self._routes.get(frame_ref)
            if hit is not None:
                self._routes.move_to_end(frame_ref)
                return hit
        idx = None
        cur = frame_ref
        for _ in range(self._chain_depth):
            try:
                prev = self._predecessor(cur)
            except Exception:
                prev = None
            if prev is None or prev == cur:
                break
            with self._lock:
                hit = self._routes.get(prev)
            if hit is not None:
                idx = hit
                break
            cur = prev
        if idx is None:
            idx = self._ring.lookup(frame_ref)
        with self._lock:
            self._routes[frame_ref] = idx
            self._routes.move_to_end(frame_ref)
            while len(self._routes) > self._max_routes:
                self._routes.popitem(last=False)
        return idx

    # -- synchronous batch driver -------------------------------------------
    def process(self, requests: Iterable[tuple]) -> list:
        """Route and answer ``(frame_ref, query)`` pairs; results in
        input order.  Groups are answered replica by replica (each
        replica coalesces its own share exactly like a standalone
        service), so results are bit-exact against a single-device
        service fed the same trace."""
        reqs = list(requests)
        buckets: OrderedDict = OrderedDict()
        for i, (ref, q) in enumerate(reqs):
            buckets.setdefault(self.replica_for(ref), []).append((i, ref, q))
        results: list = [None] * len(reqs)
        for idx, items in buckets.items():
            outs = self.replicas[idx].process(
                [(ref, q) for _, ref, q in items])
            for (i, _, _), out in zip(items, outs):
                results[i] = out
        return results

    # -- concurrent driver ---------------------------------------------------
    def start(self) -> "DistributedAnalyticsService":
        for r in self.replicas:
            r.start()
        self._started = True
        return self

    def submit(self, frame_ref, query, *, block: bool = False):
        """Enqueue one request on its routed replica; returns a Future.

        The admission check is AGGREGATE: total outstanding submits
        across every replica stay within ``max_pending`` (a hot replica
        cannot hide behind idle ones).  ``block=True`` still blocks on
        the replica's own queue once admitted."""
        if not self._started:
            raise RuntimeError(
                "service not started — use start() or "
                "`with DistributedAnalyticsService(...) as svc:`")
        with self._lock:
            if self._inflight >= self.max_pending:
                self._rejected += 1
                admitted = False
            else:
                self._inflight += 1
                admitted = True
        if not admitted:
            raise ServiceOverloaded(
                f"aggregate submit window full ({self.max_pending} "
                "pending across replicas)")
        idx = self.replica_for(frame_ref)
        try:
            fut = self.replicas[idx].submit(frame_ref, query, block=block)
        except BaseException:
            with self._lock:
                self._inflight -= 1
            raise
        fut.add_done_callback(self._retire)
        return fut

    def _retire(self, _fut) -> None:
        with self._lock:
            self._inflight -= 1

    def close(self) -> None:
        self._started = False
        for r in self.replicas:
            r.close()

    def __enter__(self) -> "DistributedAnalyticsService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # -- introspection -------------------------------------------------------
    def snapshot(self) -> dict:
        """Aggregate counters/rates + per-replica snapshots."""
        per = [r.stats.snapshot() for r in self.replicas]
        lat = np.sort(np.concatenate(
            [np.asarray(list(r.stats.latencies_s), np.float64)
             for r in self.replicas]
        )) if self.replicas else np.zeros(0)
        done = len(lat)
        wall = time.perf_counter() - self._started_at
        agg: dict = {
            k: sum(p[k] for p in per)
            for k in ("requests", "completed", "engine_runs", "cache_hits",
                      "coalesced", "updated", "recomputed")
        }
        with self._lock:
            rejected = self._rejected
            routes = len(self._routes)
        agg["rejected"] = rejected + sum(p["rejected"] for p in per)
        agg["hit"] = agg["cache_hits"]
        agg["cache_hit_rate"] = agg["cache_hits"] / max(agg["requests"], 1)
        agg["update_ratio"] = agg["updated"] / max(agg["engine_runs"], 1)
        agg["requests_per_s"] = done / wall if wall > 0 else 0.0
        agg["latency_p50_s"] = (
            float(lat[int(0.50 * (done - 1))]) if done else 0.0)
        agg["latency_p95_s"] = (
            float(lat[int(0.95 * (done - 1))]) if done else 0.0)
        agg["num_replicas"] = len(self.replicas)
        agg["routed_refs"] = routes
        agg["replicas"] = per
        return agg

    @property
    def cached_frames(self) -> tuple:
        """Per-replica cached frame refs (a tuple of tuples)."""
        return tuple(r.cached_frames for r in self.replicas)

    def clear_cache(self) -> None:
        for r in self.replicas:
            r.clear_cache()
        with self._lock:
            self._routes.clear()


def _mesh_devices(mesh) -> int:
    n = 1
    for v in dict(mesh.shape).values():
        n *= v
    return n


def sharded_engine_factory(num_bins: int, **engine_kwargs) -> Callable:
    """The ``engine_factory`` for the common case: each replica group
    gets a ``HistogramEngine`` sharded over its submesh (or a plain
    single-device engine for 1-device groups, which keeps the PR 9
    incremental path)."""
    from repro.core.engine import HistogramEngine

    def factory(submesh):
        return HistogramEngine(num_bins, mesh=submesh, **engine_kwargs)

    return factory
