"""Serving front-end over the plan/execute engine (ROADMAP north star:
heavy concurrent query traffic against the integral-histogram engine).

``AnalyticsService`` is the single-engine core; the mesh-scale layer
(``DistributedAnalyticsService``, serve/distributed.py) runs one of it
per replica group of the planner's ``MeshLayout``."""

from repro.serve.distributed import (
    DistributedAnalyticsService,
    HashRing,
    sharded_engine_factory,
)
from repro.serve.service import (
    AnalyticsService,
    ServiceOverloaded,
    ServiceStats,
)

__all__ = [
    "AnalyticsService",
    "DistributedAnalyticsService",
    "HashRing",
    "ServiceOverloaded",
    "ServiceStats",
    "sharded_engine_factory",
]
