"""Serving front-end over the plan/execute engine (ROADMAP north star:
heavy concurrent query traffic against the integral-histogram engine)."""

from repro.serve.service import (
    AnalyticsService,
    ServiceOverloaded,
    ServiceStats,
)

__all__ = ["AnalyticsService", "ServiceOverloaded", "ServiceStats"]
