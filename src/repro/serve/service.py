"""`AnalyticsService`: a serving facade over `HistogramEngine`.

The ROADMAP north star is a production system serving heavy query
traffic; the engine (core/engine.py) answers one request at a time.
This module adds the request-level scheduler on top:

  * **Same-frame coalescing** — requests landing on the same
    ``frame_ref`` are grouped and answered by ONE engine run.  The
    engine already unions the corner rows of a multi-query request into
    a single ``rows()`` pass (PR 4's ``prefetch_rows``), so k queries on
    one frame cost one H computation and one band stream, not k.
  * **HSource LRU cache** — computed representations are kept keyed by
    ``frame_ref`` (``cache_size`` frames, and optionally ``cache_bytes``
    of accumulated ``HSource.nbytes`` — evicted LRU-first when either
    bound is exceeded).  A hit on a dense or spilled source answers with
    no H computation at all; a hit on a *banded* source caches the
    replayable stream factory, so it skips planning and re-streams the
    bands for the hit's corner-row union — bounded memory (full H still
    never materializes), not zero kernel work.  ``stats.cache_hits``
    counts requests served from the cache either way; ``engine_runs``
    counts plan+compute dispatches through the engine.
  * **Video-delta chaining** — a miss on frame ``t+1`` whose
    *predecessor* frame ``t`` is still cached hands the pair to the
    engine (``run(..., prev=(frame_t, source_t))``): for low-motion
    streams the engine *updates* the cached H in place of a full
    recompute (core/delta.py), bit-exactly.  The chain is keyed by
    ``predecessor`` (default: integer refs decrement, so a store indexed
    by frame number chains for free).  ``stats.updated`` vs
    ``stats.recomputed`` splits the engine runs by which path ran.
  * **Backpressure** — the submit queue is bounded
    (``max_pending``); a full queue rejects with ``ServiceOverloaded``
    instead of growing without bound (Ehsan et al.'s
    resource-constrained serving posture: fail loudly, never thrash).
  * **Stats** — per-request latency (p50/p95), throughput,
    cache hit rate, coalescing ratio, engine-run count
    (``service.stats.snapshot()``) — what benchmarks/bench_serve.py
    reports.

Two drivers share all of that logic:

  * ``process(requests)`` — synchronous batch mode: coalesce + answer a
    list of ``(frame_ref, query)`` pairs in submission order
    (deterministic; what the tests pin down).
  * ``submit(frame_ref, query) -> Future`` — concurrent mode: a worker
    thread drains the queue greedily, so whatever accumulated since the
    last drain coalesces naturally under load (the adaptive-batching
    effect of Koppaka et al., here at the request level: the batch grows
    exactly when the service is behind).
"""

from __future__ import annotations

import collections
import dataclasses
import queue
import threading
import time
from concurrent.futures import Future
from typing import Any, Callable, Iterable, Mapping

import numpy as np


class ServiceOverloaded(RuntimeError):
    """Submit queue is full (``max_pending``) — shed load upstream."""


@dataclasses.dataclass
class ServiceStats:
    """Counters + latency samples; ``snapshot()`` derives the rates."""

    requests: int = 0
    engine_runs: int = 0            # H computations (cache misses)
    cache_hits: int = 0             # requests answered from the LRU
    coalesced: int = 0              # requests that shared another's run
    rejected: int = 0               # backpressure rejections
    updated: int = 0                # engine runs via incremental update
    recomputed: int = 0             # engine runs via full recompute
    latencies_s: list = dataclasses.field(default_factory=list)
    started_at: float = dataclasses.field(default_factory=time.perf_counter)

    def observe(self, latency_s: float) -> None:
        self.latencies_s.append(latency_s)

    def snapshot(self) -> dict:
        lat = np.sort(np.asarray(self.latencies_s, np.float64))
        wall = time.perf_counter() - self.started_at
        done = len(lat)
        return {
            "requests": self.requests,
            "completed": done,
            "engine_runs": self.engine_runs,
            "cache_hits": self.cache_hits,
            "cache_hit_rate": self.cache_hits / max(self.requests, 1),
            "coalesced": self.coalesced,
            "rejected": self.rejected,
            # engine-run split under video-delta chaining ("hit" is the
            # third outcome: answered with no engine run at all)
            "updated": self.updated,
            "recomputed": self.recomputed,
            "hit": self.cache_hits,
            "update_ratio": self.updated / max(self.engine_runs, 1),
            "requests_per_s": done / wall if wall > 0 else 0.0,
            "latency_p50_s": float(lat[int(0.50 * (done - 1))]) if done else 0.0,
            "latency_p95_s": float(lat[int(0.95 * (done - 1))]) if done else 0.0,
        }


def _int_predecessor(frame_ref):
    """Default frame-chain resolver: integer refs decrement (frame ``t``
    follows ``t - 1``); anything else has no known predecessor."""
    if isinstance(frame_ref, bool):
        return None
    if isinstance(frame_ref, (int, np.integer)):
        return frame_ref - 1
    return None


@dataclasses.dataclass
class _Pending:
    """One queued request (threaded mode carries a Future)."""

    frame_ref: Any
    query: Any
    t_submit: float
    future: Future | None = None


class AnalyticsService:
    """Serve ``(frame_ref, query)`` requests against one engine.

    Two requests on the same frame coalesce into ONE engine run (and,
    when their corner-row union is small, the planner fuses them into
    the scan so H is never stored):

    >>> import numpy as np
    >>> from repro.core.engine import HistogramEngine, RegionQuery
    >>> frames = {"f0": np.arange(64, dtype=np.uint8).reshape(8, 8) % 4}
    >>> svc = AnalyticsService(
    ...     HistogramEngine(num_bins=4, value_range=4, backend="jnp"),
    ...     frames)
    >>> out = svc.process([("f0", RegionQuery([[0, 0, 7, 7]])),
    ...                    ("f0", RegionQuery([[0, 0, 3, 7]]))])
    >>> [float(v) for v in np.asarray(out[0]).ravel()]
    [16.0, 16.0, 16.0, 16.0]
    >>> svc.stats.engine_runs       # both queries rode one engine run
    1
    >>> svc._engine.last_plan.representation
    'fused'

    Args:
      engine: a ``HistogramEngine`` — plans/computes/queries; the
        service never touches representations directly.
      frames: ``frame_ref -> frame`` resolver — a mapping (frame store)
        or a callable (decoder / fetcher).  Only cache *misses* resolve.
      cache_size: HSource LRU entries kept (0 disables caching).
      cache_bytes: optional bound on the cache's accumulated
        ``HSource.nbytes`` (planner size estimates for banded-factory
        entries); LRU entries are evicted until the total fits.
      max_pending: bound on queued submits before ``ServiceOverloaded``.
      max_coalesce: most requests the worker drains into one batch.
      predecessor: ``frame_ref -> prev_ref | None`` — names the frame a
        ref follows, seeding the engine's incremental video-delta path
        when the predecessor's H is still cached.  Defaults to integer
        decrement; pass ``lambda ref: None`` to disable chaining.
    """

    # Shared mutable state and the methods that mutate it: writes to
    # these attributes outside `with self._lock:` race the worker thread
    # against process()/submit() callers (the close()/drain race class).
    # The lock-discipline lint rule enforces this declaration.
    _LOCK_PROTECTED = ("_cache", "stats")
    _LOCK_PROTECTED_MUTATORS = ("observe",)

    def __init__(
        self,
        engine,
        frames: Mapping | Callable,
        *,
        cache_size: int = 8,
        cache_bytes: int | None = None,
        max_pending: int = 64,
        max_coalesce: int = 32,
        predecessor: Callable | None = None,
    ):
        if cache_size < 0 or max_pending < 1 or max_coalesce < 1:
            raise ValueError(
                "cache_size >= 0, max_pending >= 1, max_coalesce >= 1"
            )
        if cache_bytes is not None and cache_bytes < 0:
            raise ValueError("cache_bytes must be >= 0")
        self._engine = engine
        self._resolve = (
            frames.__getitem__ if hasattr(frames, "__getitem__") else frames
        )
        self.cache_size = cache_size
        self.cache_bytes = cache_bytes
        self.max_coalesce = max_coalesce
        self._predecessor = (
            predecessor if predecessor is not None else _int_predecessor
        )
        self._cache: collections.OrderedDict = collections.OrderedDict()
        self._lock = threading.Lock()
        self.stats = ServiceStats()
        self._queue: queue.Queue = queue.Queue(maxsize=max_pending)
        self._worker: threading.Thread | None = None
        self._closing = False

    # -- the one serving core (both drivers call this) ----------------------
    def _evict_locked(self) -> None:
        """LRU eviction under both bounds; caller holds ``self._lock``
        (hence the pragmas — the rule cannot see a caller's lock)."""
        while len(self._cache) > self.cache_size:
            # analysis: allow-lock-discipline(caller holds self._lock)
            self._cache.popitem(last=False)
        if self.cache_bytes is not None:
            total = sum(
                getattr(s, "nbytes", 0) for s in self._cache.values())
            while self._cache and total > self.cache_bytes:
                # analysis: allow-lock-discipline(caller holds self._lock)
                _, dropped = self._cache.popitem(last=False)
                total -= getattr(dropped, "nbytes", 0)

    def _source_for(self, frame_ref, queries):
        """(source, results-or-None, hit): the cached HSource, or one
        engine run answering ``queries`` directly on a miss."""
        with self._lock:
            cached = self._cache.get(frame_ref)
            if cached is not None:
                self._cache.move_to_end(frame_ref)
            prev_ref = prev_src = None
            if cached is None:
                try:
                    prev_ref = self._predecessor(frame_ref)
                except Exception:
                    prev_ref = None
                if prev_ref is not None:
                    prev_src = self._cache.get(prev_ref)
        if cached is not None:
            return cached, None, True
        frame = self._resolve(frame_ref)
        prev = None
        if prev_src is not None:
            try:
                prev = (self._resolve(prev_ref), prev_src)
            except Exception:  # predecessor frame gone from the store
                prev = None
        # ONE compute, k queries — updated in place when the planner
        # takes the incremental path off the cached predecessor H
        out = self._engine.run(frame, queries, prev=prev)
        incremental = getattr(out.plan, "incremental", False)
        with self._lock:
            self.stats.engine_runs += 1
            if incremental:
                self.stats.updated += 1
            else:
                self.stats.recomputed += 1
            if self.cache_size:
                self._cache[frame_ref] = out.source
                self._cache.move_to_end(frame_ref)
                self._evict_locked()
        return out.source, out.results, False

    def _answer_group(self, frame_ref, group: list[_Pending]) -> list:
        """Answer every request of one frame group; returns results in
        group order."""
        from repro.core.engine import prefetch_rows
        from repro.core.hsource import BandedH, MissingRowsError

        queries = [p.query for p in group]
        source, results, hit = self._source_for(frame_ref, queries)
        if results is None:
            # Cache hit: apply the queries to the cached source, sharing
            # one corner-row prefetch when the source streams (the same
            # union the engine does for a fresh multi-query run).
            target = source
            if len(queries) > 1 and isinstance(source, BandedH):
                target = prefetch_rows(source, queries) or source
            try:
                results = [q.apply(target) for q in queries]
            except MissingRowsError:
                # A fused cache entry holds ONLY its own request's corner
                # rows; a hit that reads outside that set has no H to
                # fall back on.  Re-run the engine (it re-plans with the
                # new row union — fused again if still small) and refresh
                # the cache.  Not a cache hit.
                hit = False
                out = self._engine.run(self._resolve(frame_ref), queries)
                results = out.results
                with self._lock:
                    self.stats.engine_runs += 1
                    self.stats.recomputed += 1
                    if self.cache_size:
                        self._cache[frame_ref] = out.source
                        self._cache.move_to_end(frame_ref)
                        self._evict_locked()
        with self._lock:
            self.stats.requests += len(group)
            if hit:
                self.stats.cache_hits += len(group)
            self.stats.coalesced += len(group) - 1
        return results

    def _process_batch(self, batch: list[_Pending]) -> list:
        """Coalesce a drained batch by frame_ref and answer every group.
        Results come back in submission order."""
        groups: collections.OrderedDict = collections.OrderedDict()
        for i, p in enumerate(batch):
            groups.setdefault(p.frame_ref, []).append((i, p))
        results: list = [None] * len(batch)
        for frame_ref, members in groups.items():
            group = [p for _, p in members]
            outs = self._answer_group(frame_ref, group)
            done = time.perf_counter()
            for (i, p), out in zip(members, outs):
                results[i] = out
                with self._lock:
                    self.stats.observe(done - p.t_submit)
                if p.future is not None:
                    p.future.set_result(out)
        return results

    # -- synchronous batch driver -------------------------------------------
    def process(self, requests: Iterable[tuple]) -> list:
        """Answer ``(frame_ref, query)`` pairs; one engine run per
        distinct uncached frame in the batch, results in input order."""
        now = time.perf_counter()
        batch = [_Pending(ref, q, now) for ref, q in requests]
        return self._process_batch(batch)

    # -- concurrent driver ---------------------------------------------------
    def start(self) -> "AnalyticsService":
        if self._worker is None:
            self._closing = False
            self._worker = threading.Thread(
                target=self._drain_loop, name="analytics-service", daemon=True
            )
            self._worker.start()
        return self

    def submit(self, frame_ref, query, *, block: bool = False) -> Future:
        """Enqueue one request; returns a Future.  A full queue raises
        ``ServiceOverloaded`` (``block=True`` waits instead — caller-side
        backpressure)."""
        if self._worker is None:
            raise RuntimeError("service not started — use start() or "
                               "`with AnalyticsService(...) as svc:`")
        p = _Pending(frame_ref, query, time.perf_counter(), Future())
        try:
            self._queue.put(p, block=block)
        except queue.Full:
            with self._lock:
                self.stats.rejected += 1
            raise ServiceOverloaded(
                f"submit queue full ({self._queue.maxsize} pending)"
            ) from None
        return p.future

    def _drain_loop(self) -> None:
        while True:
            try:
                first = self._queue.get(timeout=0.05)
            except queue.Empty:
                if self._closing:
                    return
                continue
            batch = [first]
            # greedy drain: whatever accumulated while the last batch
            # computed coalesces into this one
            while len(batch) < self.max_coalesce:
                try:
                    batch.append(self._queue.get_nowait())
                except queue.Empty:
                    break
            try:
                self._process_batch(batch)
            except Exception as e:  # fail the batch's futures, keep serving
                for p in batch:
                    if p.future is not None and not p.future.done():
                        p.future.set_exception(e)

    def close(self) -> None:
        """Drain outstanding requests, then stop the worker.

        A submit racing with close can land on the queue after the
        worker's final drain; those futures are failed here rather than
        left to hang forever."""
        if self._worker is not None:
            self._closing = True
            self._worker.join()
            self._worker = None
        while True:
            try:
                p = self._queue.get_nowait()
            except queue.Empty:
                break
            if p.future is not None and not p.future.done():
                p.future.set_exception(
                    RuntimeError("service closed before request ran"))

    def __enter__(self) -> "AnalyticsService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # -- introspection -------------------------------------------------------
    @property
    def cached_frames(self) -> tuple:
        with self._lock:
            return tuple(self._cache)

    def clear_cache(self) -> None:
        """Drop every cached HSource (benchmarks call this after their
        compile warm-up so measured hit rates start cold)."""
        with self._lock:
            self._cache.clear()
