"""Configuration system: model configs, input-shape cells, CLI plumbing.

Every assigned architecture is a ``ModelConfig`` in repro/configs/<id>.py;
the four assigned input shapes are ``ShapeConfig`` instances below.  A
(arch x shape) pair is a dry-run/benchmark *cell*.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | encdec | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 128
    d_ff: int = 0
    vocab_size: int = 32000

    # attention options
    qkv_bias: bool = False
    qk_norm: bool = False
    sliding_window: Optional[int] = None   # local attention window (tokens)
    rope_theta: float = 10000.0
    logits_softcap: float = 0.0

    # MoE
    num_experts: int = 0
    num_experts_per_token: int = 0
    expert_d_ff: int = 0
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    first_k_dense: int = 0            # leading dense (non-MoE) layers
    router_aux_coef: float = 0.01

    # SSM (Mamba-2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_groups: int = 1
    conv_kernel: int = 4
    # sequence parallelism for the SSD scan: shard the sequence over the
    # model axis; chunk-boundary states propagate via a ppermute carry
    # wavefront (the paper's tiled-scan carry at ICI scale — §Perf C)
    ssm_seq_parallel: bool = False

    # hybrid (Griffin / RecurrentGemma)
    block_pattern: tuple = ()         # e.g. ("rec", "rec", "attn")
    rnn_width: int = 0
    rnn_scan_chunk: int = 256
    # sequence parallelism for the RG-LRU scan (same ppermute carry
    # wavefront as ssm_seq_parallel; local-attn layers stay as-is)
    rnn_seq_parallel: bool = False

    # encoder-decoder
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    num_decoder_layers: int = 0

    # multimodal stub frontend (assignment: precomputed patch/frame embeds)
    modality: Optional[str] = None    # "vision" | "audio"
    num_prefix_embeds: int = 0        # patches/frames occupying prefix positions

    # numerics / layout
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    use_layer_norm: bool = False      # LayerNorm (enc-dec) vs RMSNorm
    tie_embeddings: bool = False
    scale_embeddings: bool = False
    remat: str = "full"               # "none" | "dots" | "full"
    scan_layers: bool = True
    attn_block_kv: int = 1024         # flash/chunked attention KV block
    flash_min_seq: int = 8192         # use chunked attention at/above this

    # training defaults
    optimizer: str = "adamw"          # "adamw" | "adafactor"

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 2048 so the unembed TP shard
        is lane-aligned on every mesh (param shapes use this; the loss
        masks the padding; 6ND uses the exact vocab_size)."""
        return -(-self.vocab_size // 2048) * 2048

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid-with-local-attn only)."""
        return self.family in ("ssm", "hybrid")

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    def param_count(self) -> int:
        """Approximate total parameter count N (for 6ND model-FLOPs)."""
        d, v = self.d_model, self.vocab_size
        embed = v * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            d_in = self.ssm_expand * d
            nheads = d_in // self.ssm_head_dim
            per = (
                d * (2 * d_in + 2 * self.ssm_groups * self.ssm_state + nheads)
                + d_in * d + self.conv_kernel * (d_in + 2 * self.ssm_groups * self.ssm_state)
            )
            return embed + self.num_layers * per
        hd, hq, hkv = self.head_dim, self.num_heads, self.num_kv_heads
        attn = d * hd * (hq + 2 * hkv) + hq * hd * d
        if self.is_moe:
            ff = 3 * d * self.expert_d_ff * (
                self.num_experts + self.num_shared_experts
            ) + d * self.num_experts
        else:
            ff = 3 * d * self.d_ff
        if self.family == "hybrid":
            # mix of recurrent and attention mixers, plus MLPs
            n_attn = sum(1 for b in self._pattern() if b == "attn")
            n_rec = self.num_layers - n_attn
            w = self.rnn_width
            rec = d * w * 2 + w * d + 3 * w  # branches + out + gates/conv approx
            return embed + n_attn * (attn + 3 * d * self.d_ff) + n_rec * (rec + 3 * d * self.d_ff)
        layers = self.num_layers * (attn + ff)
        if self.is_encoder_decoder:
            layers = (self.num_encoder_layers + self.num_decoder_layers) * (attn + ff)
            layers += self.num_decoder_layers * attn  # cross-attention
        return embed + layers

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k + shared experts only)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        total = self.param_count()
        all_ff = 3 * d * self.expert_d_ff * self.num_experts * self.num_layers
        active_ff = (
            3 * d * self.expert_d_ff * self.num_experts_per_token * self.num_layers
        )
        return total - all_ff + active_ff

    def _pattern(self) -> tuple:
        if not self.block_pattern:
            return ()
        reps = -(-self.num_layers // len(self.block_pattern))
        return (self.block_pattern * reps)[: self.num_layers]


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                          # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def cell_is_runnable(model: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not model.sub_quadratic:
        return False, (
            "skipped: pure full-attention architecture has no sub-quadratic "
            "path for 512k context (DESIGN.md §Arch-applicability)"
        )
    return True, ""


# v5e hardware constants for the roofline analysis (assignment-specified).
HW = {
    "peak_flops_bf16": 197e12,   # FLOP/s per chip
    "hbm_bw": 819e9,             # bytes/s per chip
    "ici_link_bw": 50e9,         # bytes/s per link (conservative per-link figure)
    "hbm_bytes": 16 * 1024**3,   # v5e HBM capacity
}
