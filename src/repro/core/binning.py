"""Binning function Q(I, b) of the integral histogram (paper Eq. 1).

Q(I(r, c), b) evaluates to 1 iff pixel value I(r, c) falls in bin b.  We
support uint8-style integer images (values in [0, value_range)) and float
images in [0, 1).  ``bin_indices`` maps each pixel to its bin id; the
one-hot expansion (the b-fold data blow-up the paper's init kernel pays a
full memory pass for) is either materialized (`one_hot_bins`, used by the
oracle and the generic scan methods) or fused into the Pallas kernels
(kernels/wf_tis.py) where it never touches HBM.
"""

from __future__ import annotations

import jax.numpy as jnp

# Pixels mapped to this sentinel never match any bin: padding contributes 0.
PAD_BIN: int = -1


def bin_indices(
    image: jnp.ndarray, num_bins: int, value_range: int | None = 256
) -> jnp.ndarray:
    """Map pixel values to integer bin ids in [0, num_bins).

    Integer images are assumed to lie in [0, value_range); float images in
    [0, 1).  Out-of-range values are clipped into the valid bin range, which
    matches the saturating behaviour of the paper's CPU reference.

    ``value_range=None`` means the input already holds bin indices (int32,
    PAD_BIN sentinel allowed) — used by the distributed bin-sharded path,
    where each shard re-bases global indices into its local bin range.
    """
    if value_range is None:
        return image.astype(jnp.int32)
    if jnp.issubdtype(image.dtype, jnp.floating):
        idx = jnp.floor(image * num_bins).astype(jnp.int32)
    else:
        idx = (image.astype(jnp.int32) * num_bins) // value_range
    return jnp.clip(idx, 0, num_bins - 1)


def one_hot_bins(idx: jnp.ndarray, num_bins: int, dtype=jnp.float32) -> jnp.ndarray:
    """Materialized Q: (..., h, w) int32 -> (..., b, h, w) {0,1}.

    The bin axis is inserted just before the two spatial axes, so a single
    frame maps (h, w) -> (b, h, w) and a frame stack maps
    (n, h, w) -> (n, b, h, w).

    fp32 is exact for counts < 2**24 — the largest supported image plane
    (8k x 8k = 2**26) is handled by the fp64-accumulation flag in ref.py or
    by int32 accumulation; for every benchmarked shape fp32 is exact.
    """
    b = jnp.arange(num_bins, dtype=jnp.int32)
    return (idx[..., None, :, :] == b[:, None, None]).astype(dtype)
