"""Histogram similarity/distance metrics used by the analytics layers.

All metrics broadcast over leading axes: (..., b) vs (b,) -> (...).
Similarities (higher = better): intersection, bhattacharyya.
Distances (lower = better): chi2, l1, l2.
"""

from __future__ import annotations

import jax.numpy as jnp

_EPS = 1e-8


def normalize(h: jnp.ndarray) -> jnp.ndarray:
    return h / (jnp.sum(h, axis=-1, keepdims=True) + _EPS)


def intersection(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Swain-Ballard histogram intersection on normalized histograms."""
    return jnp.sum(jnp.minimum(normalize(a), normalize(b)), axis=-1)


def bhattacharyya(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Bhattacharyya coefficient (similarity in [0, 1]).

    sqrt(a) * sqrt(b) instead of sqrt(a * b + eps): an eps inside the
    sqrt adds ~sqrt(eps) per empty bin, pushing identical histograms
    above 1 and disjoint ones above 0 (at 128 bins: 1.0127 and 0.0128)."""
    return jnp.sum(jnp.sqrt(normalize(a)) * jnp.sqrt(normalize(b)), axis=-1)


def chi2(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    an, bn = normalize(a), normalize(b)
    return 0.5 * jnp.sum((an - bn) ** 2 / (an + bn + _EPS), axis=-1)


def l1(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(jnp.abs(normalize(a) - normalize(b)), axis=-1)


def l2(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return jnp.sqrt(jnp.sum((normalize(a) - normalize(b)) ** 2, axis=-1))


SIMILARITIES = {"intersection": intersection, "bhattacharyya": bhattacharyya}
DISTANCES = {"chi2": chi2, "l1": l1, "l2": l2}
