"""One H-representation protocol for every way the repo holds an
integral histogram.

PRs 1-3 grew four representations of the same mathematical object — a
dense ``jax.Array`` H, a streamed band sequence (core/bands.py), a
host-spilled ``SpilledIH`` under a storage policy, and a mesh-sharded H
(core/distributed.py) — each with its own forked analytics entry points.
Eq. 2 only ever reads corner *rows* of H, so a single protocol suffices:

    class HSource:
        num_bins / height / width / lead     # metadata
        exact_region_bound                   # storage-policy count bound
        rows(row_ids) -> (..., b, k, w)      # host array, storage dtype
        dense() -> (..., b, h, w)            # assemble (when it fits)

Every analytics function (``region_histogram``,
``sliding_window_histograms``, ``likelihood_map``,
``multi_scale_search``) has ONE generic implementation against
``rows()`` — a rect touches two rows, a sliding-window field touches two
strided row lattices, and a multi-scale search touches the union of its
scales' lattices in a single pass.  Representations override only where
a genuinely faster path exists (dense strided slices, bin-sharded
shard_map queries); results are bit-exact either way because all H
arithmetic is integer-valued (fp32 below 2**24, modular for the integer
storage policies).

``rows()``/``dense()`` return **host** (numpy) arrays by design: on
jax 0.4.37 ``jnp.concatenate`` over row-sharded device bands silently
mis-assembles (see CHANGES.md, PR 3), so cross-band and cross-shard
assembly always goes through ``np.asarray`` — regression-tested in
tests/test_distributed.py.
"""

from __future__ import annotations

import abc
import functools
import itertools
import warnings

import jax.numpy as jnp
import numpy as np

from repro.core import region_query as rq


class MissingRowsError(KeyError):
    """A row-restricted source was asked for rows it does not hold.

    Raised by :class:`PrefetchedRowsH` (engine prefetch missed a query's
    rows — a caller bug) and :class:`FusedRowsH` (a fused result holds
    ONLY its request's corner rows; asking for more means the request
    changed and the engine must recompute — ``AnalyticsService`` catches
    exactly this to fall back from a fused cache hit)."""


class HSource(abc.ABC):
    """Corner-row access + metadata over any integral-histogram holder."""

    # -- metadata: concrete classes provide these as attributes, dataclass
    # fields (SpilledIH), or properties -------------------------------------
    num_bins: int
    height: int
    width: int
    lead: tuple      # leading frame axes of the H stack (() for a frame)

    @property
    def exact_region_bound(self) -> int | None:
        """Largest region pixel count a query is guaranteed exact for, or
        ``None`` when unbounded (fp32 sources are bounded upstream by the
        2**24 compute-exactness validation)."""
        return None

    @property
    def nbytes(self) -> int:
        """Size estimate for cache accounting (``AnalyticsService``'s
        byte-aware eviction).  The default is the planner's estimate —
        the full fp32 H footprint — which is exact for a materialized
        dense H and deliberately conservative for streamed/factory
        sources (what a replay can transiently pin); representations
        with a real resident footprint (SpilledIH, FusedRowsH)
        override it."""
        nlead = int(np.prod(self.lead, dtype=np.int64) or 1)
        return 4 * nlead * self.num_bins * self.height * self.width

    # -- the one representation primitive -----------------------------------
    @abc.abstractmethod
    def rows(self, row_ids) -> np.ndarray:
        """Full-frame H restricted to ``row_ids`` (sorted, ascending).

        Returns a host array (..., b, len(row_ids), w) in the source's
        storage dtype (integer policies keep their modular values)."""

    def dense(self):
        """Materialize (..., b, h, w) as fp32 — small frames only."""
        return jnp.asarray(
            self.rows(np.arange(self.height)).astype(np.float32)
        )

    # -- unified analytics (Eq. 2 against rows()) ---------------------------
    def _check_region_bound(self, max_area: int, what: str = "region") -> None:
        bound = self.exact_region_bound
        if bound is not None and max_area > bound:
            raise ValueError(
                f"{what} of {max_area} pixels exceeds the {self.storage} "
                f"storage policy's exact-count bound {bound}; spill with a "
                "wider policy"
            )

    def region_histogram(self, rects) -> jnp.ndarray:
        """``region_query.region_histogram`` semantics; returns fp32."""
        rects = np.asarray(rects)
        area = (rects[..., 2] - rects[..., 0] + 1) * (
            rects[..., 3] - rects[..., 1] + 1
        )
        self._check_region_bound(int(np.max(area)))
        needed = rq.corner_rows(rects)
        Hc = self.rows(needed)
        out = rq.compressed_region_histogram(
            jnp.asarray(Hc), jnp.asarray(needed), jnp.asarray(rects)
        )
        return out.astype(jnp.float32)

    def _window_lattices(self, window, stride):
        """The two corner-row lattices of the regular window grid."""
        wh, ww = window
        n_r = (self.height - wh) // stride + 1
        n_c = (self.width - ww) // stride + 1
        bot = wh - 1 + np.arange(max(n_r, 0)) * stride
        top = np.arange(max(n_r, 0)) * stride - 1     # row -1 is virtual
        return n_r, n_c, bot, top

    def _windows_from_rows(self, R, needed, window, stride):
        """Four-corner arithmetic over prefetched corner rows.

        ``R`` is ``self.rows(needed)``; integer storage dtypes wrap
        modularly through the whole combination, so the result is exact
        whenever the window area fits the policy bound (validated by the
        caller)."""
        n_r, n_c, bot_rows, top_rows = self._window_lattices(window, stride)
        bot = R[..., np.searchsorted(needed, bot_rows), :]
        top = np.zeros_like(bot)
        real = top_rows >= 0
        top[..., real, :] = R[..., np.searchsorted(needed, top_rows[real]), :]
        # In-place difference (unsigned dtypes wrap modularly, as required)
        # and drop ``top`` immediately: peak memory stays at R + the two
        # n_r-row slabs — the proxy _fill_stats reports as 2 * R.nbytes.
        np.subtract(bot, top, out=bot)
        del top
        diff = bot                                     # (..., b, n_r, w)
        s = stride
        ww = window[1]
        d = diff[..., ww - 1 :: s][..., :n_c]
        c = np.zeros_like(d)                           # virtual zero column
        c[..., 1:] = diff[..., s - 1 :: s][..., : n_c - 1]
        out = d - c
        if out.dtype != np.float32:
            # Post-combination values are true counts (<= the validated
            # window area), so the cast out of the modular dtype is exact.
            out = out.astype(np.float32)
        return jnp.asarray(np.moveaxis(out, -3, -1))   # (..., n_r, n_c, b)

    def _empty_windows(self, n_r, n_c):
        return jnp.zeros(
            self.lead + (max(n_r, 0), max(n_c, 0), self.num_bins),
            jnp.float32,
        )

    def sliding_window_histograms(
        self, window, stride: int = 1, *, stats: dict | None = None
    ) -> jnp.ndarray:
        """``region_query.sliding_window_histograms`` semantics: one O(1)
        query per window position, one ``rows()`` pass total."""
        n_r, n_c, bot_rows, top_rows = self._window_lattices(window, stride)
        if n_r <= 0 or n_c <= 0:
            return self._empty_windows(n_r, n_c)
        self._check_region_bound(window[0] * window[1], "window")
        needed = np.unique(np.concatenate([bot_rows, top_rows[top_rows >= 0]]))
        self._warn_if_slabs_dominate(n_r, stride)
        R = self.rows(needed)
        out = self._windows_from_rows(R, needed, window, stride)
        if stats is not None:
            self._fill_stats(stats, R)
        return out

    def likelihood_map(
        self, target_hist, window, metric, stride: int = 1,
        *, stats: dict | None = None,
    ):
        hists = self.sliding_window_histograms(window, stride, stats=stats)
        target_hist = jnp.asarray(target_hist)
        if target_hist.ndim > 1:
            target_hist = target_hist[..., None, None, :]
        return metric(hists, target_hist)

    def multi_scale_search(
        self, target_hist, windows, metric, stride: int = 1
    ):
        """``region_query.multi_scale_search`` semantics — the union of all
        scales' corner-row lattices is fetched in ONE ``rows()`` pass, so a
        band-streamed source computes every scale from a single stream."""
        lattices = [self._window_lattices(wnd, stride) for wnd in windows]
        # Only scales that actually fit the frame query anything; larger
        # ones contribute an empty map (matching the dense path's skip),
        # so they must not trip the storage-policy bound either.
        live = [
            wh * ww for (wh, ww), (n_r, n_c, _, _) in zip(windows, lattices)
            if n_r > 0 and n_c > 0
        ]
        self._check_region_bound(max(live, default=0), "window")
        all_rows = [
            np.concatenate([bot, top[top >= 0]])
            for (n_r, n_c, bot, top) in lattices
            if n_r > 0 and n_c > 0
        ]
        needed = (
            np.unique(np.concatenate(all_rows))
            if all_rows else np.zeros((0,), np.int64)
        )
        R = self.rows(needed) if needed.size else None
        maps = []
        for wnd, (n_r, n_c, _, _) in zip(windows, lattices):
            if n_r <= 0 or n_c <= 0:
                hists = self._empty_windows(n_r, n_c)
            else:
                hists = self._windows_from_rows(R, needed, wnd, stride)
            t = jnp.asarray(target_hist)
            if t.ndim > 1:
                t = t[..., None, None, :]
            maps.append(metric(hists, t))
        best_rect, best_score = rq.reduce_scale_maps(
            maps, windows, stride, self.lead
        )
        return best_rect, best_score, maps

    # -- stats / diagnostics -------------------------------------------------
    # (policy-backed sources — SpilledIH — carry a ``storage`` attribute;
    # it is only read when exact_region_bound is not None, i.e. by them.)

    def _warn_if_slabs_dominate(self, n_r: int, stride: int) -> None:
        """Streaming sources warn when the corner-row slabs are no smaller
        than the monolithic H they avoid (stride-1 sliding windows)."""

    def _fill_stats(self, stats: dict, R: np.ndarray) -> None:
        nlead = int(np.prod(self.lead, dtype=np.int64) or 1)
        stats.update(
            slab_bytes=2 * R.nbytes,
            full_h_bytes=4 * nlead * self.num_bins * self.height * self.width,
        )
        stats.setdefault("num_bands", 1)
        stats.setdefault("band_bytes", 0)
        stats["peak_bytes"] = stats["band_bytes"] + stats["slab_bytes"]


class DenseH(HSource):
    """A materialized (..., b, h, w) H — thin adapter over ``jax.Array``.

    Analytics delegate to the existing dense fast paths (direct advanced
    indexing, strided-slice sliding windows); ``rows()`` exists for
    protocol completeness and cross-representation tests."""

    def __init__(self, H):
        self.H = jnp.asarray(H)
        if self.H.ndim < 3:
            raise ValueError(f"DenseH wants (..., b, h, w), got {self.H.shape}")

    @property
    def num_bins(self) -> int:
        return self.H.shape[-3]

    @property
    def height(self) -> int:
        return self.H.shape[-2]

    @property
    def width(self) -> int:
        return self.H.shape[-1]

    @property
    def lead(self) -> tuple:
        return tuple(self.H.shape[:-3])

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.H.shape, dtype=np.int64)) \
            * self.H.dtype.itemsize

    def rows(self, row_ids) -> np.ndarray:
        return np.asarray(self.H[..., np.asarray(row_ids), :])

    def dense(self):
        return self.H

    def update_bands(self, next_frame, report, *, recompute,
                     apply_fn=None) -> "DenseH":
        """The incremental-video hook (core/delta.py): a new DenseH for
        ``next_frame``, recomputing only the report's dirty bands and
        carry-correcting the clean slabs below — bit-exact vs a full
        recompute."""
        from repro.core import delta as delta_mod

        return DenseH(delta_mod.update_dense_ih(
            self.H, next_frame, report,
            recompute=recompute, apply_fn=apply_fn,
        ))

    def region_histogram(self, rects) -> jnp.ndarray:
        return rq.region_histogram(self.H, jnp.asarray(rects))

    def sliding_window_histograms(
        self, window, stride: int = 1, *, stats: dict | None = None
    ) -> jnp.ndarray:
        return rq.sliding_window_histograms(self.H, window, stride,
                                            stats=stats)

    def multi_scale_search(self, target_hist, windows, metric,
                           stride: int = 1):
        return rq.multi_scale_search(self.H, target_hist, windows, metric,
                                     stride)


class BandedH(HSource):
    """An H held as a ``BandH`` stream (core/bands.py) — full H never
    materializes on device.

    ``bands`` is either an *iterable/iterator* of ``BandH`` (single-shot:
    a second query raises with a pointer to the factory form) or a
    zero-arg *callable* returning a fresh stream per query (replayable —
    what ``HistogramEngine`` builds).  ``rows()`` streams the bands once,
    keeping only the requested rows; each band is pulled to the host with
    ``np.asarray`` before any assembly (the jax-0.4.37 row-sharded
    concatenate hazard — bands from ``iter_banded_sharded_ih`` arrive
    device-sharded)."""

    def __init__(self, bands):
        self._factory = bands if callable(bands) else None
        self._tail = None if callable(bands) else iter(bands)
        self._meta = None
        self.last_stream_stats: dict = {}

    # -- stream management ---------------------------------------------------
    def _take_stream(self):
        # A stashed stream (from a meta peek) is used first; otherwise the
        # factory opens a fresh one, and a single-shot iterator that was
        # already taken has nothing left to give.
        if self._tail is not None:
            stream, self._tail = self._tail, None
        elif self._factory is not None:
            stream = self._factory()
        else:
            raise RuntimeError(
                "this BandedH wraps a single-shot band iterator that was "
                "already consumed; construct it with a zero-arg factory "
                "(e.g. BandedH(lambda: ih.map_bands(img, ...))) to run "
                "multiple queries"
            )
        first = next(stream)
        if self._meta is None:
            self._meta = (first.frame_h, first.H.shape)
        return itertools.chain([first], stream)

    def _peek_meta(self):
        if self._meta is None:
            # Hand the un-consumed stream back so the peek costs nothing:
            # the next query picks it up before asking the factory again.
            self._tail = self._take_stream()
        return self._meta

    # -- metadata ------------------------------------------------------------
    @property
    def num_bins(self) -> int:
        return self._peek_meta()[1][-3]

    @property
    def height(self) -> int:
        return self._peek_meta()[0]

    @property
    def width(self) -> int:
        return self._peek_meta()[1][-1]

    @property
    def lead(self) -> tuple:
        return tuple(self._peek_meta()[1][:-3])

    # -- protocol ------------------------------------------------------------
    def rows(self, row_ids) -> np.ndarray:
        row_ids = np.asarray(row_ids)
        out = None
        num_bands = 0
        peak_band = 0
        for band in self._take_stream():
            if out is None:
                out = np.zeros(
                    band.H.shape[:-2] + (len(row_ids), band.H.shape[-1]),
                    np.float32,
                )
            num_bands = band.num_bands
            sel = (row_ids >= band.r0) & (row_ids < band.r1)
            # Host-side assembly: np.asarray pulls the (possibly sharded)
            # band off device before any indexing/concatenation happens.
            Hb = np.asarray(band.H)
            peak_band = max(peak_band, Hb.nbytes)
            if sel.any():
                out[..., sel, :] = Hb[..., row_ids[sel] - band.r0, :]
        self.last_stream_stats = {
            "num_bands": num_bands, "band_bytes": peak_band,
        }
        return out

    def dense(self):
        """Assemble full H host-side (np.concatenate over host bands —
        never ``jnp.concatenate`` over possibly-sharded device bands)."""
        return jnp.asarray(np.concatenate(
            [np.asarray(band.H) for band in self._take_stream()], axis=-2,
        ))

    def update_bands(self, next_frame, report, *, recompute,
                     apply_fn=None) -> "BandedH":
        """The incremental-video hook (core/delta.py): a new replayable
        BandedH whose stream replays this one's bands, recomputing dirty
        bands from ``next_frame`` and carry-correcting clean bands below.
        Only factory-backed (replayable) sources can be updated — a
        single-shot iterator has no stream left to replay."""
        from repro.core import delta as delta_mod

        if self._factory is None:
            raise RuntimeError(
                "cannot update a single-shot BandedH — only factory-"
                "backed (replayable) band streams support incremental "
                "updates; the engine falls back to a full recompute"
            )
        return BandedH(delta_mod.update_banded_factory(
            self._factory, next_frame, report,
            recompute=recompute, apply_fn=apply_fn,
        ))

    # -- stats / warnings ----------------------------------------------------
    def _warn_if_slabs_dominate(self, n_r: int, stride: int) -> None:
        nlead = int(np.prod(self.lead, dtype=np.int64) or 1)
        slab_bytes = 2 * 4 * nlead * self.num_bins * n_r * self.width
        full_bytes = 4 * nlead * self.num_bins * self.height * self.width
        if slab_bytes >= full_bytes:
            warnings.warn(
                f"banded sliding windows at stride {stride} need "
                f"{slab_bytes} B of corner-row slabs >= the {full_bytes} B "
                "monolithic H they avoid; increase the stride (slabs scale "
                "with 1/stride) or use the monolithic path for frames this "
                "size",
                stacklevel=4,
            )

    def _fill_stats(self, stats: dict, R: np.ndarray) -> None:
        stats.update(self.last_stream_stats)
        super()._fill_stats(stats, R)


class PrefetchedRowsH(HSource):
    """A view over corner rows already fetched from another source.

    ``HistogramEngine.run`` unions the rows every query of a request
    needs and fetches them in ONE ``rows()`` pass (one band stream for a
    banded plan, however many queries ride on it); this class then serves
    each query from that prefetched slab.  ``row_ids`` handed to
    ``rows()`` must be a subset of the prefetched set — anything else is
    a caller bug and raises."""

    def __init__(self, base: HSource, needed: np.ndarray, R: np.ndarray):
        self._base = base
        self._needed = np.asarray(needed)
        self._R = R

    @property
    def num_bins(self) -> int:
        return self._base.num_bins

    @property
    def height(self) -> int:
        return self._base.height

    @property
    def width(self) -> int:
        return self._base.width

    @property
    def lead(self) -> tuple:
        return self._base.lead

    @property
    def exact_region_bound(self) -> int | None:
        return self._base.exact_region_bound

    @property
    def storage(self) -> str:
        return getattr(self._base, "storage", "float32")

    def rows(self, row_ids) -> np.ndarray:
        row_ids = np.asarray(row_ids)
        idx = np.searchsorted(self._needed, row_ids)
        bad = (idx >= len(self._needed)) | (
            self._needed[np.minimum(idx, len(self._needed) - 1)] != row_ids
        ) if len(self._needed) else np.ones(row_ids.shape, bool)
        if row_ids.size and bad.any():
            raise MissingRowsError(
                f"rows {row_ids[bad].tolist()} were not prefetched; the "
                "engine's row-union must cover every query"
            )
        return self._R[..., idx, :]


class FusedRowsH(HSource):
    """The result of a query-fused dispatch: corner rows WITHOUT an H.

    A fused plan (``plan().representation == "fused"``) never builds the
    (n, b, h, w) integral histogram — ``kernels.ops.fused_corner_rows``
    emits exactly the rows the request's queries read (Eq. 2), and this
    source serves those queries from that slab.  Consequences the class
    enforces rather than papers over:

      * ``rows()`` outside the fused set raises :class:`MissingRowsError`
        — there is no H to go back to; the caller must re-run the engine
        with the larger request (``AnalyticsService`` does this on fused
        cache hits whose next request needs more rows);
      * ``dense()`` raises :class:`MissingRowsError` always: densifying
        is precisely what the plan promised not to do.

    ``nbytes`` is the whole footprint of the representation — the
    peak-memory proxy the fused tests assert stays << dense H.
    """

    def __init__(self, row_ids, R, *, height: int, width: int):
        self._row_ids = np.asarray(row_ids, np.int64).reshape(-1)
        self._R = np.asarray(R)
        if self._R.ndim < 3 or self._R.shape[-2] != self._row_ids.size:
            raise ValueError(
                f"R {self._R.shape} does not hold {self._row_ids.size} "
                "rows (want (..., b, k, w))"
            )
        self.height = height
        self.width = width

    @property
    def num_bins(self) -> int:
        return self._R.shape[-3]

    @property
    def lead(self) -> tuple:
        return tuple(self._R.shape[:-3])

    @property
    def row_ids(self) -> np.ndarray:
        return self._row_ids

    @property
    def nbytes(self) -> int:
        return self._R.nbytes

    def rows(self, row_ids) -> np.ndarray:
        row_ids = np.asarray(row_ids)
        idx = np.searchsorted(self._row_ids, row_ids)
        n = len(self._row_ids)
        bad = (
            (idx >= n) | (self._row_ids[np.minimum(idx, n - 1)] != row_ids)
            if n else np.ones(row_ids.shape, bool)
        )
        if row_ids.size and bad.any():
            raise MissingRowsError(
                f"rows {row_ids[bad].tolist()} were not part of the fused "
                "request; a fused plan computes only its declared corner "
                "rows — re-run the engine with the new queries"
            )
        return self._R[..., idx, :]

    def dense(self):
        raise MissingRowsError(
            "this H was query-fused: only the requested corner rows were "
            "ever computed and the dense (b, h, w) H does not exist; "
            "re-plan without query fusion to materialize it"
        )


@functools.lru_cache(maxsize=64)
def _rows_gather(mesh, kind, lead, bin_axis, row_axis, local_h):
    """Jitted (H, row_ids) -> slab gather for ShardedH.rows().

    Cached per (mesh, kind, geometry) with the row ids as a *dynamic*
    argument: every cached frame holds its own ShardedH, and serving
    traffic calls rows() once per request — rebuilding the shard_map
    per call would retrace and recompile every time (~seconds per query
    on a fake-device mesh), so the executable must outlive the source."""
    import jax
    from jax import lax
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map

    if kind == "bin":
        fn = shard_map(
            lambda h_local, rid: jnp.take(h_local, rid, axis=-2),
            mesh=mesh,
            in_specs=(P(*([None] * lead), bin_axis, None, None), P(None)),
            out_specs=P(*([None] * lead), bin_axis, None, None),
            check_vma=False,
        )
        return jax.jit(fn)

    def shard_fn(h_local, rid):
        lo = lax.axis_index(row_axis) * local_h
        local = rid - lo
        own = (local >= 0) & (local < local_h)
        slab = jnp.take(
            h_local, jnp.clip(local, 0, local_h - 1), axis=-2
        )
        slab = jnp.where(own[:, None], slab, jnp.zeros((), slab.dtype))
        return lax.psum(slab, row_axis)

    fn = shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(P(*([None] * lead), None, row_axis, None), P(None)),
        out_specs=P(*([None] * lead), None, None, None),
        check_vma=False,
    )
    return jax.jit(fn)


@functools.lru_cache(maxsize=64)
def _region_sharded(mesh, h_lead, rects_ndim, bin_axis):
    """Jitted (H, rects) -> per-bin-shard region histograms (bin kind)."""
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map

    fn = shard_map(
        lambda h_local, r: rq.region_histogram(h_local, r),
        mesh=mesh,
        in_specs=(
            P(*([None] * h_lead), bin_axis, None, None), P(),
        ),
        out_specs=P(*([None] * (h_lead + rects_ndim - 1)), bin_axis),
        check_vma=False,
    )
    return jax.jit(fn)


class ShardedH(HSource):
    """A mesh-sharded dense H (core/distributed.py).

    ``kind="bin"`` (the paper's multi-GPU scheme) keeps region queries
    device-side and embarrassingly parallel via shard_map.  ``rows()``
    gathers corner rows device-side for both kinds: bin shards index
    their (unsharded) row axis locally, row shards mask-select the rows
    they own and a ``psum`` assembles the slab — so the only readback is
    the (.., b, k, w) slab itself, never the whole H.  A device-side
    ``concatenate`` over shards would be the jax-0.4.37 hazard; the
    gather uses take/where/psum only."""

    def __init__(self, H, mesh, *, kind: str = "bin",
                 bin_axis: str = "model", row_axis: str = "data"):
        if kind not in ("bin", "spatial"):
            raise ValueError(f"unknown sharding kind {kind!r} (bin|spatial)")
        self.H = H
        self.mesh = mesh
        self.kind = kind
        self.bin_axis = bin_axis
        self.row_axis = row_axis

    @property
    def num_bins(self) -> int:
        return self.H.shape[-3]

    @property
    def height(self) -> int:
        return self.H.shape[-2]

    @property
    def width(self) -> int:
        return self.H.shape[-1]

    @property
    def lead(self) -> tuple:
        return tuple(self.H.shape[:-3])

    @property
    def nbytes(self) -> int:
        # The actual aggregate array footprint, like DenseH — the HSource
        # default re-derives a 4-byte-per-element planner estimate, which
        # mis-counts a sharded H the moment its dtype is not fp32.  The
        # service's byte-aware cache eviction (cache_bytes=) charges
        # sources by this number, so it must track the real storage.
        return int(np.prod(self.H.shape, dtype=np.int64)) * self.H.dtype.itemsize

    def rows(self, row_ids) -> np.ndarray:
        row_ids = np.asarray(row_ids)
        if row_ids.size == 0:
            return np.asarray(self.H)[..., row_ids, :]
        if self.kind == "spatial" and self.height % self.mesh.shape[self.row_axis]:
            # Uneven row shards cannot compute local offsets statically;
            # fall back to the whole-H host pull (engine plans never
            # produce this — plan validation requires divisibility).
            return np.asarray(self.H)[..., row_ids, :]
        return self._rows_device(row_ids)

    def _rows_device(self, row_ids: np.ndarray) -> np.ndarray:
        """Device-side corner-row gather: select the k requested rows on
        the mesh and read back only the (.., b, k, w) slab — the
        sanctioned query-side sync, not the carry path.  No cross-shard
        concat happens: bin shards take rows locally (the row axis is
        unsharded within each shard), and row shards zero the rows they
        do not own and psum over the row axis."""
        lead = self.H.ndim - 3
        rid = jnp.asarray(row_ids, jnp.int32)
        local_h = (0 if self.kind == "bin"
                   else self.height // self.mesh.shape[self.row_axis])
        fn = _rows_gather(self.mesh, self.kind, lead,
                          self.bin_axis, self.row_axis, local_h)
        return np.asarray(fn(self.H, rid))

    def dense(self):
        return jnp.asarray(np.asarray(self.H))

    def region_histogram(self, rects) -> jnp.ndarray:
        if self.kind != "bin":
            return super().region_histogram(rects)
        rects = jnp.asarray(rects)
        h_lead = self.H.ndim - 3
        # Same executable-reuse story as _rows_gather: one cached jitted
        # shard_map per (mesh, geometry), rects as a dynamic argument.
        fn = _region_sharded(self.mesh, h_lead, rects.ndim, self.bin_axis)
        return fn(self.H, rects)


def as_hsource(H) -> HSource:
    """Coerce any representation to the protocol.

    Accepts an ``HSource`` (returned as-is), a dense (..., b, h, w) array,
    a ``BandH`` iterable/iterator, or a zero-arg band-stream factory."""
    if isinstance(H, HSource):
        return H
    if callable(H):
        return BandedH(H)
    if hasattr(H, "ndim") and hasattr(H, "shape"):
        return DenseH(H)
    if hasattr(H, "__iter__") or hasattr(H, "__next__"):
        return BandedH(H)
    raise TypeError(
        f"cannot interpret {type(H).__name__} as an integral-histogram "
        "source (want an HSource, a dense (..., b, h, w) array, or a "
        "BandH stream/factory)"
    )
