"""O(1) region-histogram queries over an integral histogram (paper Eq. 2).

h(R, b) = H(r1, c1, b) - H(r0-1, c1, b) - H(r1, c0-1, b) + H(r0-1, c0-1, b)

for the inclusive region R = [r0..r1] x [c0..c1].  Corners with index -1
read as 0 (the virtual zero row/column of the inclusive integral image).

Also implements the paper's headline use case: multi-scale exhaustive
search — histograms of *every* sliding window extracted in constant time
per window — and target likelihood maps for tracking/detection.

Every entry point is rank-polymorphic over a frame-batch axis: an H of
shape ``(b, h, w)`` queries one frame, ``(n, b, h, w)`` (or any stack of
leading axes ``(..., b, h, w)``) queries every frame of the stack in ONE
dispatch, bit-exact with a per-frame Python loop.  Rects/windows are
shared across the frame axis; for per-frame rects, vmap
``region_histogram`` over the frame axis.

``sliding_window_histograms`` has two implementations:

  * ``impl="slice"`` (default) — pure strided-slice four-corner
    arithmetic: the regular window grid means every corner of every
    window lives on a strided lattice, so the whole (n_rows, n_cols)
    field of Eq.-2 queries is four slices of a zero-padded H combined
    elementwise.  No gather, no index arrays — XLA lowers it to
    contiguous strided loads.
  * ``impl="gather"`` — one explicit Eq.-2 gather per window position
    (the general path that also serves arbitrary ``rects`` via
    ``region_histogram``); kept as the oracle for the slice path and for
    benchmarking the difference (benchmarks/bench_analytics.py).

Every entry point also accepts an ``HSource`` (core/hsource.py) instead
of a raw array: the dense, banded, spilled, and sharded representations
all answer the same queries through one corner-row protocol — Eq. 2 only
ever reads corner *rows*, so a rect touches at most 2 bands and a
sliding-window field touches two strided row lattices.  Frames whose
full (b, h, w) H exceeds memory (paper §4.6: 32 GB at 64 MB x 128 bins)
still get exact O(1) queries and likelihood maps.

The ``banded_*`` entry points are deprecated shims over that dispatch
(``BandedH`` + the unified functions); see ``HistogramEngine``
(core/engine.py) for the planned successor to hand-routing any of this.
"""

from __future__ import annotations

import warnings

import jax.numpy as jnp
import numpy as np


def _maybe_hsource(H):
    """Return H as an HSource when it is one, else None (raw array path)."""
    from repro.core import hsource  # deferred: hsource imports this module

    return H if isinstance(H, hsource.HSource) else None


def _corner(H: jnp.ndarray, r: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """H[..., :, r, c] with r/c == -1 reading as 0.

    H: (..., b, h, w); r, c: broadcastable int arrays (idx shape ``S``).
    Returns shape (..., *S, b) — bins moved last for query ergonomics.
    """
    r = jnp.asarray(r)
    c = jnp.asarray(c)
    rc, cc = jnp.broadcast_arrays(jnp.clip(r, 0, None), jnp.clip(c, 0, None))
    # Advanced indices on the two trailing axes are adjacent, so the index
    # dims land in place: (..., b, h, w) -> (..., b, *S).
    vals = H[..., rc, cc]
    if rc.ndim:
        vals = jnp.moveaxis(vals, -(rc.ndim + 1), -1)        # (..., *S, b)
    valid = ((r >= 0) & (c >= 0)).astype(H.dtype)
    return vals * valid[..., None]


def region_histogram(H: jnp.ndarray, rects: jnp.ndarray) -> jnp.ndarray:
    """Histograms of inclusive regions.

    Args:
      H: (b, h, w) integral histogram, or a stack (..., b, h, w).
      rects: (..., 4) int32 [r0, c0, r1, c1], inclusive coordinates,
        shared across any leading frame axes of H.

    Returns:
      (*H_lead, *rects_lead, b) region histograms.
    """
    src = _maybe_hsource(H)
    if src is not None:
        return src.region_histogram(rects)
    r0, c0, r1, c1 = (rects[..., i] for i in range(4))
    return (
        _corner(H, r1, c1)
        - _corner(H, r0 - 1, c1)
        - _corner(H, r1, c0 - 1)
        + _corner(H, r0 - 1, c0 - 1)
    )


def _sliding_windows_gather(
    H: jnp.ndarray, window: tuple[int, int], stride: int
) -> jnp.ndarray:
    """One Eq.-2 gather per window position (the original path)."""
    h, w = H.shape[-2:]
    wh, ww = window
    rows = jnp.arange(0, h - wh + 1, stride)
    cols = jnp.arange(0, w - ww + 1, stride)
    r0 = rows[:, None]
    c0 = cols[None, :]
    rects = jnp.stack(
        jnp.broadcast_arrays(r0, c0, r0 + wh - 1, c0 + ww - 1), axis=-1
    )
    return region_histogram(H, rects)


def _sliding_windows_slice(
    H: jnp.ndarray, window: tuple[int, int], stride: int
) -> jnp.ndarray:
    """Strided-slice four-corner arithmetic over the regular window grid.

    The window lattice r0 = i·s, c0 = j·s puts all four Eq.-2 corners of
    every window on strided slices of H itself:

      bottom-right  H[wh-1 + i·s, ww-1 + j·s]   ->  H[wh-1::s, ww-1::s]
      top-right     H[i·s - 1,    ww-1 + j·s]   ->  H[s-1::s,  ww-1::s]
                                                    shifted down one row,
                                                    zero row prepended
      (and symmetrically for the left corners)

    The virtual H(-1, ·) = H(·, -1) = 0 boundary becomes a one-element
    zero strip concatenated onto the (already window-grid-sized) corner
    slices — nothing the size of H is ever copied, no index arrays are
    built, and XLA fuses the concatenates, the four-term combination and
    the final bins-last transpose into a single elementwise loop over
    contiguous strided loads.
    """
    h, w = H.shape[-2:]
    wh, ww = window
    n_r = (h - wh) // stride + 1
    n_c = (w - ww) // stride + 1

    def zrow(x):  # prepend the virtual zero row (window row i = 0)
        z = jnp.zeros(x.shape[:-2] + (1,) + x.shape[-1:], x.dtype)
        return jnp.concatenate([z, x], axis=-2)

    def zcol(x):  # prepend the virtual zero column (window col j = 0)
        z = jnp.zeros(x.shape[:-1] + (1,), x.dtype)
        return jnp.concatenate([z, x], axis=-1)

    s = stride
    d = H[..., wh - 1 :: s, ww - 1 :: s][..., :n_r, :n_c]
    b = zrow(H[..., s - 1 :: s, ww - 1 :: s][..., : n_r - 1, :n_c])
    c = zcol(H[..., wh - 1 :: s, s - 1 :: s][..., :n_r, : n_c - 1])
    a = zrow(zcol(H[..., s - 1 :: s, s - 1 :: s][..., : n_r - 1, : n_c - 1]))
    # Same association order as the gather path (d - b - c + a) so the
    # fp32 arithmetic is bit-identical, not just allclose.
    return jnp.moveaxis(d - b - c + a, -3, -1)       # (..., n_r, n_c, b)


def sliding_window_histograms(
    H: jnp.ndarray,
    window: tuple[int, int],
    stride: int = 1,
    *,
    impl: str = "slice",
    stats: dict | None = None,
) -> jnp.ndarray:
    """Histograms of every (wh, ww) window at the given stride.

    Returns (..., n_rows, n_cols, b) — one O(1) query per window position
    and frame; this is the constant-time multi-scale exhaustive search of
    the paper.  ``impl`` selects the strided-slice path (default) or the
    explicit per-window gather (see module docstring); both are bit-exact.
    An ``HSource`` H routes through the corner-row protocol (``impl`` is
    moot there; ``stats`` receives the peak-memory proxy).
    """
    if impl not in ("slice", "gather"):
        raise ValueError(f"unknown impl {impl!r} (want 'slice' or 'gather')")
    src = _maybe_hsource(H)
    if src is not None:
        return src.sliding_window_histograms(window, stride, stats=stats)
    if stats is not None:
        # Dense-array semantics: the whole H is the one live "band".
        nbytes = 4 * int(np.prod(H.shape, dtype=np.int64))
        stats.update(num_bands=1, band_bytes=nbytes, slab_bytes=0,
                     peak_bytes=nbytes, full_h_bytes=nbytes)
    h, w = H.shape[-2:]
    n_r = (h - window[0]) // stride + 1
    n_c = (w - window[1]) // stride + 1
    if n_r <= 0 or n_c <= 0:
        # window larger than the frame on some axis: no positions
        return jnp.zeros(
            H.shape[:-3] + (max(n_r, 0), max(n_c, 0), H.shape[-3]), H.dtype
        )
    if impl == "slice":
        return _sliding_windows_slice(H, window, stride)
    return _sliding_windows_gather(H, window, stride)


def likelihood_map(H: jnp.ndarray, target_hist: jnp.ndarray,
                   window: tuple[int, int], metric, stride: int = 1,
                   *, stats: dict | None = None):
    """Feature likelihood map (abstract, ¶1): per-position similarity of the
    window histogram to the target histogram.

    ``target_hist`` is (b,) — one target for all frames — or carries the
    same leading frame axes as H (e.g. (n, b) against an (n, b, h, w)
    stack: one target per frame, broadcast over window positions).
    Returns (..., n_rows, n_cols).  H may be any ``HSource``.
    """
    src = _maybe_hsource(H)
    if src is not None:
        return src.likelihood_map(target_hist, window, metric, stride,
                                  stats=stats)
    hists = sliding_window_histograms(H, window, stride, stats=stats)
    if target_hist.ndim > 1:
        target_hist = target_hist[..., None, None, :]
    return metric(hists, target_hist)


def reduce_scale_maps(maps, windows, stride: int, lead: tuple):
    """Per-frame argmax across a list of per-scale likelihood maps.

    Shared by the dense ``multi_scale_search`` and the ``HSource`` generic
    (core/hsource.py) so both reduce identically (bit-exact)."""
    best_rect = jnp.zeros(lead + (4,), jnp.int32)
    best_score = jnp.full(lead, -jnp.inf)
    for (wh, ww), scores in zip(windows, maps):
        if scores.shape[-2] == 0 or scores.shape[-1] == 0:
            continue                # window exceeds the frame at this scale
        flat = scores.reshape(lead + (-1,))
        idx = jnp.argmax(flat, axis=-1)
        score = jnp.take_along_axis(flat, idx[..., None], axis=-1)[..., 0]
        n_cols = scores.shape[-1]
        r0 = (idx // n_cols) * stride
        c0 = (idx % n_cols) * stride
        rect = jnp.stack(
            [r0, c0, r0 + wh - 1, c0 + ww - 1], axis=-1
        ).astype(jnp.int32)
        better = score > best_score
        best_rect = jnp.where(better[..., None], rect, best_rect)
        best_score = jnp.maximum(score, best_score)
    return best_rect, best_score


def multi_scale_search(
    H: jnp.ndarray,
    target_hist: jnp.ndarray,
    windows: tuple[tuple[int, int], ...],
    metric,
    stride: int = 1,
):
    """Best-matching window across scales, per frame.

    Returns (best_rect, best_score, per_scale_maps) where ``metric`` is a
    similarity (higher = better) from core/distances.py.  For an H stack
    (..., b, h, w) the rects are (..., 4) and scores (...,) — the argmax
    runs independently per frame, matching a per-frame loop bit-exactly.
    An ``HSource`` H fetches the union of every scale's corner-row
    lattices in one pass (one band stream serves all scales).
    """
    src = _maybe_hsource(H)
    if src is not None:
        return src.multi_scale_search(target_hist, windows, metric, stride)
    lead = H.shape[:-3]
    maps = [
        likelihood_map(H, target_hist, (wh, ww), metric, stride)
        for wh, ww in windows
    ]
    best_rect, best_score = reduce_scale_maps(maps, windows, stride, lead)
    return best_rect, best_score, maps


# ---------------------------------------------------------------------------
# Banded queries: Eq. 2 over a band stream (core/bands.py) — the full
# (b, h, w) H never materializes.
# ---------------------------------------------------------------------------
def compressed_region_histogram(
    Hc: jnp.ndarray, row_ids: jnp.ndarray, rects: jnp.ndarray
) -> jnp.ndarray:
    """Eq.-2 queries against a row-compressed H.

    ``Hc`` (..., b, k, w) holds only the full-frame H rows listed in
    ``row_ids`` (sorted, ascending).  Every rect corner row (r0 - 1 and
    r1) must appear in ``row_ids`` or be -1 (the virtual zero row).  The
    four-term association order matches ``region_histogram`` exactly, so
    fp32 results are bit-identical; integer-dtype Hc wraps modularly
    (the reduced-width spill policies rely on this).
    """
    r0, c0, r1, c1 = (rects[..., i] for i in range(4))

    def m(r):  # remap a frame row to its slot in Hc; keep -1 virtual
        return jnp.where(r >= 0, jnp.searchsorted(row_ids, r), -1)

    return (
        _corner(Hc, m(r1), c1)
        - _corner(Hc, m(r0 - 1), c1)
        - _corner(Hc, m(r1), c0 - 1)
        + _corner(Hc, m(r0 - 1), c0 - 1)
    )


def corner_rows(rects: np.ndarray) -> np.ndarray:
    """The distinct full-frame H rows Eq. 2 reads for ``rects``: r0 - 1
    and r1 per rect, deduplicated, the virtual -1 row dropped.  Shared by
    ``banded_region_histogram`` and ``bands.SpilledIH.region_histogram``."""
    rects = np.asarray(rects)
    needed = np.unique(
        np.concatenate([(rects[..., 0] - 1).ravel(), rects[..., 2].ravel()])
    )
    return needed[needed >= 0].astype(np.int64)


def _deprecated_banded(name: str, replacement: str):
    warnings.warn(
        f"{name} is deprecated and will be removed in 2.0: wrap the band "
        f"stream in an HSource and use the unified entry point instead — "
        f"{replacement} — or drive the whole request through "
        "repro.core.engine.HistogramEngine",
        DeprecationWarning,
        stacklevel=3,
    )


def banded_region_histogram(bands, rects: jnp.ndarray) -> jnp.ndarray:
    """Deprecated shim: ``region_histogram(BandedH(bands), rects)``.

    Streams the bands once, keeping only the corner rows the rects touch
    (each rect's four corners live on two rows, hence in <= 2 bands);
    memory is O(distinct corner rows x b x w), never O(b x h x w).
    """
    from repro.core.hsource import as_hsource

    _deprecated_banded(
        "banded_region_histogram", "region_histogram(BandedH(bands), rects)"
    )
    return region_histogram(as_hsource(bands), rects)


def banded_sliding_window_histograms(
    bands,
    window: tuple[int, int],
    stride: int = 1,
    *,
    stats: dict | None = None,
) -> jnp.ndarray:
    """Deprecated shim:
    ``sliding_window_histograms(BandedH(bands), window, stride)``.

    On the regular window grid all four Eq.-2 corners live on two strided
    row lattices, so the stream is consumed in one pass into corner-row
    slabs; peak memory is one band plus the slabs (``stats`` receives the
    proxy), never the full H.  At stride 1 the slabs match the full-H
    footprint and a UserWarning says banding cannot help.
    """
    from repro.core.hsource import as_hsource

    _deprecated_banded(
        "banded_sliding_window_histograms",
        "sliding_window_histograms(BandedH(bands), window, stride)",
    )
    return sliding_window_histograms(
        as_hsource(bands), window, stride, stats=stats
    )


def banded_likelihood_map(
    bands,
    target_hist: jnp.ndarray,
    window: tuple[int, int],
    metric,
    stride: int = 1,
    *,
    stats: dict | None = None,
):
    """Deprecated shim:
    ``likelihood_map(BandedH(bands), target, window, metric, stride)``."""
    from repro.core.hsource import as_hsource

    _deprecated_banded(
        "banded_likelihood_map",
        "likelihood_map(BandedH(bands), target, window, metric)",
    )
    return likelihood_map(
        as_hsource(bands), target_hist, window, metric, stride, stats=stats
    )
