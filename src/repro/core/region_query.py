"""O(1) region-histogram queries over an integral histogram (paper Eq. 2).

h(R, b) = H(r1, c1, b) - H(r0-1, c1, b) - H(r1, c0-1, b) + H(r0-1, c0-1, b)

for the inclusive region R = [r0..r1] x [c0..c1].  Corners with index -1
read as 0 (the virtual zero row/column of the inclusive integral image).

Also implements the paper's headline use case: multi-scale exhaustive
search — histograms of *every* sliding window extracted in constant time
per window — and target likelihood maps for tracking/detection.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _corner(H: jnp.ndarray, r: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """H[:, r, c] with r/c == -1 reading as 0.  r, c: broadcastable int arrays.

    Returns shape (*r.shape, b).
    """
    rc = jnp.clip(r, 0, None)
    cc = jnp.clip(c, 0, None)
    # (b, h, w) -> gather -> (b, *idx); move bins last for query ergonomics.
    vals = H[:, rc, cc]
    valid = ((r >= 0) & (c >= 0)).astype(H.dtype)
    return jnp.moveaxis(vals, 0, -1) * valid[..., None]


def region_histogram(H: jnp.ndarray, rects: jnp.ndarray) -> jnp.ndarray:
    """Histograms of inclusive regions.

    Args:
      H: (b, h, w) integral histogram.
      rects: (..., 4) int32 [r0, c0, r1, c1], inclusive coordinates.

    Returns:
      (..., b) region histograms.
    """
    r0, c0, r1, c1 = (rects[..., i] for i in range(4))
    return (
        _corner(H, r1, c1)
        - _corner(H, r0 - 1, c1)
        - _corner(H, r1, c0 - 1)
        + _corner(H, r0 - 1, c0 - 1)
    )


def sliding_window_histograms(
    H: jnp.ndarray, window: tuple[int, int], stride: int = 1
) -> jnp.ndarray:
    """Histograms of every (wh, ww) window at the given stride.

    Returns (n_rows, n_cols, b) — one O(1) query per window position; this
    is the constant-time multi-scale exhaustive search of the paper.
    """
    _, h, w = H.shape
    wh, ww = window
    rows = jnp.arange(0, h - wh + 1, stride)
    cols = jnp.arange(0, w - ww + 1, stride)
    r0 = rows[:, None]
    c0 = cols[None, :]
    rects = jnp.stack(
        jnp.broadcast_arrays(r0, c0, r0 + wh - 1, c0 + ww - 1), axis=-1
    )
    return region_histogram(H, rects)


def multi_scale_search(
    H: jnp.ndarray,
    target_hist: jnp.ndarray,
    windows: tuple[tuple[int, int], ...],
    metric,
    stride: int = 1,
):
    """Best-matching window across scales.

    Returns (best_rect[4], best_score, per_scale_maps) where ``metric`` is a
    similarity (higher = better) from core/distances.py.
    """
    best_rect = jnp.zeros((4,), jnp.int32)
    best_score = -jnp.inf
    maps = []
    for wh, ww in windows:
        hists = sliding_window_histograms(H, (wh, ww), stride)
        scores = metric(hists, target_hist)          # (n_rows, n_cols)
        maps.append(scores)
        idx = jnp.argmax(scores)
        r, c = jnp.unravel_index(idx, scores.shape)
        r0, c0 = r * stride, c * stride
        rect = jnp.array([r0, c0, r0 + wh - 1, c0 + ww - 1], jnp.int32)
        score = scores.reshape(-1)[idx]
        best_rect = jnp.where(score > best_score, rect, best_rect)
        best_score = jnp.maximum(score, best_score)
    return best_rect, best_score, maps


def likelihood_map(H: jnp.ndarray, target_hist: jnp.ndarray,
                   window: tuple[int, int], metric, stride: int = 1):
    """Feature likelihood map (abstract, ¶1): per-position similarity of the
    window histogram to the target histogram."""
    hists = sliding_window_histograms(H, window, stride)
    return metric(hists, target_hist)
