"""O(1) region-histogram queries over an integral histogram (paper Eq. 2).

h(R, b) = H(r1, c1, b) - H(r0-1, c1, b) - H(r1, c0-1, b) + H(r0-1, c0-1, b)

for the inclusive region R = [r0..r1] x [c0..c1].  Corners with index -1
read as 0 (the virtual zero row/column of the inclusive integral image).

Also implements the paper's headline use case: multi-scale exhaustive
search — histograms of *every* sliding window extracted in constant time
per window — and target likelihood maps for tracking/detection.

Every entry point is rank-polymorphic over a frame-batch axis: an H of
shape ``(b, h, w)`` queries one frame, ``(n, b, h, w)`` (or any stack of
leading axes ``(..., b, h, w)``) queries every frame of the stack in ONE
dispatch, bit-exact with a per-frame Python loop.  Rects/windows are
shared across the frame axis; for per-frame rects, vmap
``region_histogram`` over the frame axis.

``sliding_window_histograms`` has two implementations:

  * ``impl="slice"`` (default) — pure strided-slice four-corner
    arithmetic: the regular window grid means every corner of every
    window lives on a strided lattice, so the whole (n_rows, n_cols)
    field of Eq.-2 queries is four slices of a zero-padded H combined
    elementwise.  No gather, no index arrays — XLA lowers it to
    contiguous strided loads.
  * ``impl="gather"`` — one explicit Eq.-2 gather per window position
    (the general path that also serves arbitrary ``rects`` via
    ``region_histogram``); kept as the oracle for the slice path and for
    benchmarking the difference (benchmarks/bench_analytics.py).

The ``banded_*`` variants run the same queries over a band stream
(core/bands.py) instead of a materialized H: Eq. 2 only ever reads corner
*rows*, so a rect touches at most 2 bands and a sliding-window field
touches two strided row lattices — frames whose full (b, h, w) H exceeds
memory (paper §4.6: 32 GB at 64 MB x 128 bins) still get exact O(1)
queries and likelihood maps.
"""

from __future__ import annotations

import itertools

import jax.numpy as jnp
import numpy as np


def _corner(H: jnp.ndarray, r: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """H[..., :, r, c] with r/c == -1 reading as 0.

    H: (..., b, h, w); r, c: broadcastable int arrays (idx shape ``S``).
    Returns shape (..., *S, b) — bins moved last for query ergonomics.
    """
    r = jnp.asarray(r)
    c = jnp.asarray(c)
    rc, cc = jnp.broadcast_arrays(jnp.clip(r, 0, None), jnp.clip(c, 0, None))
    # Advanced indices on the two trailing axes are adjacent, so the index
    # dims land in place: (..., b, h, w) -> (..., b, *S).
    vals = H[..., rc, cc]
    if rc.ndim:
        vals = jnp.moveaxis(vals, -(rc.ndim + 1), -1)        # (..., *S, b)
    valid = ((r >= 0) & (c >= 0)).astype(H.dtype)
    return vals * valid[..., None]


def region_histogram(H: jnp.ndarray, rects: jnp.ndarray) -> jnp.ndarray:
    """Histograms of inclusive regions.

    Args:
      H: (b, h, w) integral histogram, or a stack (..., b, h, w).
      rects: (..., 4) int32 [r0, c0, r1, c1], inclusive coordinates,
        shared across any leading frame axes of H.

    Returns:
      (*H_lead, *rects_lead, b) region histograms.
    """
    r0, c0, r1, c1 = (rects[..., i] for i in range(4))
    return (
        _corner(H, r1, c1)
        - _corner(H, r0 - 1, c1)
        - _corner(H, r1, c0 - 1)
        + _corner(H, r0 - 1, c0 - 1)
    )


def _sliding_windows_gather(
    H: jnp.ndarray, window: tuple[int, int], stride: int
) -> jnp.ndarray:
    """One Eq.-2 gather per window position (the original path)."""
    h, w = H.shape[-2:]
    wh, ww = window
    rows = jnp.arange(0, h - wh + 1, stride)
    cols = jnp.arange(0, w - ww + 1, stride)
    r0 = rows[:, None]
    c0 = cols[None, :]
    rects = jnp.stack(
        jnp.broadcast_arrays(r0, c0, r0 + wh - 1, c0 + ww - 1), axis=-1
    )
    return region_histogram(H, rects)


def _sliding_windows_slice(
    H: jnp.ndarray, window: tuple[int, int], stride: int
) -> jnp.ndarray:
    """Strided-slice four-corner arithmetic over the regular window grid.

    The window lattice r0 = i·s, c0 = j·s puts all four Eq.-2 corners of
    every window on strided slices of H itself:

      bottom-right  H[wh-1 + i·s, ww-1 + j·s]   ->  H[wh-1::s, ww-1::s]
      top-right     H[i·s - 1,    ww-1 + j·s]   ->  H[s-1::s,  ww-1::s]
                                                    shifted down one row,
                                                    zero row prepended
      (and symmetrically for the left corners)

    The virtual H(-1, ·) = H(·, -1) = 0 boundary becomes a one-element
    zero strip concatenated onto the (already window-grid-sized) corner
    slices — nothing the size of H is ever copied, no index arrays are
    built, and XLA fuses the concatenates, the four-term combination and
    the final bins-last transpose into a single elementwise loop over
    contiguous strided loads.
    """
    h, w = H.shape[-2:]
    wh, ww = window
    n_r = (h - wh) // stride + 1
    n_c = (w - ww) // stride + 1

    def zrow(x):  # prepend the virtual zero row (window row i = 0)
        z = jnp.zeros(x.shape[:-2] + (1,) + x.shape[-1:], x.dtype)
        return jnp.concatenate([z, x], axis=-2)

    def zcol(x):  # prepend the virtual zero column (window col j = 0)
        z = jnp.zeros(x.shape[:-1] + (1,), x.dtype)
        return jnp.concatenate([z, x], axis=-1)

    s = stride
    d = H[..., wh - 1 :: s, ww - 1 :: s][..., :n_r, :n_c]
    b = zrow(H[..., s - 1 :: s, ww - 1 :: s][..., : n_r - 1, :n_c])
    c = zcol(H[..., wh - 1 :: s, s - 1 :: s][..., :n_r, : n_c - 1])
    a = zrow(zcol(H[..., s - 1 :: s, s - 1 :: s][..., : n_r - 1, : n_c - 1]))
    # Same association order as the gather path (d - b - c + a) so the
    # fp32 arithmetic is bit-identical, not just allclose.
    return jnp.moveaxis(d - b - c + a, -3, -1)       # (..., n_r, n_c, b)


def sliding_window_histograms(
    H: jnp.ndarray,
    window: tuple[int, int],
    stride: int = 1,
    *,
    impl: str = "slice",
) -> jnp.ndarray:
    """Histograms of every (wh, ww) window at the given stride.

    Returns (..., n_rows, n_cols, b) — one O(1) query per window position
    and frame; this is the constant-time multi-scale exhaustive search of
    the paper.  ``impl`` selects the strided-slice path (default) or the
    explicit per-window gather (see module docstring); both are bit-exact.
    """
    if impl not in ("slice", "gather"):
        raise ValueError(f"unknown impl {impl!r} (want 'slice' or 'gather')")
    h, w = H.shape[-2:]
    n_r = (h - window[0]) // stride + 1
    n_c = (w - window[1]) // stride + 1
    if n_r <= 0 or n_c <= 0:
        # window larger than the frame on some axis: no positions
        return jnp.zeros(
            H.shape[:-3] + (max(n_r, 0), max(n_c, 0), H.shape[-3]), H.dtype
        )
    if impl == "slice":
        return _sliding_windows_slice(H, window, stride)
    return _sliding_windows_gather(H, window, stride)


def likelihood_map(H: jnp.ndarray, target_hist: jnp.ndarray,
                   window: tuple[int, int], metric, stride: int = 1):
    """Feature likelihood map (abstract, ¶1): per-position similarity of the
    window histogram to the target histogram.

    ``target_hist`` is (b,) — one target for all frames — or carries the
    same leading frame axes as H (e.g. (n, b) against an (n, b, h, w)
    stack: one target per frame, broadcast over window positions).
    Returns (..., n_rows, n_cols).
    """
    hists = sliding_window_histograms(H, window, stride)
    if target_hist.ndim > 1:
        target_hist = target_hist[..., None, None, :]
    return metric(hists, target_hist)


def multi_scale_search(
    H: jnp.ndarray,
    target_hist: jnp.ndarray,
    windows: tuple[tuple[int, int], ...],
    metric,
    stride: int = 1,
):
    """Best-matching window across scales, per frame.

    Returns (best_rect, best_score, per_scale_maps) where ``metric`` is a
    similarity (higher = better) from core/distances.py.  For an H stack
    (..., b, h, w) the rects are (..., 4) and scores (...,) — the argmax
    runs independently per frame, matching a per-frame loop bit-exactly.
    """
    lead = H.shape[:-3]
    best_rect = jnp.zeros(lead + (4,), jnp.int32)
    best_score = jnp.full(lead, -jnp.inf)
    maps = []
    for wh, ww in windows:
        scores = likelihood_map(H, target_hist, (wh, ww), metric, stride)
        maps.append(scores)
        if scores.shape[-2] == 0 or scores.shape[-1] == 0:
            continue                # window exceeds the frame at this scale
        flat = scores.reshape(lead + (-1,))
        idx = jnp.argmax(flat, axis=-1)
        score = jnp.take_along_axis(flat, idx[..., None], axis=-1)[..., 0]
        n_cols = scores.shape[-1]
        r0 = (idx // n_cols) * stride
        c0 = (idx % n_cols) * stride
        rect = jnp.stack(
            [r0, c0, r0 + wh - 1, c0 + ww - 1], axis=-1
        ).astype(jnp.int32)
        better = score > best_score
        best_rect = jnp.where(better[..., None], rect, best_rect)
        best_score = jnp.maximum(score, best_score)
    return best_rect, best_score, maps


# ---------------------------------------------------------------------------
# Banded queries: Eq. 2 over a band stream (core/bands.py) — the full
# (b, h, w) H never materializes.
# ---------------------------------------------------------------------------
def compressed_region_histogram(
    Hc: jnp.ndarray, row_ids: jnp.ndarray, rects: jnp.ndarray
) -> jnp.ndarray:
    """Eq.-2 queries against a row-compressed H.

    ``Hc`` (..., b, k, w) holds only the full-frame H rows listed in
    ``row_ids`` (sorted, ascending).  Every rect corner row (r0 - 1 and
    r1) must appear in ``row_ids`` or be -1 (the virtual zero row).  The
    four-term association order matches ``region_histogram`` exactly, so
    fp32 results are bit-identical; integer-dtype Hc wraps modularly
    (the reduced-width spill policies rely on this).
    """
    r0, c0, r1, c1 = (rects[..., i] for i in range(4))

    def m(r):  # remap a frame row to its slot in Hc; keep -1 virtual
        return jnp.where(r >= 0, jnp.searchsorted(row_ids, r), -1)

    return (
        _corner(Hc, m(r1), c1)
        - _corner(Hc, m(r0 - 1), c1)
        - _corner(Hc, m(r1), c0 - 1)
        + _corner(Hc, m(r0 - 1), c0 - 1)
    )


def corner_rows(rects: np.ndarray) -> np.ndarray:
    """The distinct full-frame H rows Eq. 2 reads for ``rects``: r0 - 1
    and r1 per rect, deduplicated, the virtual -1 row dropped.  Shared by
    ``banded_region_histogram`` and ``bands.SpilledIH.region_histogram``."""
    rects = np.asarray(rects)
    needed = np.unique(
        np.concatenate([(rects[..., 0] - 1).ravel(), rects[..., 2].ravel()])
    )
    return needed[needed >= 0].astype(np.int64)


def banded_region_histogram(bands, rects: jnp.ndarray) -> jnp.ndarray:
    """``region_histogram`` over a band iterator.

    Streams the bands once, keeping only the corner rows the rects touch
    (each rect's four corners live on two rows, hence in <= 2 bands);
    memory is O(distinct corner rows x b x w), never O(b x h x w).
    """
    rects_np = np.asarray(rects)
    needed = corner_rows(rects_np)
    chunks = []
    for band in bands:
        sel = (needed >= band.r0) & (needed < band.r1)
        if sel.any():
            chunks.append(np.asarray(band.H[..., needed[sel] - band.r0, :]))
    Hc = np.concatenate(chunks, axis=-2)
    return compressed_region_histogram(
        jnp.asarray(Hc), jnp.asarray(needed), jnp.asarray(rects_np)
    )


def banded_sliding_window_histograms(
    bands,
    window: tuple[int, int],
    stride: int = 1,
    *,
    stats: dict | None = None,
) -> jnp.ndarray:
    """``sliding_window_histograms`` over a band iterator.

    On the regular window grid all four Eq.-2 corners live on two strided
    row lattices — bottom rows ``wh-1 + i*s`` and top rows ``i*s - 1`` —
    so each band contributes a few rows to two (..., b, n_rows, w) slabs
    and is then dropped.  The column arithmetic afterwards is the same
    strided-slice trick as the monolithic path.  Peak memory is one band
    plus the two slabs (``stats`` receives the proxy; see
    benchmarks/bench_bands.py), never the full H.

    The slabs hold n_rows = (h - wh) // stride + 1 rows each, so the
    memory win over monolithic H scales with the stride: at stride 1 the
    slabs (and the query field itself, which is ~ b*h*w values) match the
    full H footprint and banding cannot help — a UserWarning says so
    rather than silently over-allocating the budget the caller set.
    """
    import warnings

    bands = iter(bands)
    first = next(bands)
    h, w = first.frame_h, first.H.shape[-1]
    wh, ww = window
    s = stride
    n_r = (h - wh) // s + 1
    n_c = (w - ww) // s + 1
    lead = first.H.shape[:-3]
    b = first.H.shape[-3]
    if n_r <= 0 or n_c <= 0:
        return jnp.zeros(lead + (max(n_r, 0), max(n_c, 0), b), jnp.float32)

    nlead = int(np.prod(lead, dtype=np.int64) or 1)
    slab_bytes = 2 * 4 * nlead * b * n_r * w
    full_bytes = 4 * nlead * b * h * w
    if slab_bytes >= full_bytes:
        warnings.warn(
            f"banded sliding windows at stride {s} need {slab_bytes} B of "
            f"corner-row slabs >= the {full_bytes} B monolithic H they "
            "avoid; increase the stride (slabs scale with 1/stride) or "
            "use the monolithic path for frames this size",
            stacklevel=2,
        )
    bot = np.zeros(lead + (b, n_r, w), np.float32)
    top = np.zeros(lead + (b, n_r, w), np.float32)
    peak_band = 0
    for band in itertools.chain([first], bands):
        Hb = np.asarray(band.H)
        peak_band = max(peak_band, Hb.nbytes)
        # bottom lattice: global rows wh-1 + i*s inside [r0, r1)
        i_lo = max(0, -(-(band.r0 - (wh - 1)) // s))
        i_hi = min(n_r - 1, (band.r1 - 1 - (wh - 1)) // s)
        if i_hi >= i_lo:
            ii = np.arange(i_lo, i_hi + 1)
            bot[..., ii, :] = Hb[..., wh - 1 + ii * s - band.r0, :]
        # top lattice: global rows i*s - 1, i >= 1 (i = 0 is the zero row)
        i_lo = max(1, -(-(band.r0 + 1) // s))
        i_hi = min(n_r - 1, band.r1 // s)
        if i_hi >= i_lo:
            ii = np.arange(i_lo, i_hi + 1)
            top[..., ii, :] = Hb[..., ii * s - 1 - band.r0, :]

    diff = bot - top                                   # (..., b, n_r, w)
    d = diff[..., ww - 1 :: s][..., :n_c]
    c = np.zeros_like(d)                               # virtual zero column
    c[..., 1:] = diff[..., s - 1 :: s][..., : n_c - 1]
    if stats is not None:
        stats.update(
            num_bands=first.num_bands,
            band_bytes=peak_band,
            slab_bytes=bot.nbytes + top.nbytes,
            peak_bytes=peak_band + bot.nbytes + top.nbytes,
            full_h_bytes=4 * int(np.prod(lead, dtype=np.int64) or 1)
            * b * h * w,
        )
    return jnp.asarray(np.moveaxis(d - c, -3, -1))     # (..., n_r, n_c, b)


def banded_likelihood_map(
    bands,
    target_hist: jnp.ndarray,
    window: tuple[int, int],
    metric,
    stride: int = 1,
    *,
    stats: dict | None = None,
):
    """``likelihood_map`` over a band stream: exact per-position similarity
    for frames whose full H exceeds memory."""
    hists = banded_sliding_window_histograms(
        bands, window, stride, stats=stats
    )
    if target_hist.ndim > 1:
        target_hist = target_hist[..., None, None, :]
    return metric(hists, target_hist)
