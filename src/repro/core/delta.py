"""Dirty-band invalidation and incremental H updates for video streams.

The paper's target is real-time *video* analytics, and consecutive
frames from a fixed camera differ in a handful of rows.  Recomputing
the full integral histogram per frame throws that structure away; this
module exploits it — the compute-vs-reuse tradeoff of Ehsan et al.
(arXiv:1510.05142) applied across time.

The math rides the band-composition rule of core/bands.py: every
column of H is a prefix sum over rows, so for a band starting at r0

    H[r, c, b] = H_band[r - r0, c, b] + H[r0 - 1, c, b]

and editing frame rows inside a band changes H *below* the band only
through the band's bottom row.  The incremental walk over a band plan:

  * bands above the first dirty band are untouched (their inputs did
    not change and their carry-in chain is identical);
  * a dirty band is recomputed from the new frame rows with the
    re-threaded carry-in;
  * a clean band below a dirty one gets one broadcast correction,
    ``delta = new_bottom - old_bottom`` of the nearest dirty band
    above, added to every row (``kernels/ops.delta_apply``); its new
    bottom row is ``old_bottom + delta``, so consecutive clean bands
    reuse the same delta without any rescan.

All H arithmetic is integer-valued fp32 (exact below 2**24, validated
upstream), so the updated H is **bit-exact** against a monolithic
recompute — asserted, not approximated, in tests/test_delta.py.  The
integer spill policies update in the same modular arithmetic they
store in; their true-valued fp32 carry chain is retained on the
``SpilledIH`` (``carries``) precisely so the delta can be formed
without unwrapping stored bands.

``diff_bands`` is the detector (a cheap host-side per-row reduction);
``update_dense_ih`` / ``update_banded_factory`` / ``update_spilled_ih``
are the per-representation walks, reached through the sources'
``update_bands`` hooks; the planner decision (dirty fraction vs
threshold) lives in ``core/engine.plan``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bands import STORAGE_POLICIES, BandPlan

#: default dirty-row fraction above which an incremental update stops
#: paying (the planner's threshold; tunable per geometry through the
#: ``$REPRO_TUNED_CONFIGS`` priors key "delta_threshold").
DEFAULT_DIRTY_THRESHOLD = 0.35


@dataclasses.dataclass(frozen=True)
class DirtyReport:
    """Per-band dirtiness of one frame transition under one band plan.

    ``spans`` are the [r0, r1) row bands the update walks; ``dirty[i]``
    says band i's frame rows changed.  The *fraction* counts rows of
    dirty bands (what the update actually recomputes), not raw changed
    rows — it is the planner's cost input."""

    spans: tuple[tuple[int, int], ...]
    dirty: tuple[bool, ...]
    frame_h: int

    @property
    def dirty_rows(self) -> int:
        return sum(r1 - r0 for (r0, r1), d in zip(self.spans, self.dirty)
                   if d)

    @property
    def dirty_fraction(self) -> float:
        return self.dirty_rows / self.frame_h if self.frame_h else 0.0

    @property
    def num_dirty(self) -> int:
        return sum(self.dirty)

    @property
    def all_clean(self) -> bool:
        return not any(self.dirty)


def _spans_of(band_plan) -> tuple[tuple[int, int], ...]:
    spans = getattr(band_plan, "spans", band_plan)
    return tuple((int(r0), int(r1)) for r0, r1 in spans)


def diff_bands(prev_frame, next_frame, band_plan) -> DirtyReport:
    """Detect the dirty row bands between two frames (or frame stacks).

    A cheap host-side reduction: a row is dirty when any pixel of any
    frame in the stack differs; a band is dirty when any of its rows
    is.  ``band_plan`` is a :class:`~repro.core.bands.BandPlan` or a
    bare span sequence — the granularity the update will recompute at
    (a cached ``SpilledIH`` hands its own spans here).
    """
    prev = np.asarray(prev_frame)
    nxt = np.asarray(next_frame)
    if prev.shape != nxt.shape:
        raise ValueError(
            f"frame shapes differ: prev {prev.shape} vs next {nxt.shape}")
    if prev.ndim < 2:
        raise ValueError(f"expected (h, w) or (n, h, w), got {prev.shape}")
    spans = _spans_of(band_plan)
    h = prev.shape[-2]
    if not spans or spans[0][0] != 0 or spans[-1][1] != h or any(
            a1 != b0 for (_, a1), (b0, _) in zip(spans, spans[1:])):
        raise ValueError(
            f"band spans {spans[:4]}... do not tile [0, {h})")
    changed = prev != nxt
    axes = tuple(i for i in range(changed.ndim) if i != changed.ndim - 2)
    row_dirty = np.any(changed, axis=axes)
    dirty = tuple(bool(row_dirty[r0:r1].any()) for r0, r1 in spans)
    return DirtyReport(spans=spans, dirty=dirty, frame_h=h)


def _default_apply(slab, delta):
    """The jnp fallback of ``kernels/ops.delta_apply``: one fused add."""
    return slab + delta[..., None, :]


def _merged_runs(report: DirtyReport):
    """Coalesce consecutive equally-dirty spans into maximal runs.

    The dense walk has no per-band storage to respect, so one recompute
    dispatch covers a whole dirty run and one broadcast apply covers a
    whole clean run — detection granularity (fine, to localise the
    change) decouples from dispatch granularity (coarse, to amortise
    per-op overhead).  The banded/spilled walks keep per-band steps:
    their storage IS the band structure.
    """
    runs: list[list] = []
    for (r0, r1), d in zip(report.spans, report.dirty):
        if runs and runs[-1][2] == d:
            runs[-1][1] = r1
        else:
            runs.append([r0, r1, d])
    return [(r0, r1, d) for r0, r1, d in runs]


@jax.jit
def _assemble_dense(H, slabs, starts, stops, delta_steps):
    """ONE fused dispatch repairing a dense H from recomputed dirty-run
    slabs: broadcast the carry-correction steps below each dirty run,
    then splice the slabs in.  Row boundaries are traced scalars, so a
    moving dirty region re-uses the compiled executable (recompiles only
    when the run count or a slab height changes).

    ``delta_steps[i]`` is D_i - D_{i-1} (D_i = run i's new bottom minus
    its old bottom): clean rows between dirty runs i and i+1 accumulate
    exactly D_i, and dirty rows — corrupted by every step mask crossing
    them — are overwritten by their slab afterwards.
    """
    rows = jnp.arange(H.shape[-2])
    out = H
    for r1, step in zip(stops, delta_steps):
        below = (rows >= r1).astype(H.dtype)
        out = out + below[:, None] * step[..., None, :]
    for slab, r0 in zip(slabs, starts):
        out = jax.lax.dynamic_update_slice(
            out, slab.astype(out.dtype),
            (0,) * (out.ndim - 2) + (r0, 0))
    return out


def update_dense_ih(
    H,
    next_frame,
    report: DirtyReport,
    *,
    recompute: Callable,
    apply_fn: Callable | None = None,
):
    """Repair a dense (..., b, h, w) H for ``next_frame``.

    ``recompute(band_rows, carry_in) -> H_band`` runs the real kernel
    dispatch (the engine builds it from its plan's kernel kwargs);
    ``apply_fn(slab, delta) -> slab`` applies the broadcast correction.
    With ``apply_fn=None`` the whole repair — correction broadcasts plus
    slab splices — is ONE fused jit dispatch (``_assemble_dense``); an
    explicit ``apply_fn`` (the engine passes ``ops.delta_apply`` for
    Pallas plans) takes the per-run walk so the kernel does the adds.
    Returns the new dense H, bit-exact vs a full recompute either way.
    """
    H = jnp.asarray(H)
    if apply_fn is None:
        slabs, starts, stops, steps = [], [], [], []
        D_prev = None          # cumulative carry delta of dirty runs above
        for r0, r1, is_dirty in _merged_runs(report):
            if not is_dirty:
                continue
            carry = None
            if r0 > 0:
                carry = H[..., r0 - 1, :]
                if D_prev is not None:
                    carry = carry + D_prev
            slab = recompute(next_frame[..., r0:r1, :], carry)
            D = slab[..., -1, :] - H[..., r1 - 1, :]
            steps.append(D if D_prev is None else D - D_prev)
            slabs.append(slab)
            starts.append(r0)
            stops.append(r1)
            D_prev = D
        if not slabs:
            return H
        return _assemble_dense(H, slabs, starts, stops, steps)

    pieces = []           # per-run slabs, reassembled in ONE copy
    new_carry = None      # bottom row of the run above, updated values
    delta = None          # correction for clean runs below a dirty one
    for r0, r1, is_dirty in _merged_runs(report):
        old_bottom = H[..., r1 - 1, :]
        if is_dirty:
            slab = recompute(next_frame[..., r0:r1, :], new_carry)
            new_carry = slab[..., -1, :]
            delta = new_carry - old_bottom
        elif delta is None:
            new_carry = old_bottom          # untouched prefix of the frame
            slab = H[..., r0:r1, :]
        else:
            slab = apply_fn(H[..., r0:r1, :], delta)
            new_carry = old_bottom + delta
        if slab.dtype != H.dtype:
            slab = slab.astype(H.dtype)
        pieces.append(slab)
    if len(pieces) == 1:
        return pieces[0]
    return jnp.concatenate(pieces, axis=-2)


def update_banded_factory(
    factory: Callable,
    next_frame,
    report: DirtyReport,
    *,
    recompute: Callable,
    apply_fn: Callable | None = None,
) -> Callable:
    """Lift a replayable band-stream factory to the next frame.

    Returns a new zero-arg factory whose stream replays ``factory``'s
    bands, recomputing dirty ones from ``next_frame`` with the
    re-threaded carry and correcting clean ones below with the carry
    delta — each yielded ``BandH`` is exactly what a fresh banded
    compute of ``next_frame`` would yield, band for band.
    """
    if apply_fn is None:
        apply_fn = _default_apply

    def replay():
        new_carry = None
        delta = None
        for band in factory():
            i = band.index
            if i >= len(report.spans) or \
                    report.spans[i] != (band.r0, band.r1):
                raise ValueError(
                    f"band {i} spans [{band.r0}, {band.r1}) but the dirty "
                    f"report was built for "
                    f"{report.spans[i] if i < len(report.spans) else None} "
                    "— detection and update must share one band plan")
            if report.dirty[i]:
                Hb = recompute(next_frame[..., band.r0:band.r1, :],
                               new_carry)
                new_carry = Hb[..., -1, :]
                delta = new_carry - band.carry
                yield dataclasses.replace(band, H=Hb, carry=new_carry)
            elif delta is None:
                new_carry = band.carry
                yield band
            else:
                new_carry = band.carry + delta
                yield dataclasses.replace(
                    band, H=apply_fn(band.H, delta), carry=new_carry)

    return replay


def _store(arr: np.ndarray, dtype) -> np.ndarray:
    """The spill cast of core/bands.spill_banded_ih: fp32 exact counts
    to the policy dtype, modular for the integer widths."""
    if dtype is np.float32:
        return arr.astype(np.float32)
    arr = np.mod(arr.astype(np.int64), np.int64(np.iinfo(dtype).max) + 1)
    return arr.astype(dtype)


def update_spilled_ih(src, next_frame, report: DirtyReport, *,
                      recompute: Callable):
    """Repair a host-spilled H (``core/bands.SpilledIH``) in its own
    storage policy.

    Dirty bands are recomputed in fp32 (true counts) and re-spilled
    through the policy cast; clean bands below take the delta in int64
    modular arithmetic, so wrapped uint16/uint32 values stay exactly
    what a fresh spill of the new frame would store.  The retained
    true-valued ``carries`` chain both supplies the old bottoms the
    delta needs and is updated alongside — a further update can chain
    off the result.
    """
    if src.carries is None:
        raise ValueError(
            "this SpilledIH predates carry retention (no `carries`); "
            "re-spill the frame before updating incrementally")
    if tuple(src.spans) != report.spans:
        raise ValueError(
            f"spill spans {tuple(src.spans)[:4]}... do not match the "
            f"dirty report's {report.spans[:4]}... — detection must run "
            "on the source's own band plan")
    dtype, _ = STORAGE_POLICIES[src.storage]
    bands_new, carries_new = [], []
    new_carry = None
    delta = None
    for i, ((r0, r1), is_dirty) in enumerate(zip(report.spans,
                                                 report.dirty)):
        if is_dirty:
            Hb = recompute(next_frame[..., r0:r1, :], new_carry)
            arr = np.asarray(Hb).astype(np.float32)
            bottom = arr[..., -1, :]
            delta = bottom - src.carries[i]
            bands_new.append(_store(arr, dtype))
            carries_new.append(bottom)
            new_carry = bottom
        elif delta is None:
            bands_new.append(src.bands[i])
            carries_new.append(src.carries[i])
            new_carry = src.carries[i]
        else:
            if dtype is np.float32:
                bands_new.append(src.bands[i] + delta[..., None, :])
            else:
                # Deltas are exact integers in fp32; add them in the
                # policy's modular ring so wrapped values stay aligned
                # with what a fresh spill would store.
                mod = np.int64(np.iinfo(dtype).max) + 1
                stepped = src.bands[i].astype(np.int64) \
                    + np.rint(delta[..., None, :]).astype(np.int64)
                bands_new.append(np.mod(stepped, mod).astype(dtype))
            carry = src.carries[i] + delta
            carries_new.append(carry)
            new_carry = carry
    return dataclasses.replace(src, bands=bands_new, carries=carries_new)
