"""Public API: the integral histogram as a composable JAX module.

>>> ih = IntegralHistogram(num_bins=32)
>>> H = ih(image)                          # (32, h, w)
>>> Hs = ih(stack)                         # (n, 32, h, w) — one dispatch
>>> hist = ih.query(H, [r0, c0, r1, c1])   # O(1) region histogram
>>> hists = ih.query(Hs, rects)            # batched: (n, ..., 32)
>>> wins = ih.sliding_windows(Hs, (24, 24))  # (n, n_r, n_c, 32), strided
...                                          # slices — no gather
>>> for H in ih.map_frames(video, batch_size=16):   # streaming throughput
...     ...

The analytics statics are rank-polymorphic over leading frame axes (see
core/region_query.py); results equal a per-frame loop bit-exactly.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator

import jax
import jax.numpy as jnp

from repro.core import region_query
from repro.kernels.ops import integral_histogram as _compute


@dataclasses.dataclass(frozen=True)
class IntegralHistogram:
    """Configured integral-histogram operator.

    Attributes:
      num_bins: histogram bins b.
      method: "cw_b" | "cw_sts" | "cw_tis" | "wf_tis" (paper's four).
      backend: "auto" (pallas on TPU, jnp elsewhere) | "pallas" | "jnp".
      tile: spatial tile edge for the tiled methods (128 = MXU native).
      bin_block: bins per kernel block (8 = sublane count).
      value_range: integer pixel range (floats are binned over [0, 1)).
      interpret: run Pallas kernels in interpret mode (CPU validation).
    """

    num_bins: int = 32
    method: str = "wf_tis"
    backend: str = "auto"
    tile: int = 128
    bin_block: int = 8
    value_range: int = 256
    use_mxu: bool = True
    interpret: bool = False

    def __call__(self, image: jnp.ndarray) -> jnp.ndarray:
        """(h, w) -> (num_bins, h, w); (n, h, w) -> (n, num_bins, h, w)."""
        return _compute(
            image,
            self.num_bins,
            method=self.method,
            backend=self.backend,
            tile=self.tile,
            bin_block=self.bin_block,
            use_mxu=self.use_mxu,
            interpret=self.interpret,
            value_range=self.value_range,
        )

    def map_frames(
        self,
        frames: Iterable,
        *,
        batch_size: int | str = "auto",
        depth: int = 2,
        device=None,
    ) -> Iterator[jax.Array]:
        """Stream integral histograms over a frame sequence.

        Microbatches ``batch_size`` frames per dispatch through the batched
        kernel path and keeps ``depth`` dispatches in flight (paper §4.4's
        dual-buffering, via ``core/runtime.py``), yielding one
        (num_bins, h, w) result per frame in order.  This is the
        throughput path for video: see benchmarks/bench_batched.py for
        the frames/sec scaling.

        ``batch_size="auto"`` asks the planner (core/engine.py) to size
        the microbatch from the per-frame output footprint (num_bins * h
        * w fp32): small ROI-scale frames are dispatch-bound and batch
        deep; full frames are cache-bound on CPU and stay near batch 1 —
        the adaptive-batching idea of Koppaka et al. (arXiv:1011.0235)
        restated for XLA dispatch.  ``batch_size="adaptive"`` starts from
        the planner's size and lets the runtime retune it online from
        measured per-dispatch latency.
        """
        import itertools

        from repro.core.runtime import FrameRuntime

        frames = iter(frames)
        try:
            first = next(frames)
        except StopIteration:
            return iter(())
        adaptive = batch_size == "adaptive"
        if isinstance(batch_size, str):
            if batch_size not in ("auto", "adaptive"):
                raise ValueError(
                    f'batch_size must be an int, "auto" or "adaptive", '
                    f"got {batch_size!r}"
                )
            from repro.core import engine as _engine

            h, w = first.shape[-2:]
            batch_size = _engine.plan(_engine.WorkloadSpec(
                height=h, width=w, num_bins=self.num_bins,
                num_frames=None, method=self.method, backend=self.backend,
            )).microbatch

        runtime = FrameRuntime(
            FrameRuntime.stateless(self), depth=depth, device=device,
            microbatch=batch_size, adaptive=adaptive,
        )
        return runtime.map_frames(itertools.chain([first], frames))

    def map_bands(
        self,
        image,
        *,
        band_h: int | None = None,
        memory_budget_bytes: int | None = None,
        prefetch: int = 0,
        device=None,
    ):
        """Stream H as row bands under a memory budget (core/bands.py).

        For frames whose (num_bins, h, w) H exceeds device or host memory
        (paper §4.6: 32 GB at 64 MB x 128 bins) the monolithic ``__call__``
        is impossible; this yields ``BandH`` chunks carrying the band's H
        and its (b, w) bottom-row carry, bit-exact vs the monolithic
        result.  Feed the iterator to ``banded_query`` /
        ``banded_sliding_windows`` / ``banded_likelihood_map`` for O(1)
        analytics that never materialize H.  ``prefetch >= 1`` stages the
        next band's pixels while the current band computes.
        """
        from repro.core import bands

        return bands.iter_banded_ih(
            image, self.num_bins,
            band_h=band_h, memory_budget_bytes=memory_budget_bytes,
            prefetch=prefetch, device=device,
            method=self.method, backend=self.backend, tile=self.tile,
            bin_block=self.bin_block, use_mxu=self.use_mxu,
            interpret=self.interpret, value_range=self.value_range,
        )

    def engine(self, **overrides):
        """A ``HistogramEngine`` (core/engine.py) sharing this operator's
        configuration — the planned successor to hand-routing between
        ``__call__`` / ``map_frames`` / ``map_bands``:

        >>> eng = ih.engine(memory_budget_bytes=256 << 20)
        >>> out = eng.run(frame, [RegionQuery(rects)])
        """
        from repro.core.engine import HistogramEngine

        kwargs = dict(
            method=self.method, backend=self.backend, tile=self.tile,
            bin_block=self.bin_block, use_mxu=self.use_mxu,
            interpret=self.interpret, value_range=self.value_range,
        )
        kwargs.update(overrides)
        return HistogramEngine(self.num_bins, **kwargs)

    # ---- O(1) analytics on a computed H (array or any HSource) ----
    query = staticmethod(region_query.region_histogram)
    sliding_windows = staticmethod(region_query.sliding_window_histograms)
    likelihood_map = staticmethod(region_query.likelihood_map)
    multi_scale_search = staticmethod(region_query.multi_scale_search)

    # ---- deprecated: the unified entry points above accept a BandedH ----
    # analysis: allow-shim-use(public deprecated aliases kept until their removal release; they re-export, not consume)
    banded_query = staticmethod(region_query.banded_region_histogram)
    banded_sliding_windows = staticmethod(
        # analysis: allow-shim-use(public deprecated aliases kept until their removal release; they re-export, not consume)
        region_query.banded_sliding_window_histograms
    )
    # analysis: allow-shim-use(public deprecated aliases kept until their removal release; they re-export, not consume)
    banded_likelihood_map = staticmethod(region_query.banded_likelihood_map)
