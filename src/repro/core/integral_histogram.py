"""Public API: the integral histogram as a composable JAX module.

>>> ih = IntegralHistogram(num_bins=32)
>>> H = ih(image)                          # (32, h, w)
>>> hist = ih.query(H, [r0, c0, r1, c1])   # O(1) region histogram
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import region_query
from repro.kernels.ops import integral_histogram as _compute


@dataclasses.dataclass(frozen=True)
class IntegralHistogram:
    """Configured integral-histogram operator.

    Attributes:
      num_bins: histogram bins b.
      method: "cw_b" | "cw_sts" | "cw_tis" | "wf_tis" (paper's four).
      backend: "auto" (pallas on TPU, jnp elsewhere) | "pallas" | "jnp".
      tile: spatial tile edge for the tiled methods (128 = MXU native).
      bin_block: bins per kernel block (8 = sublane count).
      value_range: integer pixel range (floats are binned over [0, 1)).
      interpret: run Pallas kernels in interpret mode (CPU validation).
    """

    num_bins: int = 32
    method: str = "wf_tis"
    backend: str = "auto"
    tile: int = 128
    bin_block: int = 8
    value_range: int = 256
    use_mxu: bool = True
    interpret: bool = False

    def __call__(self, image: jnp.ndarray) -> jnp.ndarray:
        return _compute(
            image,
            self.num_bins,
            method=self.method,
            backend=self.backend,
            tile=self.tile,
            bin_block=self.bin_block,
            use_mxu=self.use_mxu,
            interpret=self.interpret,
            value_range=self.value_range,
        )

    # ---- O(1) analytics on a computed H ----
    query = staticmethod(region_query.region_histogram)
    sliding_windows = staticmethod(region_query.sliding_window_histograms)
    likelihood_map = staticmethod(region_query.likelihood_map)
    multi_scale_search = staticmethod(region_query.multi_scale_search)
