"""Core: the paper's contribution — integral histograms and their uses.

The plan/execute surface (``WorkloadSpec`` / ``plan`` /
``HistogramEngine`` and the ``HSource`` protocol) is re-exported lazily:
``repro.core.engine`` transitively imports ``repro.kernels.ops``, which
itself imports this package, so an eager import here would make the
package unimportable whenever ``kernels.ops`` is the entry module.
"""

from repro.core.binning import PAD_BIN, bin_indices, one_hot_bins
from repro.core.scans import METHODS, apply_carry, cw_b, cw_sts, cw_tis, wf_tis

_ENGINE_EXPORTS = {
    "WorkloadSpec", "ExecutionPlan", "MeshLayout", "plan",
    "HistogramEngine", "EngineResult", "RegionQuery", "SlidingWindowQuery",
    "LikelihoodQuery", "MultiScaleQuery",
}
_HSOURCE_EXPORTS = {"HSource", "DenseH", "BandedH", "ShardedH", "as_hsource"}

__all__ = [
    "PAD_BIN", "bin_indices", "one_hot_bins",
    "METHODS", "apply_carry", "cw_b", "cw_sts", "cw_tis", "wf_tis",
    *sorted(_ENGINE_EXPORTS), *sorted(_HSOURCE_EXPORTS),
]


def __getattr__(name):
    if name in _ENGINE_EXPORTS:
        from repro.core import engine

        return getattr(engine, name)
    if name in _HSOURCE_EXPORTS:
        from repro.core import hsource

        return getattr(hsource, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
