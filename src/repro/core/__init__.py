"""Core: the paper's contribution — integral histograms and their uses."""

from repro.core.binning import PAD_BIN, bin_indices, one_hot_bins
from repro.core.scans import METHODS, apply_carry, cw_b, cw_sts, cw_tis, wf_tis

__all__ = [
    "PAD_BIN", "bin_indices", "one_hot_bins",
    "METHODS", "apply_carry", "cw_b", "cw_sts", "cw_tis", "wf_tis",
]
