"""Tile/band-shape autotuner: measured configs the planner loads as priors.

The paper's §4.2/§4.5 point is that tile shape decides throughput and the
best shape is hardware- and geometry-dependent; ``benchmarks/bench_roofline``
measures where each config sits against the machine's streaming bandwidth.
This module closes the loop: ``autotune()`` times the real dispatch over a
candidate grid of (tile, bin_block) — and a band-height sweep when a memory
budget applies — and persists the winners to JSON.  ``plan()`` consults that
file (via :func:`prior_for`) and substitutes the tuned tile/bin_block when
the caller left them at the defaults, stamping the plan's ``tuned`` field so
``explain()`` shows the provenance.

The priors file is opt-in: it is looked up from the ``REPRO_TUNED_CONFIGS``
environment variable (or an explicit path), so default plans — and the
golden ``explain()`` snapshots — are byte-identical with no file present.

Format (one entry per workload geometry)::

    {"version": 1,
     "configs": {"480x640x32": {"tile": 128, "bin_block": 8,
                                "band_h": 120, "seconds": 0.0123,
                                "gbps": 3.1}}}

CLI::

    python -m repro.core.autotune --height 480 --width 640 --bins 32 \
        --out tuned.json
    REPRO_TUNED_CONFIGS=tuned.json python ...   # planner picks it up
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

#: environment variable naming the priors file ``plan()`` consults.
ENV_VAR = "REPRO_TUNED_CONFIGS"

#: candidate grid — the shapes bench_grid/bench_roofline sweep.
TILE_CANDIDATES = (64, 128, 256)
BIN_BLOCK_CANDIDATES = (4, 8, 16)

# (path, mtime) -> parsed configs; reloads only when the file changes.
_cache: dict[tuple[str, float], dict] = {}


def config_key(height: int, width: int, num_bins: int) -> str:
    return f"{height}x{width}x{num_bins}"


def load_priors(path: str | None = None) -> dict:
    """The tuned-config table, or ``{}`` when no file is configured.

    ``path=None`` reads ``$REPRO_TUNED_CONFIGS``; a missing/unreadable
    file is an empty table, not an error — priors are advisory.
    """
    path = path or os.environ.get(ENV_VAR)
    if not path:
        return {}
    try:
        mtime = os.path.getmtime(path)
    except OSError:
        return {}
    key = (os.path.abspath(path), mtime)
    if key not in _cache:
        try:
            with open(path) as f:
                data = json.load(f)
            configs = data.get("configs", {})
        except (OSError, ValueError):
            configs = {}
        _cache.clear()           # one live file; stale mtimes drop out
        _cache[key] = configs
    return _cache[key]


def prior_for(spec, path: str | None = None) -> dict | None:
    """The tuned config for ``spec``'s geometry, if the caller left the
    shape knobs at their defaults (an explicit tile/bin_block is a user
    decision the prior must not override)."""
    if spec.tile != 128 or spec.bin_block != 8:
        return None
    priors = load_priors(path)
    return priors.get(config_key(spec.height, spec.width, spec.num_bins))


def _time_call(fn, repeats: int) -> float:
    fn()                                          # compile / warm caches
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        getattr(out, "block_until_ready", lambda: out)()
        best = min(best, time.perf_counter() - t0)
    return best


def autotune(
    height: int,
    width: int,
    num_bins: int,
    *,
    method: str = "wf_tis",
    backend: str = "auto",
    memory_budget_bytes: int | None = None,
    tiles=TILE_CANDIDATES,
    bin_blocks=BIN_BLOCK_CANDIDATES,
    repeats: int = 3,
    rng=None,
) -> dict:
    """Measure the candidate grid on this machine, return the winner.

    The returned dict is one priors-file entry: the fastest
    ``(tile, bin_block)`` for a full-frame dispatch, the fastest
    ``band_h`` under ``memory_budget_bytes`` (when given), the winning
    time and its effective bandwidth (touched bytes / time — the number
    to put beside ``bench_roofline``'s streaming ceiling).
    """
    from repro.core.bands import plan_bands
    from repro.kernels.ops import integral_histogram

    rng = np.random.default_rng(0) if rng is None else rng
    frame = rng.integers(0, 256, (height, width), np.uint8)
    touched = height * width + 4 * num_bins * height * width

    best = None
    for tile in tiles:
        for bb in bin_blocks:
            sec = _time_call(
                lambda t=tile, b=bb: integral_histogram(
                    frame, num_bins, method=method, backend=backend,
                    tile=t, bin_block=b,
                ),
                repeats,
            )
            if best is None or sec < best["seconds"]:
                best = {"tile": tile, "bin_block": bb, "seconds": sec}

    if memory_budget_bytes is not None:
        budget_plan = plan_bands(
            height, width, num_bins,
            memory_budget_bytes=memory_budget_bytes,
        )
        cands = sorted({
            bh for bh in (
                budget_plan.band_h, budget_plan.band_h // 2, best["tile"],
            ) if 1 <= bh <= budget_plan.band_h
        })
        best_bh = None
        for bh in cands:
            sec = _time_call(
                lambda b=bh: integral_histogram(
                    frame, num_bins, method=method, backend=backend,
                    tile=best["tile"], bin_block=best["bin_block"],
                    memory_budget_bytes=4 * num_bins * b * width,
                ),
                repeats,
            )
            if best_bh is None or sec < best_bh[1]:
                best_bh = (bh, sec)
        best["band_h"] = best_bh[0]

    best["gbps"] = touched / best["seconds"] / 1e9
    return best


def save_priors(path: str, configs: dict) -> None:
    with open(path, "w") as f:
        json.dump({"version": 1, "configs": configs}, f, indent=2,
                  sort_keys=True)
        f.write("\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.core.autotune",
        description="tune tile/bin_block/band_h for one workload geometry "
                    "and persist the winner as a planner prior",
    )
    ap.add_argument("--height", type=int, default=480)
    ap.add_argument("--width", type=int, default=640)
    ap.add_argument("--bins", type=int, default=32)
    ap.add_argument("--budget", type=int, default=None,
                    help="memory budget (bytes) to tune a band height under")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--out", default="tuned.json",
                    help="priors file to merge the result into")
    args = ap.parse_args(argv)

    entry = autotune(
        args.height, args.width, args.bins,
        memory_budget_bytes=args.budget, repeats=args.repeats,
    )
    configs = dict(load_priors(args.out))
    key = config_key(args.height, args.width, args.bins)
    configs[key] = entry
    save_priors(args.out, configs)
    print(f"{key}: {entry}")
    print(f"wrote {args.out} — export {ENV_VAR}={args.out} to use it")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
