"""Double-buffered frame pipeline — paper §4.4 (dual-buffering, Fig. 12-14).

The paper overlaps (disk -> host), (host -> device), kernel execution and
(device -> host) across a frame sequence using two CUDA streams with
page-locked memory.  The JAX/TPU equivalent lives in ``core/runtime.py``
(one async scheduler: bounded in-flight window, microbatching, carry
threading, device prefetch); this module keeps the historical entry
points as thin adapters over it:

  * ``DoubleBufferedExecutor`` — ``depth`` dispatches in flight,
    ``batch_size`` frames stacked per dispatch.  depth=1 degenerates to
    fully synchronous execution (the "no dual-buffering" baseline of
    Fig. 13); on real TPUs the same code overlaps PCIe/DCN infeed with
    TPU compute, on CPU it overlaps host staging with XLA:CPU's async
    execution (benchmarks/bench_pipeline.py).
  * ``prefetch_to_device`` / ``prefetch_row_bands`` — the H2D staging
    half of the overlap, for consumers that drive their own compute.

Microbatch *sizing* lives in the planner (``core/engine.py``), which
owns ``auto_batch_size``; it is re-exported here for compatibility.
"""

from __future__ import annotations

from typing import Callable, Iterable, Iterator

import jax

from repro.core.runtime import FrameRuntime, stack_chunks, stage_stream

# Re-exports: sizing moved into the planner (core/engine.py) with PR 5;
# chunking moved into the runtime.  Import them from their new homes in
# new code.
from repro.core.engine import auto_batch_size  # noqa: F401

__all__ = [
    "DoubleBufferedExecutor",
    "auto_batch_size",
    "stack_chunks",
    "prefetch_to_device",
    "iter_row_bands",
    "prefetch_row_bands",
]


class DoubleBufferedExecutor:
    """Apply a jitted fn over a stream of host frames with dispatch-ahead.

    A thin adapter over ``runtime.FrameRuntime`` (the §4.4 scheduler).

    Args:
      fn: jitted callable.  With ``batch_size > 1`` it must accept stacked
        (k, *frame_shape) inputs and return outputs whose leading axis is
        the frame axis (``integral_histogram`` and ``IntegralHistogram``
        both do).
      depth: number of dispatches kept in flight (1 = synchronous).
      batch_size: frames stacked per dispatch.  The final chunk of a
        stream may be smaller (one extra compile for the ragged tail).
    """

    def __init__(
        self, fn: Callable, depth: int = 2, device=None, batch_size: int = 1
    ):
        if depth < 1:
            raise ValueError("depth must be >= 1")
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        self.fn = fn
        self.depth = depth
        self.batch_size = batch_size
        self.device = device or jax.devices()[0]

    def _runtime(self) -> FrameRuntime:
        return FrameRuntime(
            FrameRuntime.stateless(self.fn),
            depth=self.depth,
            microbatch=self.batch_size,
            device=self.device,
        )

    def map(self, frames: Iterable) -> Iterator[jax.Array]:
        """Yield fn(frame) per input frame, `depth` dispatches in flight.

        With ``batch_size > 1`` each dispatch covers ``batch_size`` frames,
        but the iterator still yields one result per frame, in order.
        """
        return self._runtime().map_frames(frames)


def prefetch_to_device(
    frames: Iterable, size: int = 2, device=None
) -> Iterator[jax.Array]:
    """Stage host arrays onto the device ahead of consumption (training
    input pipeline building block).  Exactly ``size`` frames are staged
    before the first yield, and at most ``size`` frames are ever resident
    beyond the one in the consumer's hands.  Device-memory commitment is
    bounded by ``size``; for ``k`` transfers overlapping the consumer's
    compute in steady state, pass ``size=k + 1``."""
    return stage_stream(frames, size=size, device=device)


def iter_row_bands(image, spans) -> Iterator:
    """Host-side row-band slices ``image[..., r0:r1, :]`` of a frame or
    stack, one per (r0, r1) span (core/bands.py plans the spans)."""
    for r0, r1 in spans:
        yield image[..., r0:r1, :]


def prefetch_row_bands(image, spans, size: int = 2, device=None) -> Iterator:
    """Band-aware prefetch: stage the next band's image slice onto the
    device while the current band's kernel runs — the §4.4 dual-buffering
    idea applied inside one large frame instead of across a frame stream.
    Device commitment is bounded by ``size`` band slices (plus the one the
    consumer holds); the full frame never leaves the host."""
    return stage_stream(iter_row_bands(image, spans), size=size, device=device)
