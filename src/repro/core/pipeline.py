"""Double-buffered frame pipeline — paper §4.4 (dual-buffering, Fig. 12-14).

The paper overlaps (disk -> host), (host -> device), kernel execution and
(device -> host) across a frame sequence using two CUDA streams with
page-locked memory.  The JAX/TPU equivalent:

  * XLA dispatch is asynchronous: enqueueing a jitted computation returns
    immediately; only blocking on results synchronizes.
  * `DoubleBufferedExecutor` keeps `depth` dispatches in flight — it stages
    the next chunk onto the device (device_put ~ cudaMemcpyAsync H2D) while
    the kernel for the current chunk runs, and only blocks on the oldest
    in-flight result (~ D2H of the previous integral histogram).
  * depth=1 degenerates to fully synchronous execution — the "no
    dual-buffering" baseline of Fig. 13.
  * `batch_size` > 1 microbatches: frames are stacked on the host and
    dispatched `batch_size` at a time through a single batched computation
    (the rank-polymorphic `integral_histogram` accepts (n, h, w) stacks).
    This amortizes per-dispatch overhead the same way Koppaka et al.'s
    adaptive CUDA streams batch histogram work — on CPU/XLA it is where
    most of the frames/sec headroom lives (benchmarks/bench_batched.py).

On real TPUs the same code overlaps PCIe/DCN infeed with TPU compute; on
CPU it overlaps host staging with XLA:CPU's async execution, which is what
benchmarks/bench_pipeline.py measures.
"""

from __future__ import annotations

import collections
from typing import Callable, Iterable, Iterator

import jax
import numpy as np

# "auto" microbatching targets this per-dispatch output footprint — roughly
# an LLC's worth, the crossover between dispatch-bound and cache-bound
# regimes measured in benchmarks/bench_batched.py.
_AUTO_BATCH_BYTES = 4 << 20


def stack_chunks(
    frames: Iterable[np.ndarray], batch_size: int
) -> Iterator[np.ndarray]:
    """Group a frame stream into stacked (<= batch_size, ...) host arrays
    (ragged final chunk included).  Shared by the executor's microbatching
    and ``FragmentTracker.track``."""
    buf: list = []
    for frame in frames:
        buf.append(np.asarray(frame))
        if len(buf) == batch_size:
            yield np.stack(buf)
            buf = []
    if buf:
        yield np.stack(buf)


def auto_batch_size(num_bins: int, h: int, w: int) -> int:
    """Frames per dispatch from the per-frame (num_bins, h, w) fp32 H
    footprint: ROI-scale frames are dispatch-bound and batch deep, full
    frames are cache-bound and stay near 1 (the adaptive-batching idea of
    Koppaka et al., arXiv:1011.0235, restated for XLA dispatch).  The
    planner (core/engine.py) owns the microbatch decision and calls this;
    ``IntegralHistogram.map_frames`` asks the planner, while
    ``FragmentTracker.track`` still sizes its scan chunks here directly."""
    per_frame_bytes = 4 * num_bins * h * w
    return max(1, min(16, _AUTO_BATCH_BYTES // per_frame_bytes))


class DoubleBufferedExecutor:
    """Apply a jitted fn over a stream of host frames with dispatch-ahead.

    Args:
      fn: jitted callable.  With ``batch_size > 1`` it must accept stacked
        (k, *frame_shape) inputs and return outputs whose leading axis is
        the frame axis (``integral_histogram`` and ``IntegralHistogram``
        both do).
      depth: number of dispatches kept in flight (1 = synchronous).
      batch_size: frames stacked per dispatch.  The final chunk of a
        stream may be smaller (one extra compile for the ragged tail).
    """

    def __init__(
        self, fn: Callable, depth: int = 2, device=None, batch_size: int = 1
    ):
        if depth < 1:
            raise ValueError("depth must be >= 1")
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        self.fn = fn
        self.depth = depth
        self.batch_size = batch_size
        self.device = device or jax.devices()[0]

    # -- internals ---------------------------------------------------------
    def _chunks(self, frames: Iterable[np.ndarray]) -> Iterator[np.ndarray]:
        """Group the stream into (batch_size, ...) stacks (or raw frames)."""
        if self.batch_size == 1:
            yield from frames
            return
        yield from stack_chunks(frames, self.batch_size)

    def _ready(self, out, is_batch: bool) -> Iterator[jax.Array]:
        out = jax.block_until_ready(out)              # ~ D2H sync point
        if is_batch:
            # Per-frame views of an already-materialized device array —
            # indexing is cheap; no extra host round-trips.
            for i in range(out.shape[0]):
                yield out[i]
        else:
            yield out

    # -- public ------------------------------------------------------------
    def map(self, frames: Iterable[np.ndarray]) -> Iterator[jax.Array]:
        """Yield fn(frame) per input frame, `depth` dispatches in flight.

        With ``batch_size > 1`` each dispatch covers ``batch_size`` frames,
        but the iterator still yields one result per frame, in order.
        """
        is_batch = self.batch_size > 1
        inflight: collections.deque = collections.deque()
        for chunk in self._chunks(frames):
            staged = jax.device_put(chunk, self.device)   # async H2D
            inflight.append(self.fn(staged))              # async dispatch
            if len(inflight) >= self.depth:
                yield from self._ready(inflight.popleft(), is_batch)
        while inflight:
            yield from self._ready(inflight.popleft(), is_batch)


def prefetch_to_device(
    frames: Iterable[np.ndarray], size: int = 2, device=None
) -> Iterator[jax.Array]:
    """Stage host arrays onto the device ahead of consumption (training
    input pipeline building block).  Exactly ``size`` frames are staged
    before the first yield, and at most ``size`` frames are ever resident
    beyond the one in the consumer's hands.  Device-memory commitment is
    bounded by ``size``; for ``k`` transfers overlapping the consumer's
    compute in steady state, pass ``size=k + 1``."""
    device = device or jax.devices()[0]
    queue: collections.deque = collections.deque()
    for frame in frames:
        queue.append(jax.device_put(frame, device))
        # yield once exactly `size` frames are staged — `> size` would
        # hold size + 1 frames on device before the first yield
        if len(queue) >= size:
            yield queue.popleft()
    while queue:
        yield queue.popleft()


def iter_row_bands(image, spans) -> Iterator:
    """Host-side row-band slices ``image[..., r0:r1, :]`` of a frame or
    stack, one per (r0, r1) span (core/bands.py plans the spans)."""
    for r0, r1 in spans:
        yield image[..., r0:r1, :]


def prefetch_row_bands(image, spans, size: int = 2, device=None) -> Iterator:
    """Band-aware prefetch: stage the next band's image slice onto the
    device while the current band's kernel runs — the §4.4 dual-buffering
    idea applied inside one large frame instead of across a frame stream.
    Device commitment is bounded by ``size`` band slices (plus the one the
    consumer holds); the full frame never leaves the host."""
    return prefetch_to_device(iter_row_bands(image, spans), size, device)
