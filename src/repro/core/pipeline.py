"""Double-buffered frame pipeline — paper §4.4 (dual-buffering, Fig. 12-14).

The paper overlaps (disk -> host), (host -> device), kernel execution and
(device -> host) across a frame sequence using two CUDA streams with
page-locked memory.  The JAX/TPU equivalent:

  * XLA dispatch is asynchronous: enqueueing a jitted computation returns
    immediately; only blocking on results synchronizes.
  * `DoubleBufferedExecutor` keeps `depth` frames in flight — it stages
    frame t+1 onto the device (device_put ~ cudaMemcpyAsync H2D) while the
    kernel for frame t runs, and only blocks on frame t-depth+1's result
    (~ D2H of the previous integral histogram).
  * depth=1 degenerates to fully synchronous execution — the "no
    dual-buffering" baseline of Fig. 13.

On real TPUs the same code overlaps PCIe/DCN infeed with TPU compute; on
CPU it overlaps host staging with XLA:CPU's async execution, which is what
benchmarks/bench_pipeline.py measures.
"""

from __future__ import annotations

import collections
from typing import Callable, Iterable, Iterator

import jax
import numpy as np


class DoubleBufferedExecutor:
    """Apply a jitted fn over a stream of host frames with dispatch-ahead."""

    def __init__(self, fn: Callable, depth: int = 2, device=None):
        if depth < 1:
            raise ValueError("depth must be >= 1")
        self.fn = fn
        self.depth = depth
        self.device = device or jax.devices()[0]

    def map(self, frames: Iterable[np.ndarray]) -> Iterator[jax.Array]:
        """Yield fn(frame) for each frame, keeping `depth` frames in flight."""
        inflight: collections.deque = collections.deque()
        for frame in frames:
            staged = jax.device_put(frame, self.device)   # async H2D
            inflight.append(self.fn(staged))              # async dispatch
            if len(inflight) >= self.depth:
                out = inflight.popleft()
                out.block_until_ready()                   # ~ D2H sync point
                yield out
        while inflight:
            out = inflight.popleft()
            out.block_until_ready()
            yield out


def prefetch_to_device(
    frames: Iterable[np.ndarray], size: int = 2, device=None
) -> Iterator[jax.Array]:
    """Stage host arrays onto the device `size` steps ahead of consumption
    (training input pipeline building block; see data/prefetch.py)."""
    device = device or jax.devices()[0]
    queue: collections.deque = collections.deque()
    for frame in frames:
        queue.append(jax.device_put(frame, device))
        if len(queue) > size:
            yield queue.popleft()
    while queue:
        yield queue.popleft()
