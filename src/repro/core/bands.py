"""Band-streamed integral histograms under a host memory budget.

The paper's headline scale scenario (§4.6) is a 64 MB frame at 128 bins
whose integral histogram is 32 GB — far beyond one device's memory.
``spatial_sharded_ih`` reaches that regime by sharding rows across a mesh;
this module reaches it on ONE host by streaming row bands through the
carry-aware kernels — the band/strip decomposition with boundary carries
and reduced-width accumulator storage of Ehsan et al. (arXiv:1510.05138,
arXiv:1510.05142), i.e. the WF-TiS column carry lifted from VMEM scratch
to a host-orchestrated (b, w) aggregate between bands.

The composition rule: an integral histogram is a prefix sum over rows, so
for a band starting at row r0,

    H[r, c, b] = H_band[r - r0, c, b] + H[r0 - 1, c, b]

The whole cross-band dependency is one (..., b, w) bottom-row carry.  All
arithmetic is integer-valued fp32 (exact below 2**24 counts), so banded
results are bit-exact vs the monolithic computation — asserted, not
approximated, in tests/test_bands.py.

Three consumption modes, none of which materializes the (b, h, w) H:

  * stream — ``iter_banded_ih`` yields ``BandH`` chunks to a consumer
    (the banded O(1) queries in core/region_query.py consume these);
  * spill  — ``spill_banded_ih`` stores bands host-side under a storage
    policy.  ``float32`` keeps counts exact below 2**24; the reduced-width
    integer policies wrap modularly (``uint16`` halves the footprint and
    any four-corner query over a region of <= 65535 pixels stays exact
    despite the wraparound — the embedded-systems accumulator trick of
    arXiv:1510.05142; validated at query time);
  * reduce — ``reduce_banded_ih`` folds bands into an accumulator while
    only ever holding one band.

``plan_bands`` turns ``memory_budget_bytes`` into band spans;
``kernels/ops.integral_histogram(memory_budget_bytes=...)`` uses the same
plan to bound its transient working set while still assembling full H.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Iterator

import jax.numpy as jnp
import numpy as np

from repro.core.hsource import HSource
from repro.kernels.ops import integral_histogram

# fp32 represents consecutive integers exactly only below 2**24; beyond it
# the accumulated counts themselves (not just a storage cast) are wrong.
FP32_EXACT_COUNT = 1 << 24

# Storage policies for spilled bands: numpy dtype + the largest region
# pixel count a four-corner query is guaranteed exact for.  Integer
# policies wrap modulo 2**bits, and modular arithmetic cancels the wrap
# for any query whose true count fits — so the bound is on the *queried
# region*, not the frame.
STORAGE_POLICIES = {
    "float32": (np.float32, FP32_EXACT_COUNT - 1),
    "uint32": (np.uint32, (1 << 32) - 1),
    "uint16": (np.uint16, (1 << 16) - 1),
}


def validate_storage_policy(storage: str, h: int, w: int) -> None:
    """Validate a spill policy against the count bound of an (h, w) frame.

    The kernels accumulate in fp32, so any frame whose total pixel count
    reaches 2**24 has inexact counts before storage even starts — no
    policy can recover that; shard spatially (core/distributed.py)
    instead.  ``uint16``'s additional <= 65535-pixel *region* bound is
    enforced at query time (``SpilledIH.region_histogram``).
    """
    if storage not in STORAGE_POLICIES:
        raise ValueError(
            f"unknown storage policy {storage!r} "
            f"(valid: {sorted(STORAGE_POLICIES)})"
        )
    if h * w >= FP32_EXACT_COUNT:
        raise ValueError(
            f"{h}x{w} frame accumulates counts up to {h * w}, beyond the "
            f"fp32 exact-integer range 2**24; no storage policy recovers "
            "exactness — use spatial sharding (core/distributed.py)"
        )


@dataclasses.dataclass(frozen=True)
class BandPlan:
    """Row-band decomposition of an (h, w) frame under a memory budget."""

    spans: tuple[tuple[int, int], ...]  # [r0, r1) per band
    band_h: int                         # nominal rows per band
    band_bytes: int                     # largest band's H footprint
    full_h_bytes: int                   # the monolithic (n, b, h, w) H

    @property
    def num_bands(self) -> int:
        return len(self.spans)


def plan_bands(
    h: int,
    w: int,
    num_bins: int,
    *,
    band_h: int | None = None,
    memory_budget_bytes: int | None = None,
    num_frames: int = 1,
    itemsize: int = 4,
    row_multiple: int = 1,
) -> BandPlan:
    """Choose band spans from an explicit ``band_h`` or a byte budget.

    The budget caps the per-band H footprint
    ``itemsize * num_frames * num_bins * band_h * w``; ``row_multiple``
    rounds the band height down to a multiple (the spatially-sharded
    composition needs bands divisible by the row-shard count).
    """
    if band_h is None:
        if memory_budget_bytes is None:
            band_h = h
        else:
            per_row = itemsize * num_frames * num_bins * w
            band_h = memory_budget_bytes // per_row
            if band_h < max(1, row_multiple):
                raise ValueError(
                    f"memory_budget_bytes={memory_budget_bytes} below one "
                    f"{max(1, row_multiple)}-row band "
                    f"({per_row * max(1, row_multiple)} bytes at "
                    f"{num_frames}x{num_bins} bins x width {w})"
                )
    band_h = min(int(band_h), h)
    if row_multiple > 1:
        band_h -= band_h % row_multiple
    if band_h < 1:
        raise ValueError(f"band_h must be >= 1, got {band_h}")
    spans = tuple((r, min(r + band_h, h)) for r in range(0, h, band_h))
    per_row = itemsize * num_frames * num_bins * w
    return BandPlan(
        spans=spans,
        band_h=band_h,
        band_bytes=per_row * band_h,
        full_h_bytes=per_row * h,
    )


@dataclasses.dataclass(frozen=True)
class BandH:
    """One streamed band of an integral histogram.

    ``H`` holds the full-frame H restricted to rows [r0, r1): shape
    (..., b, r1 - r0, w).  ``carry`` is its bottom row (..., b, w) — the
    only state the next band needs.  ``frame_h`` is the full frame height
    so consumers can size window lattices without exhausting the iterator.
    """

    index: int
    num_bands: int
    r0: int
    r1: int
    frame_h: int
    H: jnp.ndarray
    carry: jnp.ndarray

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.H.shape)) * self.H.dtype.itemsize


def iter_banded_ih(
    image,
    num_bins: int,
    *,
    band_h: int | None = None,
    memory_budget_bytes: int | None = None,
    plan: BandPlan | None = None,
    carry_in: jnp.ndarray | None = None,
    compute_fn: Callable | None = None,
    prefetch: int = 0,
    device=None,
    method: str = "wf_tis",
    backend: str = "auto",
    tile: int = 128,
    bin_block: int = 8,
    use_mxu: bool = True,
    interpret: bool = False,
    value_range: int = 256,
) -> Iterator[BandH]:
    """Stream the integral histogram of ``image`` as row bands.

    ``image`` is (h, w) or (n, h, w) (numpy or jax; large frames stay on
    the host and only band slices are staged).  Bands follow ``plan`` or
    are planned from ``band_h`` / ``memory_budget_bytes``; the carry is
    threaded through the carry-aware kernels between dispatches.

    ``compute_fn(band_image, carry_in) -> H_band`` overrides the kernel
    call — core/distributed.py uses this to run every band bin- or
    spatially-sharded with the same carry chain.  ``prefetch >= 1`` keeps
    that many band image slices staged on device ahead of the one
    computing (the §4.4 overlap applied inside one large frame).
    ``device`` is any staging placement (``Device`` or ``Sharding``);
    when given, slices are staged even at ``prefetch=0`` — a
    ``NamedSharding`` commits each slice to the layout a sharded
    compute_fn's shard_map consumes.

    The loop itself is ``runtime.FrameRuntime`` with the (b, w)
    bottom-row carry threaded between dispatches; this function only
    shapes each retired dispatch into a ``BandH``.
    """
    from repro.core.runtime import FrameRuntime

    h, w = image.shape[-2:]
    num_frames = int(np.prod(image.shape[:-2], dtype=np.int64)) or 1
    if plan is None:
        plan = plan_bands(
            h, w, num_bins,
            band_h=band_h, memory_budget_bytes=memory_budget_bytes,
            num_frames=num_frames,
        )
    if compute_fn is None:
        def compute_fn(band_img, carry):
            return integral_histogram(
                band_img, num_bins, method=method, backend=backend,
                tile=tile, bin_block=bin_block, use_mxu=use_mxu,
                interpret=interpret, value_range=value_range,
                carry_in=carry,
            )

    def step(band_img, carry):
        H_band = compute_fn(band_img, carry)
        return H_band, H_band[..., -1, :]

    # Band slices are staged whenever a placement is known or prefetch is
    # requested.  ``device`` may be a single ``Device`` or a ``Sharding``:
    # a sharded compute_fn (iter_banded_sharded_ih) passes the
    # ``NamedSharding`` its shard_map expects, so slices arrive already
    # committed to the mesh layout instead of bouncing through one device
    # — the old "stage only when prefetch >= 1" carve-out is gone.
    runtime = FrameRuntime(
        step, depth=1, carry_in=carry_in, device=device,
        stage_inputs=prefetch >= 1 or device is not None,
        stage_ahead=max(prefetch, 0),
        block=False,
    )
    slices: Iterable = (image[..., r0:r1, :] for r0, r1 in plan.spans)
    for d in runtime.run(slices, batched=False,
                         meta=lambda i, c, ch: plan.spans[i]):
        r0, r1 = d.meta
        yield BandH(
            index=d.index, num_bands=plan.num_bands, r0=r0, r1=r1,
            frame_h=h, H=d.out, carry=d.carry,
        )


def banded_integral_histogram(image, num_bins: int, **kwargs) -> jnp.ndarray:
    """Assemble full H from the band stream (parity oracle + the target of
    ``integral_histogram(memory_budget_bytes=...)``'s auto-banding: the
    result still materializes, but the per-dispatch working set — one-hot
    masks, transposes, scan intermediates — is bounded to a band).

    Assembly is host-side (each band pulled with ``np.asarray``, then one
    ``np.concatenate``): under jax 0.4.37 a device-side concat over bands
    whose donors live on different devices silently mis-assembles (the
    hazard core/hsource.py:28 documents and the sharded-concat lint rule
    enforces)."""
    pieces = [
        np.asarray(band.H)
        for band in iter_banded_ih(image, num_bins, **kwargs)
    ]
    return jnp.asarray(np.concatenate(pieces, axis=-2))


def reduce_banded_ih(image, num_bins: int, reduce_fn, init=None, **kwargs):
    """Fold ``reduce_fn(acc, band)`` over the band stream — O(band) memory."""
    acc = init
    for band in iter_banded_ih(image, num_bins, **kwargs):
        acc = reduce_fn(acc, band)
    return acc


@dataclasses.dataclass
class SpilledIH(HSource):
    """A banded integral histogram spilled host-side under a storage policy.

    ``bands[i]`` holds rows ``spans[i]`` as (..., b, bh, w) in the policy
    dtype.  Integer policies store H modulo 2**bits; four-corner queries
    run in the same modular arithmetic, so any region whose true count
    fits the dtype reads back exactly (``uint16``: <= 65535 pixels).

    An ``HSource`` (core/hsource.py): every unified analytics entry point
    — region queries, sliding windows, likelihood maps, multi-scale
    search — runs straight off the spill through ``rows()``, with the
    policy's exact-count bound enforced per query.
    """

    num_bins: int
    height: int
    width: int
    lead: tuple
    storage: str
    spans: tuple[tuple[int, int], ...]
    bands: list
    # Per-band true-valued fp32 bottom rows (..., b, w) — the carry
    # chain the incremental video path (core/delta.py) needs: integer
    # policies store H modularly, so the real carries cannot be
    # recovered from ``bands`` and are retained at spill time instead.
    # ``None`` on spills predating carry retention (not updatable).
    carries: list | None = None

    @property
    def nbytes(self) -> int:
        total = sum(b.nbytes for b in self.bands)
        if self.carries is not None:
            total += sum(c.nbytes for c in self.carries)
        return total

    @property
    def exact_region_bound(self) -> int:
        return STORAGE_POLICIES[self.storage][1]

    def _band_of(self, r: int) -> int:
        for i, (r0, r1) in enumerate(self.spans):
            if r0 <= r < r1:
                return i
        raise IndexError(f"row {r} outside frame of height {self.height}")

    def rows(self, row_ids) -> np.ndarray:
        """Gather full-frame H rows (..., b, len(row_ids), w), policy dtype."""
        dtype, _ = STORAGE_POLICIES[self.storage]
        out = np.empty(
            self.lead + (self.num_bins, len(row_ids), self.width), dtype
        )
        for k, r in enumerate(row_ids):
            i = self._band_of(int(r))
            out[..., k, :] = self.bands[i][..., int(r) - self.spans[i][0], :]
        return out

    # region_histogram / sliding windows / likelihood maps are inherited
    # from HSource: Eq. 2 against rows(), area-validated per query against
    # exact_region_bound, modular through the integer policies.

    def assemble(self) -> np.ndarray:
        """Materialize full (..., b, h, w) H as fp32 (small frames only)."""
        return np.concatenate(
            [b.astype(np.float32) for b in self.bands], axis=-2
        )

    def dense(self):
        return jnp.asarray(self.assemble())

    def update_bands(self, next_frame, report, *, recompute,
                     apply_fn=None) -> "SpilledIH":
        """The incremental-video hook (core/delta.py): a new SpilledIH
        for ``next_frame`` in the same storage policy — dirty bands
        recomputed and re-spilled, clean bands below corrected in the
        policy's own modular arithmetic (``apply_fn`` is accepted for
        hook-signature uniformity; the spill update is host-side)."""
        from repro.core import delta as delta_mod

        del apply_fn
        return delta_mod.update_spilled_ih(
            self, next_frame, report, recompute=recompute,
        )


def spill_banded_ih(
    image, num_bins: int, *, storage: str = "float32", **kwargs
) -> SpilledIH:
    """Compute the banded H and spill every band host-side under
    ``storage`` (validated against the count bound up front)."""
    h, w = image.shape[-2:]
    validate_storage_policy(storage, h, w)
    dtype, _ = STORAGE_POLICIES[storage]
    spans, bands, carries = [], [], []
    for band in iter_banded_ih(image, num_bins, **kwargs):
        arr = np.asarray(band.H)
        # The true-valued bottom row, BEFORE any storage cast — the
        # carry chain the incremental update path (core/delta.py)
        # threads through clean bands.
        carries.append(arr[..., -1, :].astype(np.float32))
        if dtype is not np.float32:
            # Counts are exact integers in fp32 here (validated above);
            # reduce the width by an explicit modular cast.
            arr = np.mod(arr.astype(np.int64), np.int64(np.iinfo(dtype).max) + 1)
            arr = arr.astype(dtype)
        else:
            arr = arr.astype(np.float32)
        spans.append((band.r0, band.r1))
        bands.append(arr)
    return SpilledIH(
        num_bins=num_bins, height=h, width=w,
        lead=tuple(image.shape[:-2]), storage=storage,
        spans=tuple(spans), bands=bands, carries=carries,
    )




