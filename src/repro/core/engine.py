"""Plan/execute engine: one entry point over the four execution paths.

PRs 1-3 left callers hand-selecting among seven functions — monolithic
``kernels/ops.integral_histogram``, batched ``map_frames``, banded
``core/bands.py``, sharded ``core/distributed.py``, each with forked
analytics.  The paper treats these as ONE computation under different
resource mappings (§4's four kernel mappings, §4.4 double-buffering,
§4.6 multi-GPU bin mapping); this module makes that explicit:

    spec = WorkloadSpec(height=480, width=640, num_bins=32,
                        memory_budget_bytes=64 << 20)
    p = plan(spec)            # deterministic, inspectable, testable
    print(p.explain())        # why this method/backend/band/shard choice

``plan`` absorbs the decisions previously buried in call sites:

  * method/backend/tile resolution (``integral_histogram``'s "auto");
  * microbatch sizing (``auto_batch_size``, which now lives here —
    arXiv:1011.0235's adaptive batching; ``adaptive_microbatch=True``
    additionally lets the runtime retune the size online);
  * band planning + storage policy under ``memory_budget_bytes``
    (``bands.plan_bands`` — the auto-banding that lived inside
    ``integral_histogram``), following Ehsan et al.'s memory-efficient
    design (arXiv:1510.05138);
  * sharding layout when a mesh is given (bin sharding — the paper's
    multi-GPU scheme — when the bins divide the mesh axis, else spatial).

``HistogramEngine`` composes plan -> compute -> query: ``engine.run``
returns an ``HSource`` (core/hsource.py) plus the results of any queries,
and the representation behind it — dense array, band stream, host spill,
or mesh-sharded — is the planner's choice, not the caller's.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator

import jax
import numpy as np

from repro.core import autotune
from repro.core import delta as delta_mod
from repro.core.bands import (
    BandPlan,
    STORAGE_POLICIES,
    SpilledIH,
    plan_bands,
    validate_storage_policy,
)
from repro.core.hsource import (
    BandedH,
    DenseH,
    FusedRowsH,
    HSource,
    PrefetchedRowsH,
    ShardedH,
)

REPRESENTATIONS = ("dense", "banded", "spilled", "sharded", "fused")

# Ehsan-style compute-vs-store bound: fuse the queries into the scan
# (never store H) when the request's corner-row union is at most this
# fraction of the frame height.  At 1/4 the fused row slab is at most
# per_frame_h_bytes / 4 and the early-exit scan skips whole bands, so
# fusion strictly dominates; past it, re-running the scan for follow-up
# queries starts losing to storing H once.
_FUSE_ROW_FRACTION = 4

# "auto" microbatching targets this per-dispatch output footprint — roughly
# an LLC's worth, the crossover between dispatch-bound and cache-bound
# regimes measured in benchmarks/bench_batched.py.
_AUTO_BATCH_BYTES = 4 << 20

# Dirty-row fraction above which an incremental update of a cached
# predecessor H stops paying and plan() recomputes (tunable per
# geometry via the "delta_threshold" priors key).
_DELTA_DIRTY_THRESHOLD = delta_mod.DEFAULT_DIRTY_THRESHOLD


class PlanValidationError(ValueError):
    """A plan failed static validation (repro.analysis.plancheck) — the
    dispatch would have failed or silently produced invalid counts."""


def auto_batch_size(num_bins: int, h: int, w: int) -> int:
    """Frames per dispatch from the per-frame (num_bins, h, w) fp32 H
    footprint: ROI-scale frames are dispatch-bound and batch deep, full
    frames are cache-bound and stay near 1 (the adaptive-batching idea of
    Koppaka et al., arXiv:1011.0235, restated for XLA dispatch).  The
    planner owns this decision — it seeds every plan's ``microbatch``,
    and ``adaptive_microbatch`` plans use it as the starting size the
    runtime's online controller tunes from there."""
    per_frame_bytes = 4 * num_bins * h * w
    return max(1, min(16, _AUTO_BATCH_BYTES // per_frame_bytes))


# ---------------------------------------------------------------------------
# spec
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """Everything the planner needs to know about a request.

    ``num_frames`` is the request's batch/stream arity: frames per call
    for stacked requests, ``None`` for an open-ended stream (microbatch
    then comes purely from the per-frame footprint).  ``mesh`` switches
    to the multi-device mappings; ``memory_budget_bytes`` bounds the live
    H footprint (banding); ``storage`` selects a host spill policy
    (core/bands.py STORAGE_POLICIES) and implies the spilled
    representation."""

    height: int
    width: int
    num_bins: int = 32
    num_frames: int | None = 1
    dtype: str = "uint8"
    value_range: int = 256
    method: str = "wf_tis"
    backend: str = "auto"
    tile: int = 128
    bin_block: int = 8
    use_mxu: bool = True
    interpret: bool = False
    memory_budget_bytes: int | None = None
    storage: str | None = None
    adaptive_microbatch: bool = False   # retune batch size online
    mesh: object | None = None          # jax.sharding.Mesh
    sharding: str = "auto"              # "auto" | "bin" | "spatial"
    bin_axis: str = "model"
    row_axis: str = "data"
    # The corner-row union of the request's declared queries (sorted,
    # ascending, within [0, height)), or None when the queries are not
    # known up front.  This is the input to the Ehsan compute-vs-store
    # decision: a small-enough union lets plan() fuse the queries into
    # the scan and never store H.  engine.run() fills it automatically
    # from the queries' needed_rows declarations.
    query_rows: tuple[int, ...] | None = None
    # Fraction of frame rows in dirty bands vs a cached predecessor H
    # (core/delta.py diff_bands), or None when no predecessor is
    # available.  Small enough -> plan() chooses the incremental path:
    # update the cached H instead of recomputing.  engine.run(prev=...)
    # fills it automatically.
    dirty_fraction: float | None = None

    @property
    def per_frame_h_bytes(self) -> int:
        """The (num_bins, h, w) fp32 H footprint of one frame."""
        return 4 * self.num_bins * self.height * self.width


# ---------------------------------------------------------------------------
# plan
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MeshLayout:
    """The planner's 2-D serving layout over a mesh (paper §4.6 run as a
    serving system): frame-parallel **replica groups** along every mesh
    axis the shard mapping does not consume, times bin/spatial sharding
    within each group.  ``explain()`` renders it, plancheck validates it
    (axes exist, disjoint, and the product covers the mesh), and
    ``serve.DistributedAnalyticsService`` executes it — one
    ``AnalyticsService`` per replica group over that group's submesh
    (``distributed.replica_meshes``)."""

    kind: str                        # "bin" | "spatial" (within-group)
    shard_axis: str                  # mesh axis the shard mapping uses
    shards_per_group: int            # devices per replica group
    replica_axes: tuple              # frame-parallel axes (may be empty)
    num_groups: int                  # product of the replica axes' sizes

    def describe(self) -> str:
        over = (" x ".join(repr(a) for a in self.replica_axes)
                or "(no free axis)")
        return (
            f"{self.num_groups} replica group(s) over {over} x "
            f"{self.kind} sharding over {self.shard_axis!r} "
            f"({self.shards_per_group} device(s)/group)"
        )


def choose_layout(mesh, kind: str, *, bin_axis: str = "model",
                  row_axis: str = "data") -> MeshLayout:
    """Derive the replica x shard layout from the mesh shape: the shard
    mapping consumes one axis (bins or row strips); every other axis is
    frame-parallel replication — the flax-imagenet scaling idiom
    (throughput = per-group rate x ``num_groups``) applied to frames
    instead of batch elements."""
    shape = dict(mesh.shape)
    shard_axis = bin_axis if kind == "bin" else row_axis
    replica_axes = tuple(a for a in mesh.axis_names if a != shard_axis)
    num_groups = 1
    for a in replica_axes:
        num_groups *= shape[a]
    return MeshLayout(
        kind=kind, shard_axis=shard_axis,
        shards_per_group=shape.get(shard_axis, 1),
        replica_axes=replica_axes, num_groups=num_groups,
    )


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """The planner's resolved decisions — inspectable and testable.

    ``representation`` names the HSource the engine will build; the
    remaining fields are the knobs the execute step passes down.  Plans
    are plain frozen dataclasses: equal specs produce equal plans
    (asserted in tests/test_engine.py)."""

    spec: WorkloadSpec
    representation: str        # dense | banded | spilled | sharded | fused
    method: str
    backend: str                        # resolved: "pallas" | "jnp"
    tile: int
    bin_block: int
    microbatch: int
    band_plan: BandPlan | None
    storage: str | None
    sharding: str | None                # None | "bin" | "spatial"
    microbatch_mode: str = "fixed"      # "fixed" | "adaptive"
    tuned: str | None = None            # autotune priors key, if applied
    incremental: bool = False           # update a cached predecessor H
    layout: MeshLayout | None = None    # replica x shard serving layout

    def explain(self, verdict=None) -> str:
        """Human-readable plan rationale (golden-snapshot tested).

        ``verdict`` (a ``repro.analysis.plancheck.PlanVerdict``, e.g.
        ``engine.last_verdict``) appends the static feasibility verdict
        to the rationale; the default output is unchanged."""
        s = self.spec
        per_frame = s.per_frame_h_bytes
        lines = [
            "ExecutionPlan",
            f"  workload        : {s.height}x{s.width} {s.dtype} frames, "
            f"{s.num_bins} bins, "
            + ("open stream" if s.num_frames is None
               else f"{s.num_frames} frame(s)/request"),
            f"  full H          : {per_frame} B/frame "
            f"({per_frame / 2**20:.1f} MiB fp32)",
            f"  representation  : {self.representation}",
        ]
        if self.incremental:
            df = s.dirty_fraction or 0.0
            recomputed = int(round(df * per_frame))
            lines.append(
                f"  incremental     : update — dirty fraction {df:.2f} "
                f"within threshold; recompute ~{recomputed} B/frame, "
                f"reuse ~{per_frame - recomputed} B/frame of cached H"
            )
        if s.query_rows is not None:
            k = len(s.query_rows)
            nf = 1 if s.num_frames is None else s.num_frames
            if self.representation == "fused":
                rows_b = 4 * nf * s.num_bins * k * s.width
                lines.append(
                    f"  query fusion    : fuse — {k} corner row(s) "
                    f"({rows_b} B) << full H {per_frame} B; H never stored"
                )
            else:
                bound = s.height // _FUSE_ROW_FRACTION
                why = (
                    f"{k} corner row(s) exceed the fuse bound "
                    f"({bound} rows)"
                    if k > bound else
                    f"{k} corner row(s), but the request pins another path"
                )
                lines.append(
                    f"  query fusion    : store — {why}; fall back to "
                    f"{self.representation}"
                )
        lines += [
            f"  method/backend  : {self.method} / {self.backend}",
            f"  tile/bin_block  : {self.tile} / {self.bin_block}"
            + (f" (tuned prior {self.tuned})" if self.tuned else ""),
            f"  microbatch      : {self.microbatch} frame(s)/dispatch"
            + (" (adaptive start)" if self.microbatch_mode == "adaptive"
               else ""),
        ]
        if self.band_plan is None:
            budget = s.memory_budget_bytes
            why = ("no memory budget" if budget is None
                   else f"fits the {budget} B budget in one band")
            lines.append(f"  bands           : none ({why})")
        else:
            bp = self.band_plan
            lines.append(
                f"  bands           : {bp.num_bands} x {bp.band_h} rows "
                f"({bp.band_bytes} B/band <= "
                f"{s.memory_budget_bytes} B budget)"
            )
        if self.storage is None:
            lines.append("  storage         : device fp32")
        else:
            bound = STORAGE_POLICIES[self.storage][1]
            lines.append(
                f"  storage         : host spill {self.storage} "
                f"(exact regions <= {bound} px)"
            )
        if self.sharding is None:
            lines.append("  sharding        : none")
        else:
            axis = s.bin_axis if self.sharding == "bin" else s.row_axis
            size = dict(s.mesh.shape)[axis]
            lines.append(
                f"  sharding        : {self.sharding} over mesh axis "
                f"{axis!r} ({size} devices)"
            )
            if self.layout is not None:
                lines.append(
                    f"  mesh layout     : {self.layout.describe()}"
                )
        if verdict is not None:
            lines.append("  " + verdict.render().replace("\n", "\n  "))
        return "\n".join(lines)


def _resolve_backend(backend: str, method: str) -> str:
    """The "auto" rule from kernels/ops.py, centralized."""
    from repro.kernels.ops import PALLAS_METHODS

    if backend == "auto":
        on_tpu = jax.default_backend() == "tpu"
        return "pallas" if on_tpu and method in PALLAS_METHODS else "jnp"
    if backend == "pallas" and method not in PALLAS_METHODS:
        raise ValueError(
            f"method {method!r} has no Pallas kernel (Pallas methods: "
            f"{sorted(PALLAS_METHODS)}); use backend='auto' or 'jnp'"
        )
    if backend not in ("pallas", "jnp"):
        raise ValueError(f"unknown backend {backend!r}")
    return backend


def plan(spec: WorkloadSpec) -> ExecutionPlan:
    """Deterministically map a workload onto an execution path.

    The decision tree (documented here because it IS the product):

      0. query_rows known and small (at most height/4 rows, no
         mesh/storage pinning another path, row slab within any budget)
         -> fused: compute ONLY those corner rows straight out of the
         scan, never store H (the Ehsan compute-vs-store decision,
         arXiv:1510.05138).
      1. mesh given        -> sharded.  "auto" picks the paper's bin
         mapping when num_bins divides the bin axis, else the spatial
         (row-strip) mapping.  A memory budget on top bands the stream
         (iter_banded_sharded_ih).
      2. budget given      -> band-plan the frame; > 1 band means the
         monolithic H breaks the budget: banded (stream) or spilled
         (host storage policy).  One band fits: dense.
      3. storage given     -> spilled even without a budget (single
         band), because the caller asked for host residency.
      4. otherwise         -> dense.

    Microbatch comes from the per-frame H footprint (auto_batch_size),
    capped by ``num_frames``; banded/spilled/fused paths stream whole
    requests, so their microbatch is the full request arity.

    A tuned-config priors file (core/autotune.py, opt-in via the
    ``REPRO_TUNED_CONFIGS`` environment variable) overrides the default
    tile/bin_block for geometries it has measured; the plan's ``tuned``
    field records the applied key.

    >>> p = plan(WorkloadSpec(height=64, width=64, num_bins=8))
    >>> p.representation, p.method
    ('dense', 'wf_tis')
    >>> fused = plan(WorkloadSpec(height=64, width=64, num_bins=8,
    ...                           query_rows=(15, 31)))
    >>> fused.representation
    'fused'
    >>> print(fused.explain().splitlines()[4])
      query fusion    : fuse — 2 corner row(s) (4096 B) << full H 131072 B; H never stored
    """
    backend = _resolve_backend(spec.backend, spec.method)
    if spec.method not in _known_methods():
        raise ValueError(f"unknown method {spec.method!r}")
    nf = spec.num_frames
    microbatch = auto_batch_size(spec.num_bins, spec.height, spec.width)
    if nf is not None:
        microbatch = max(1, min(microbatch, nf))

    tile, bin_block, tuned = spec.tile, spec.bin_block, None
    prior = autotune.prior_for(spec)
    if prior:
        tile = int(prior.get("tile", tile))
        bin_block = int(prior.get("bin_block", bin_block))
        tuned = autotune.config_key(spec.height, spec.width, spec.num_bins)

    # Decision "incremental" (the video-delta path, core/delta.py): a
    # cached predecessor H exists and few enough rows changed that
    # updating it (recompute dirty bands, carry-correct clean slabs
    # below) beats a full recompute.  The threshold is tunable per
    # geometry via the priors file ("delta_threshold").  Fusion is
    # skipped for incremental plans — it never stores H, so there is
    # nothing to update next frame; mesh plans reassemble cross-device
    # and are recomputed whole.
    incremental = False
    if spec.dirty_fraction is not None:
        if not 0.0 <= spec.dirty_fraction <= 1.0:
            raise ValueError(
                f"dirty_fraction must be within [0, 1], got "
                f"{spec.dirty_fraction}")
        threshold = float(
            (prior or {}).get("delta_threshold", _DELTA_DIRTY_THRESHOLD))
        incremental = spec.mesh is None and spec.dirty_fraction <= threshold

    if spec.query_rows is not None and not incremental:
        rows = spec.query_rows
        k = len(rows)
        if not all(
            0 <= r < spec.height for r in rows
        ) or list(rows) != sorted(set(rows)):
            raise ValueError(
                f"query_rows must be sorted unique within "
                f"[0, {spec.height}), got {rows[:8]}"
            )
        nf_eff = 1 if nf is None else nf
        rows_bytes = 4 * nf_eff * spec.num_bins * k * spec.width
        fits = (
            spec.memory_budget_bytes is None
            or rows_bytes <= spec.memory_budget_bytes
        )
        if (
            0 < k <= spec.height // _FUSE_ROW_FRACTION
            and spec.storage is None
            and spec.mesh is None
            and fits
        ):
            return ExecutionPlan(
                spec=spec, representation="fused", method=spec.method,
                backend=backend, tile=tile, bin_block=bin_block,
                microbatch=(microbatch if nf is None else nf),
                band_plan=None, storage=None, sharding=None,
                microbatch_mode=(
                    "adaptive" if spec.adaptive_microbatch else "fixed"),
                tuned=tuned,
            )

    if spec.storage is not None:
        validate_storage_policy(spec.storage, spec.height, spec.width)
        if spec.mesh is not None:
            raise ValueError(
                "storage policies spill host-side; combine them with "
                "banding, not with a mesh"
            )

    band_frames = 1 if nf is None else nf
    sharding = None
    band_plan = None
    if spec.mesh is not None:
        mesh_shape = dict(spec.mesh.shape)
        sharding = spec.sharding
        if sharding == "auto":
            divisible = (
                spec.bin_axis in mesh_shape
                and spec.num_bins % mesh_shape[spec.bin_axis] == 0
            )
            sharding = "bin" if divisible else "spatial"
        if sharding not in ("bin", "spatial"):
            raise ValueError(
                f"unknown sharding {spec.sharding!r} (auto|bin|spatial)"
            )
        if sharding == "spatial" and nf is not None and nf != 1:
            # spatial_sharded_ih shards the *row* axis of a single (h, w)
            # frame; handing it an (n, h, w) stack would shard the frame
            # axis instead and silently return garbage.  (num_frames=None
            # — an open stream — is frames one at a time, which is fine;
            # map_frames itself rejects sharded plans with its own error.)
            raise ValueError(
                "spatial (row-strip) sharding is single-frame; this "
                f"request has num_frames={spec.num_frames} — make "
                f"num_bins divisible by the {spec.bin_axis!r} mesh axis "
                "for bin sharding, or submit frames one at a time"
            )
        row_multiple = (
            mesh_shape[spec.row_axis] if sharding == "spatial" else 1
        )
        if spec.memory_budget_bytes is not None:
            band_plan = plan_bands(
                spec.height, spec.width, spec.num_bins,
                memory_budget_bytes=spec.memory_budget_bytes,
                num_frames=band_frames, row_multiple=row_multiple,
            )
            if band_plan.num_bands == 1:
                band_plan = None
        return ExecutionPlan(
            spec=spec, representation="sharded", method=spec.method,
            backend=backend, tile=tile, bin_block=bin_block,
            microbatch=microbatch, band_plan=band_plan,
            storage=None, sharding=sharding,
            microbatch_mode=(
                "adaptive" if spec.adaptive_microbatch else "fixed"),
            tuned=tuned,
            layout=choose_layout(
                spec.mesh, sharding,
                bin_axis=spec.bin_axis, row_axis=spec.row_axis,
            ),
        )

    if spec.memory_budget_bytes is not None:
        band_plan = plan_bands(
            spec.height, spec.width, spec.num_bins,
            memory_budget_bytes=spec.memory_budget_bytes,
            num_frames=band_frames,
        )
        if band_plan.num_bands == 1 and spec.storage is None:
            band_plan = None
    elif spec.storage is not None:
        band_plan = plan_bands(spec.height, spec.width, spec.num_bins,
                               num_frames=band_frames)

    if spec.storage is not None:
        representation = "spilled"
    elif band_plan is not None:
        representation = "banded"
    else:
        representation = "dense"
    if representation in ("banded", "spilled") and nf is not None:
        microbatch = nf        # bands stream the whole request at once
    if representation == "dense" and spec.memory_budget_bytes is not None:
        # One band fits the budget, but the *dispatch* is microbatch
        # frames wide — cap it so the budget bounds the live H too.
        microbatch = max(
            1, min(microbatch,
                   spec.memory_budget_bytes // spec.per_frame_h_bytes)
        )

    return ExecutionPlan(
        spec=spec, representation=representation, method=spec.method,
        backend=backend, tile=tile, bin_block=bin_block,
        microbatch=microbatch, band_plan=band_plan,
        storage=spec.storage, sharding=None,
        microbatch_mode=("adaptive" if spec.adaptive_microbatch
                         else "fixed"),
        tuned=tuned, incremental=incremental,
    )


def _known_methods():
    from repro.core import scans

    return scans.METHODS


# ---------------------------------------------------------------------------
# queries
# ---------------------------------------------------------------------------
def _window_rows(source: HSource, window, stride) -> np.ndarray:
    """The corner rows a sliding-window field reads (empty if no fit)."""
    n_r, n_c, bot, top = source._window_lattices(window, stride)
    if n_r <= 0 or n_c <= 0:
        return np.zeros((0,), np.int64)
    return np.unique(np.concatenate([bot, top[top >= 0]]))


class _GeomView:
    """Just enough HSource surface for ``needed_rows`` declarations to
    run BEFORE any H exists — the planner asks the queries what rows
    they read from frame geometry alone (the fuse/store input)."""

    def __init__(self, height: int, width: int):
        self.height = height
        self.width = width

    _window_lattices = HSource._window_lattices


def _declared_rows(queries, height: int, width: int) -> tuple[int, ...] | None:
    """The corner-row union the request will read, from the queries'
    ``needed_rows`` declarations — or ``None`` when any query cannot
    declare its rows up front (then fusion is off the table)."""
    view = _GeomView(height, width)
    needs = []
    for q in queries:
        declare = getattr(q, "needed_rows", None)
        if declare is None:
            return None
        rows = declare(view)
        if rows is None:
            return None
        needs.append(np.asarray(rows))
    if not needs:
        return None
    rows = np.unique(np.concatenate(needs))
    rows = rows[(rows >= 0) & (rows < height)]
    if rows.size == 0:
        return None
    return tuple(int(r) for r in rows)


@dataclasses.dataclass(frozen=True)
class RegionQuery:
    """O(1) region histograms of ``rects`` (Eq. 2)."""

    rects: object

    def apply(self, source: HSource):
        return source.region_histogram(self.rects)

    def needed_rows(self, source: HSource) -> np.ndarray:
        from repro.core.region_query import corner_rows

        return corner_rows(np.asarray(self.rects))


@dataclasses.dataclass(frozen=True)
class SlidingWindowQuery:
    """Histograms of every (wh, ww) window at ``stride``."""

    window: tuple[int, int]
    stride: int = 1

    def apply(self, source: HSource):
        return source.sliding_window_histograms(self.window, self.stride)

    def needed_rows(self, source: HSource) -> np.ndarray:
        return _window_rows(source, self.window, self.stride)


@dataclasses.dataclass(frozen=True)
class LikelihoodQuery:
    """Per-position similarity of window histograms to ``target``."""

    target: object
    window: tuple[int, int]
    metric: object = None
    stride: int = 1

    def apply(self, source: HSource):
        from repro.core import distances

        metric = self.metric or distances.intersection
        return source.likelihood_map(
            self.target, self.window, metric, self.stride
        )

    def needed_rows(self, source: HSource) -> np.ndarray:
        return _window_rows(source, self.window, self.stride)


@dataclasses.dataclass(frozen=True)
class MultiScaleQuery:
    """Best-matching window across scales (rect, score, per-scale maps)."""

    target: object
    windows: tuple[tuple[int, int], ...]
    metric: object = None
    stride: int = 1

    def apply(self, source: HSource):
        from repro.core import distances

        metric = self.metric or distances.intersection
        return source.multi_scale_search(
            self.target, self.windows, metric, self.stride
        )

    def needed_rows(self, source: HSource) -> np.ndarray:
        rows = [_window_rows(source, wnd, self.stride)
                for wnd in self.windows]
        return (np.unique(np.concatenate(rows))
                if rows else np.zeros((0,), np.int64))


@dataclasses.dataclass
class EngineResult:
    """What ``HistogramEngine.run`` hands back."""

    plan: ExecutionPlan
    source: HSource
    results: list


def prefetch_rows(source: HSource, queries) -> PrefetchedRowsH | None:
    """Union the corner rows every query needs and fetch them in ONE
    ``rows()`` pass — a band stream runs once for the whole request.

    Returns ``None`` (caller falls back to per-query access) when any
    query cannot declare its rows up front or no rows are needed."""
    needs = []
    for q in queries:
        declare = getattr(q, "needed_rows", None)
        if declare is None:
            return None
        rows = declare(source)
        if rows is None:
            return None
        needs.append(np.asarray(rows))
    needed = (np.unique(np.concatenate(needs))
              if needs else np.zeros((0,), np.int64))
    if needed.size == 0:
        return None
    return PrefetchedRowsH(source, needed, source.rows(needed))


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------
class HistogramEngine:
    """Plan -> compute -> query facade.

    Holds the workload-independent configuration (bins, method prefs,
    budget, mesh); per-request geometry comes from the frames themselves:

        engine = HistogramEngine(num_bins=32,
                                 memory_budget_bytes=256 << 20)
        out = engine.run(frames, [RegionQuery(rects),
                                  LikelihoodQuery(target, (48, 48))])
        out.plan.explain()       # why this path
        out.results              # one entry per query

    ``engine.last_plan`` keeps the most recent plan for inspection.
    """

    def __init__(
        self,
        num_bins: int = 32,
        *,
        method: str = "wf_tis",
        backend: str = "auto",
        tile: int = 128,
        bin_block: int = 8,
        use_mxu: bool = True,
        interpret: bool = False,
        value_range: int = 256,
        memory_budget_bytes: int | None = None,
        storage: str | None = None,
        adaptive_microbatch: bool = False,
        mesh=None,
        sharding: str = "auto",
        bin_axis: str = "model",
        row_axis: str = "data",
    ):
        self.num_bins = num_bins
        self.method = method
        self.backend = backend
        self.tile = tile
        self.bin_block = bin_block
        self.use_mxu = use_mxu
        self.interpret = interpret
        self.value_range = value_range
        self.memory_budget_bytes = memory_budget_bytes
        self.storage = storage
        self.adaptive_microbatch = adaptive_microbatch
        self.mesh = mesh
        self.sharding = sharding
        self.bin_axis = bin_axis
        self.row_axis = row_axis
        self.last_plan: ExecutionPlan | None = None
        self.last_runtime = None        # FrameRuntime from map_frames
        self.last_verdict = None        # PlanVerdict from validate()

    # -- planning -----------------------------------------------------------
    def spec_for(
        self, shape, dtype="uint8", *, num_frames: int | None = "infer"
    ) -> WorkloadSpec:
        """Derive the WorkloadSpec for an (h, w) / (n, h, w) request.

        ``num_frames`` overrides the inferred request arity — pass ``None``
        for an open-ended stream of (h, w) frames (map_frames does)."""
        shape = tuple(shape)
        if len(shape) == 2:
            nf = 1 if num_frames == "infer" else num_frames
        elif len(shape) == 3:
            nf = shape[0]
        else:
            raise ValueError(f"expected (h, w) or (n, h, w), got {shape}")
        return WorkloadSpec(
            height=shape[-2], width=shape[-1], num_bins=self.num_bins,
            num_frames=nf, dtype=str(dtype), value_range=self.value_range,
            method=self.method, backend=self.backend, tile=self.tile,
            bin_block=self.bin_block, use_mxu=self.use_mxu,
            interpret=self.interpret,
            memory_budget_bytes=self.memory_budget_bytes,
            storage=self.storage,
            adaptive_microbatch=self.adaptive_microbatch,
            mesh=self.mesh, sharding=self.sharding,
            bin_axis=self.bin_axis, row_axis=self.row_axis,
        )

    def plan_for(self, frames) -> ExecutionPlan:
        p = plan(self.spec_for(np.shape(frames),
                               getattr(frames, "dtype", "uint8")))
        self.last_plan = p
        return p

    # -- static validation --------------------------------------------------
    def validate(self, p: ExecutionPlan | None = None, queries=(),
                 *, deep: bool = False):
        """Statically verify a plan (``repro.analysis.plancheck``):
        H shapes/dtypes by abstract evaluation, the cross-band carry
        chain, peak memory vs budget, Pallas VMEM fit, and the
        count-validity bounds for ``queries`` — no dispatch runs.

        ``deep=True`` additionally proves the Pallas kernel contracts
        (``repro.analysis.kernelcheck``: carry happens-before under the
        declared grid order, exactly-once output coverage, in-bounds
        index maps, spec-derived VMEM fit) and merges them into the
        verdict; shallow is the default so existing rendered verdicts
        are unchanged.

        Returns the ``PlanVerdict`` (also kept as ``last_verdict``;
        ``explain()`` surfaces it).  ``run()``/``map_frames()`` call
        this with ``deep=True`` before their first dispatch and raise
        ``PlanValidationError`` on a rejected plan."""
        from repro.analysis.plancheck import check_plan

        if p is None:
            p = self.last_plan
        if p is None:
            raise ValueError("no plan to validate — pass one or run "
                             "plan_for() first")
        verdict = check_plan(p, tuple(queries), deep=deep)
        self.last_verdict = verdict
        return verdict

    def _validate_or_raise(self, p: ExecutionPlan, queries=()) -> None:
        verdict = self.validate(p, queries, deep=True)
        if not verdict.ok:
            raise PlanValidationError(
                "plan rejected by static validation:\n" + verdict.render()
            )

    def explain(self) -> str:
        """``last_plan.explain()`` with the ``last_verdict`` appended."""
        if self.last_plan is None:
            raise ValueError("no plan yet — run plan_for()/run() first")
        return self.last_plan.explain(self.last_verdict)

    # -- execution ----------------------------------------------------------
    def _kernel_kwargs(self, p: ExecutionPlan) -> dict:
        return dict(
            method=p.method, backend=p.backend, tile=p.tile,
            bin_block=p.bin_block, use_mxu=p.spec.use_mxu,
            interpret=p.spec.interpret, value_range=p.spec.value_range,
        )

    def compute_dense(self, frames):
        """The raw (..., b, h, w) H — jit-traceable (no HSource wrapper);
        what jitted consumers like FragmentTracker call."""
        from repro.kernels.ops import integral_histogram

        return integral_histogram(
            frames, self.num_bins, method=self.method, backend=self.backend,
            tile=self.tile, bin_block=self.bin_block, use_mxu=self.use_mxu,
            interpret=self.interpret, value_range=self.value_range,
        )

    def compute(self, frames, p: ExecutionPlan | None = None) -> HSource:
        """Execute the plan: frames -> the planned H representation."""
        from repro.core import bands as bands_mod
        from repro.kernels.ops import integral_histogram

        if p is None:
            p = self.plan_for(frames)
        kw = self._kernel_kwargs(p)

        if p.representation == "fused":
            from repro.kernels.ops import fused_corner_rows

            rows = np.asarray(p.spec.query_rows, np.int64)
            stats: dict = {}
            R = fused_corner_rows(
                frames, self.num_bins, rows, stats=stats, **kw,
            )
            source = FusedRowsH(
                rows, np.asarray(R),
                height=p.spec.height, width=p.spec.width,
            )
            source.last_fused_stats = stats
            return source

        if p.representation == "sharded":
            from repro.core import distributed

            s = p.spec
            if p.band_plan is not None:
                return BandedH(lambda: distributed.iter_banded_sharded_ih(
                    frames, self.num_bins, s.mesh, sharding=p.sharding,
                    band_h=p.band_plan.band_h, bin_axis=s.bin_axis,
                    row_axis=s.row_axis, method=p.method, backend=p.backend,
                    value_range=s.value_range,
                ))
            if p.sharding == "bin":
                H = distributed.bin_sharded_ih(
                    frames, self.num_bins, s.mesh, bin_axis=s.bin_axis,
                    method=p.method, backend=p.backend,
                    value_range=s.value_range,
                )
            else:
                H = distributed.spatial_sharded_ih(
                    frames, self.num_bins, s.mesh, row_axis=s.row_axis,
                    method=p.method, backend=p.backend,
                    value_range=s.value_range,
                )
            return ShardedH(H, s.mesh, kind=p.sharding,
                            bin_axis=s.bin_axis, row_axis=s.row_axis)

        if p.representation == "spilled":
            return bands_mod.spill_banded_ih(
                frames, self.num_bins, storage=p.storage,
                plan=p.band_plan, **kw,
            )

        if p.representation == "banded":
            return BandedH(lambda: bands_mod.iter_banded_ih(
                frames, self.num_bins, plan=p.band_plan, **kw,
            ))

        return DenseH(integral_histogram(frames, self.num_bins, **kw))

    # -- incremental video path (core/delta.py) -----------------------------
    def _delta_spans(self, spec: WorkloadSpec, prev_source: HSource):
        """The band granularity dirty detection and update share: a
        spilled source's own spans, the spec's budget bands otherwise,
        tile-high bands for a dense plan (no bands of its own)."""
        spans = getattr(prev_source, "spans", None)
        if spans is not None:
            return tuple(spans)
        nf = spec.num_frames
        band_frames = 1 if nf is None else nf
        if spec.memory_budget_bytes is not None:
            bp = plan_bands(
                spec.height, spec.width, spec.num_bins,
                memory_budget_bytes=spec.memory_budget_bytes,
                num_frames=band_frames,
            )
        else:
            # Dense plans have no bands of their own: detect finely (the
            # dense walk merges adjacent spans back into maximal runs, so
            # fine detection costs dispatches nothing and recomputes less)
            # while keeping at least ~8 bands on small frames.
            band_h = max(1, min(16, -(-spec.height // 8)))
            bp = plan_bands(spec.height, spec.width, spec.num_bins,
                            band_h=band_h)
        return bp.spans

    def _delta_report(self, frames, prev_frame, prev_source: HSource,
                      spec: WorkloadSpec):
        """Dirty-band detection against a cached predecessor, or None
        when the predecessor cannot seed an update (geometry/bin/shape
        mismatch, mesh plan, or a representation without the hook)."""
        if self.mesh is not None:
            return None
        if not hasattr(prev_source, "update_bands"):
            return None
        if np.shape(prev_frame) != np.shape(frames):
            return None
        if (prev_source.height, prev_source.width) != (spec.height,
                                                       spec.width):
            return None
        if prev_source.num_bins != self.num_bins:
            return None
        return delta_mod.diff_bands(
            prev_frame, frames, self._delta_spans(spec, prev_source))

    def _updatable(self, prev_source: HSource, p: ExecutionPlan) -> bool:
        """Does the cached representation match the plan well enough to
        take the update in place?  (Policy mismatch -> full recompute.)"""
        if p.representation == "dense":
            return isinstance(prev_source, DenseH)
        if p.representation == "banded":
            return (isinstance(prev_source, BandedH)
                    and prev_source._factory is not None)
        if p.representation == "spilled":
            return (isinstance(prev_source, SpilledIH)
                    and prev_source.storage == p.storage
                    and prev_source.carries is not None)
        return False

    def _update(self, prev_source: HSource, frames, report,
                p: ExecutionPlan) -> HSource:
        """Drive the cached source's ``update_bands`` hook with the
        plan's kernel dispatch and the delta_apply slab repair."""
        from repro.kernels import ops

        kw = self._kernel_kwargs(p)

        def recompute(band_rows, carry):
            return ops.integral_histogram(
                band_rows, self.num_bins, carry_in=carry, **kw)

        # Pallas plans route the broadcast correction through the
        # delta_apply kernel; jnp plans leave apply_fn unset so the
        # dense walk takes its fused single-dispatch assembly.
        apply_fn = None
        if p.backend == "pallas":
            def apply_fn(slab, d):
                return ops.delta_apply(
                    slab, d, backend=p.backend, tile=p.tile,
                    bin_block=p.bin_block, interpret=p.spec.interpret)

        return prev_source.update_bands(
            frames, report, recompute=recompute, apply_fn=apply_fn)

    def run(self, frames, queries: Iterable = (), *,
            prev=None) -> EngineResult:
        """Plan, compute, and answer ``queries`` in order.

        The queries shape the plan: their declared corner-row union goes
        into the spec as ``query_rows``, and when it is small the planner
        fuses the queries into the scan (``representation == "fused"``)
        so H is never stored.  Multiple queries against a band-streamed
        plan share ONE stream: the union of every query's corner rows is
        fetched in a single ``rows()`` pass (``prefetch_rows``) instead
        of re-running the banded kernel per query.

        ``prev=(prev_frame, prev_source)`` offers a predecessor frame
        and its H (an ``HSource`` or ``EngineResult``) to the planner:
        when few enough rows changed (core/delta.py), the plan goes
        ``incremental`` and the cached H is *updated* — only dirty
        bands recomputed, clean slabs below carry-corrected — instead
        of rebuilt, bit-exactly.  High motion, geometry/policy
        mismatches, and non-updatable representations (fused, sharded,
        single-shot banded) fall back to a full recompute.

        >>> import numpy as np
        >>> from repro.core.engine import HistogramEngine, RegionQuery
        >>> frame = np.arange(64, dtype=np.uint8).reshape(8, 8) % 4
        >>> eng = HistogramEngine(num_bins=4, value_range=4, backend="jnp")
        >>> out = eng.run(frame, [RegionQuery([[0, 0, 7, 7]])])
        >>> out.plan.representation      # 1 corner row -> query-fused
        'fused'
        >>> [float(v) for v in np.asarray(out.results[0]).ravel()]
        [16.0, 16.0, 16.0, 16.0]
        """
        queries = list(queries)
        spec = self.spec_for(np.shape(frames),
                             getattr(frames, "dtype", "uint8"))
        rows = _declared_rows(queries, spec.height, spec.width)
        if rows is not None:
            spec = dataclasses.replace(spec, query_rows=rows)

        prev_frame = prev_source = report = None
        if prev is not None:
            prev_frame, prev_source = prev
            if isinstance(prev_source, EngineResult):
                prev_source = prev_source.source
            report = self._delta_report(frames, prev_frame, prev_source,
                                        spec)
            if report is not None:
                spec = dataclasses.replace(
                    spec, dirty_fraction=report.dirty_fraction)

        p = plan(spec)
        if p.incremental and not self._updatable(prev_source, p):
            # The cached representation cannot take the update (policy
            # mismatch, single-shot stream, ...): re-plan for a full
            # recompute rather than fail.
            spec = dataclasses.replace(spec, dirty_fraction=None)
            p = plan(spec)
        self.last_plan = p
        self._validate_or_raise(p, queries)
        if p.incremental:
            source = self._update(prev_source, frames, report, p)
        else:
            source = self.compute(frames, p)
        target = source
        if len(queries) > 1 and isinstance(source, BandedH):
            target = prefetch_rows(source, queries) or source
        results = [q.apply(target) for q in queries]
        return EngineResult(plan=p, source=source, results=results)

    # -- streaming ----------------------------------------------------------
    def runtime_for(self, p: ExecutionPlan, step=None, *, depth: int = 2,
                    device=None, **kw):
        """A ``FrameRuntime`` (core/runtime.py) configured from a plan:
        microbatch size and fixed/adaptive mode come from the planner,
        the in-flight window from the caller.  ``step`` defaults to the
        engine's dense compute lifted to the runtime signature."""
        from repro.core.runtime import FrameRuntime

        if step is None:
            step = FrameRuntime.stateless(self.compute_dense)
        return FrameRuntime(
            step, depth=depth, microbatch=p.microbatch,
            adaptive=(p.microbatch_mode == "adaptive"),
            device=device, **kw,
        )

    def map_frames(
        self, frames: Iterable, *, depth: int = 2, device=None
    ) -> Iterator[jax.Array]:
        """Stream per-frame H's with planner-chosen microbatching and
        ``depth`` dispatches in flight (paper §4.4 double-buffering) —
        the planner-driven successor of ``IntegralHistogram.map_frames``.
        An ``adaptive_microbatch`` engine hands the runtime the plan's
        size as a starting point and lets its online controller retune
        it from measured per-dispatch latency."""
        import itertools

        frames = iter(frames)
        try:
            first = next(frames)
        except StopIteration:
            return iter(())
        p = plan(self.spec_for(np.shape(first),
                               getattr(first, "dtype", "uint8"),
                               num_frames=None))
        self.last_plan = p
        if p.representation != "dense":
            # Streaming yields one dense (b, h, w) H per frame; executing
            # a banded/spilled/sharded plan here would silently ignore
            # the budget/mesh/storage the engine was configured with.
            raise ValueError(
                f"map_frames streams dense per-frame H's, but the plan "
                f"chose {p.representation!r} for {p.spec.height}x"
                f"{p.spec.width}x{p.spec.num_bins}; run each frame "
                "through engine.run()/compute() instead"
            )
        self._validate_or_raise(p)
        runtime = self.runtime_for(p, depth=depth, device=device)
        self.last_runtime = runtime
        return runtime.map_frames(itertools.chain([first], frames))
