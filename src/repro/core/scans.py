"""The paper's four integral-histogram computation strategies, jnp level.

CW-B    — cross-weave baseline: unbatched per-bin scan/transpose/scan
          composition (faithful to the paper's "many tiny kernels" storm;
          on XLA the launch overhead becomes trace/HLO blow-up and lost
          fusion, and its HBM-traffic model keeps the 6-pass floor).
CW-STS  — single batched scan -> materialized 3-D transpose -> scan.
CW-TiS  — tiled horizontal strip scan then tiled vertical strip scan,
          no transpose (4 HBM passes).  Pallas kernel: kernels/cw_tis.py.
WF-TiS  — single fused pass: per-tile h-scan + v-scan with boundary
          carries (2 HBM passes).  Pallas kernel: kernels/wf_tis.py.

The jnp versions here are schedule-faithful restatements used as CPU
executables (wall-time benchmarks) and as shape/semantics references; the
TPU-native schedules live in repro/kernels/.

Every method accepts a single frame ``(h, w)`` -> ``(b, h, w)`` or a frame
stack ``(n, h, w)`` -> ``(n, b, h, w)``, identical to a loop of
single-frame calls.  For the cross-weave methods the frame axis simply
rides the leading batch dimensions of the same scan primitives (one fused
dispatch, no per-frame launches — the throughput model of Koppaka et
al.'s stream-batched histograms); WF-TiS is vmapped so its strip/carry
schedule stays frame-faithful while XLA widens the carries to (n, b, w).
All results are identical to kernels/ref.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.binning import bin_indices, one_hot_bins


# ---------------------------------------------------------------------------
# Band-carry composition.  An integral histogram is a prefix sum over rows,
# so the H of rows [r0, r1) of a frame equals the local H of that band plus
# the full-frame H's row r0-1 — an (..., b, w) aggregate, the WF-TiS column
# carry lifted out of the kernel.  All arithmetic is integer-valued fp32
# (exact below 2**24), so post-adding the carry is bit-identical to seeding
# the scan with it; core/bands.py streams whole frames through this.
# ---------------------------------------------------------------------------
def apply_carry(H: jnp.ndarray, carry_in: jnp.ndarray | None) -> jnp.ndarray:
    """Compose a band's local H (..., b, bh, w) with the (..., b, w)
    aggregate of everything above the band (``None`` = topmost band)."""
    if carry_in is None:
        return H
    return H + carry_in.astype(H.dtype)[..., :, None, :]


# ---------------------------------------------------------------------------
# CW-B: naive baseline — bins processed one at a time, rows/cols as separate
# scan primitives (Algorithm 2 of the paper).
# ---------------------------------------------------------------------------
def cw_b(image: jnp.ndarray, num_bins: int, value_range: int = 256) -> jnp.ndarray:
    idx = bin_indices(image, num_bins, value_range)
    outs = []
    for b in range(num_bins):  # one "kernel launch" chain per bin (faithful)
        q = (idx == b).astype(jnp.float32)
        h_scanned = jnp.cumsum(q, axis=-1)         # horizontal prescan
        t = jnp.swapaxes(h_scanned, -2, -1)        # 2-D transpose (materialized)
        v_scanned = jnp.cumsum(t, axis=-1)         # vertical prescan (as rows)
        outs.append(jnp.swapaxes(v_scanned, -2, -1))
    return jnp.stack(outs, axis=-3)


# ---------------------------------------------------------------------------
# CW-STS: one batched scan, one 3-D transpose, one batched scan (Algorithm 3).
# A frame stack fuses into the scan's leading batch axes: (n, b, h, w) is one
# (n*b)-deep batched scan, not n dispatches.
# ---------------------------------------------------------------------------
def cw_sts(image: jnp.ndarray, num_bins: int, value_range: int = 256) -> jnp.ndarray:
    idx = bin_indices(image, num_bins, value_range)
    q = one_hot_bins(idx, num_bins)                          # (..., b, h, w) init pass
    h_scanned = jnp.cumsum(q, axis=-1)                       # batched row scan
    transposed = jnp.swapaxes(h_scanned, -2, -1).copy()      # 3-D transpose
    v_scanned = jnp.cumsum(transposed, axis=-1)              # batched "row" scan
    return jnp.swapaxes(v_scanned, -2, -1)                   # back to (..., b, h, w)


# ---------------------------------------------------------------------------
# Tiled building block: blocked inclusive cumsum along the last axis —
# per-tile local scan + exclusive carry of tile totals (the strip schedule
# of CW-TiS, Fig. 5 of the paper).
# ---------------------------------------------------------------------------
def _blocked_cumsum_last(x: jnp.ndarray, tile: int) -> jnp.ndarray:
    *lead, n = x.shape
    if n % tile:
        raise ValueError(f"axis {n} not divisible by tile {tile}")
    xt = x.reshape(*lead, n // tile, tile)
    local = jnp.cumsum(xt, axis=-1)                          # intra-tile scan
    totals = local[..., -1]                                  # per-tile sums
    carry = jnp.cumsum(totals, axis=-1) - totals             # exclusive carry
    return (local + carry[..., None]).reshape(*lead, n)


def _pad_idx(idx: jnp.ndarray, th: int, tw: int) -> jnp.ndarray:
    """Pad a bin-index image (or stack) to tile multiples on the spatial
    (last two) axes; padding matches no bin."""
    from repro.core.binning import PAD_BIN

    h, w = idx.shape[-2:]
    ph, pw = (-h) % th, (-w) % tw
    if ph or pw:
        pad = [(0, 0)] * (idx.ndim - 2) + [(0, ph), (0, pw)]
        idx = jnp.pad(idx, pad, constant_values=PAD_BIN)
    return idx


def cw_tis(
    image: jnp.ndarray, num_bins: int, value_range: int = 256, tile: int = 128
) -> jnp.ndarray:
    idx = bin_indices(image, num_bins, value_range)
    h, w = image.shape[-2:]
    th, tw = min(tile, h), min(tile, w)
    idx = _pad_idx(idx, th, tw)
    q = one_hot_bins(idx, num_bins)
    h_scanned = _blocked_cumsum_last(q, tw)                  # horizontal strips
    v_scanned = _blocked_cumsum_last(jnp.swapaxes(h_scanned, -2, -1), th)
    return jnp.swapaxes(v_scanned, -2, -1)[..., :h, :w]


# ---------------------------------------------------------------------------
# WF-TiS: fused single pass.  The jnp statement of "h-scan then v-scan with
# tile carries, one sweep" — XLA fuses it; the true 2-HBM-pass schedule is
# the Pallas kernel.  A lax.scan over row strips keeps the carry structure
# explicit (the (b, w) column carry is exactly the kernel's VMEM scratch).
# ---------------------------------------------------------------------------
def _wf_tis_single(
    image: jnp.ndarray,
    num_bins: int,
    value_range: int,
    tile: int,
    carry_in: jnp.ndarray | None = None,
) -> jnp.ndarray:
    idx = bin_indices(image, num_bins, value_range)
    h, w = image.shape
    th = min(tile, h)
    idx = _pad_idx(idx, th, 1)
    hp = idx.shape[0]
    idx_strips = idx.reshape(hp // th, th, w)

    def strip_step(col_carry, idx_strip):
        # col_carry: (b, w) running column sums of everything above.
        q = one_hot_bins(idx_strip, num_bins)                # (b, th, w)
        hs = jnp.cumsum(q, axis=2)                           # horizontal scan
        vs = jnp.cumsum(hs, axis=1)                          # vertical within strip
        out = vs + col_carry[:, None, :]
        return out[:, -1, :], out                            # new carry, strip H

    # A band's carry_in seeds the scan exactly where the previous band's
    # bottom row left off — the natural statement of band streaming.
    init = (
        jnp.zeros((num_bins, w), dtype=jnp.float32)
        if carry_in is None
        else carry_in.astype(jnp.float32)
    )
    _, strips = jax.lax.scan(strip_step, init, idx_strips)
    return jnp.moveaxis(strips, 1, 0).reshape(num_bins, hp, w)[:, :h, :]


def wf_tis(
    image: jnp.ndarray,
    num_bins: int,
    value_range: int = 256,
    tile: int = 128,
    carry_in: jnp.ndarray | None = None,
) -> jnp.ndarray:
    if image.ndim == 3:  # frame stack: widen the strip scan's carry to (n, b, w)
        if carry_in is None:
            return jax.vmap(
                lambda im: _wf_tis_single(im, num_bins, value_range, tile)
            )(image)
        return jax.vmap(
            lambda im, c: _wf_tis_single(im, num_bins, value_range, tile, c)
        )(image, carry_in)
    return _wf_tis_single(image, num_bins, value_range, tile, carry_in)


METHODS = {"cw_b": cw_b, "cw_sts": cw_sts, "cw_tis": cw_tis, "wf_tis": wf_tis}
