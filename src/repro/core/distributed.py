"""Distributed integral histograms: the paper's multi-GPU scheme at pod scale.

Paper §4.6: bins are grouped into tasks and dispatched over 4 GPUs through
a task queue (PCIe-attached, no peer communication).  On a TPU mesh the
"task queue" becomes a sharding spec:

  * **Bin sharding** (`bin_sharded_ih`) — the paper's scheme, verbatim:
    bins are an embarrassingly-parallel axis; every device computes the
    integral histogram of its own bin range from the (replicated or
    broadcast) frame.  Zero inter-device traffic after the frame broadcast.

  * **Spatial sharding** (`spatial_sharded_ih`) — beyond-paper: row strips
    are sharded across devices; each device computes its local strip IH and
    the 1-D bottom-boundary aggregate (b, w) is carried across devices with
    an exclusive prefix "wavefront" — the WF-TiS carry pattern lifted from
    VMEM scratch to ICI collectives.  This is what lets a single 8k x 8k x
    128-bin frame (32 GB of H, paper §4.6) live sharded across a pod
    instead of being serialized through one device's memory.

  * Both compose: rows over one mesh axis, bins over the other.

  * **Band streaming** (`iter_banded_sharded_ih`) — either scheme composed
    with core/bands.py: row bands of one huge frame stream through the
    sharded computation, the (b, w) band carry riding on top of the
    intra-band device carries.  Bounds per-device live memory to one
    sharded band.

The exclusive cross-device prefix is implemented two ways:
  - `allgather`: gather all carries, masked sum (one collective; XLA
    optimizes this well on ICI).
  - `ppermute`: log2(D) Hillis-Steele ladder of collective_permutes — the
    literal wavefront, cheaper at large D and the schedule used for the
    sequence-parallel SSM scan in models/ssm.py.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core.binning import PAD_BIN, bin_indices
from repro.core.scans import apply_carry
from repro.kernels.ops import integral_histogram


def exclusive_axis_scan(
    x: jnp.ndarray, axis_name: str, axis_size: int, impl: str = "allgather"
) -> jnp.ndarray:
    """Exclusive prefix-sum of ``x`` across a mesh axis (device i receives
    the sum of x from devices 0..i-1).  Runs inside shard_map."""
    if impl == "allgather":
        all_x = lax.all_gather(x, axis_name)                 # (D, ...)
        idx = lax.axis_index(axis_name)
        mask = (jnp.arange(axis_size) < idx).astype(x.dtype)
        return jnp.tensordot(mask, all_x, axes=1)
    if impl == "ppermute":
        # Shift right by one, then Hillis-Steele inclusive ladder.
        val = lax.ppermute(
            x, axis_name, [(i, i + 1) for i in range(axis_size - 1)]
        )
        d = 1
        while d < axis_size:
            recv = lax.ppermute(
                val, axis_name, [(i, i + d) for i in range(axis_size - d)]
            )
            val = val + recv
            d *= 2
        return val
    raise ValueError(f"unknown impl {impl!r}")


def band_input_sharding(
    mesh: Mesh,
    sharding: str,
    *,
    row_axis: str = "data",
    bin_axis: str = "model",
    lead: int = 0,
) -> NamedSharding:
    """The placement a band image slice should be staged with before it
    enters the sharded band compute: replicated for bin sharding (every
    device masks its own bin range out of the full band) and row strips
    over ``row_axis`` for spatial sharding.  ``lead`` counts leading
    frame axes — (n, h, w) stacks are bin-sharded only, so the lead axes
    are never split.  Handing this to ``FrameRuntime``/``stage_stream``
    as ``device=`` commits each slice to the exact layout the shard_map
    consumes, which is what removed the old "sharded plans skip staging"
    carve-out in ``bands.iter_banded_ih``."""
    if sharding == "bin":
        return NamedSharding(mesh, P())
    return NamedSharding(mesh, P(*([None] * lead), row_axis, None))


def replica_meshes(mesh: Mesh, replica_axis: str) -> list:
    """Split a mesh into frame-parallel replica-group submeshes along
    ``replica_axis`` — the serving half of the planner's 2-D layout
    (replica groups x within-group sharding).  One entry per index on the
    axis, each a ``Mesh`` over the remaining axes (the within-group shard
    layout), or ``None`` when the group is a bare single device (a 1-D
    mesh has no remaining axes; callers hand ``None`` groups a plain
    single-device engine, which keeps the PR 9 incremental path alive).
    A mesh without the axis is one group: ``[mesh]``."""
    names = list(mesh.axis_names)
    if replica_axis not in names:
        return [mesh]
    ax = names.index(replica_axis)
    rest = tuple(names[:ax] + names[ax + 1:])
    out = []
    for i in range(mesh.shape[replica_axis]):
        devs = np.take(np.asarray(mesh.devices), i, axis=ax)
        out.append(Mesh(devs, rest) if rest else None)
    return out


def bin_sharded_ih(
    image: jnp.ndarray,
    num_bins: int,
    mesh: Mesh,
    *,
    bin_axis: str = "model",
    method: str = "wf_tis",
    backend: str = "jnp",
    value_range: int = 256,
) -> jnp.ndarray:
    """Paper's multi-GPU scheme: bins sharded over ``bin_axis``.

    Accepts an (h, w) frame or an (n, h, w) stack (one batched dispatch
    per shard).  Returns H ([n,] num_bins, h, w) sharded over bins.
    """
    nshards = mesh.shape[bin_axis]
    if num_bins % nshards:
        raise ValueError(f"{num_bins} bins not divisible by {nshards} shards")
    local_bins = num_bins // nshards

    def shard_fn(img):
        idx = bin_indices(img, num_bins, value_range)
        lo = lax.axis_index(bin_axis) * local_bins
        local_idx = jnp.where(
            (idx >= lo) & (idx < lo + local_bins), idx - lo, PAD_BIN
        )
        return integral_histogram(
            local_idx, local_bins, method=method, backend=backend,
            value_range=None,
        )

    lead = image.ndim - 2                   # 0 single frame, 1 frame stack
    fn = shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=P(),                       # frame(s) replicated
        out_specs=P(*([None] * lead), bin_axis, None, None),
        check_vma=False,
    )
    return fn(image)


def spatial_sharded_ih(
    image: jnp.ndarray,
    num_bins: int,
    mesh: Mesh,
    *,
    row_axis: str = "data",
    bin_axis: str | None = None,
    method: str = "wf_tis",
    backend: str = "jnp",
    value_range: int = 256,
    scan_impl: str = "allgather",
) -> jnp.ndarray:
    """Beyond-paper: row strips over ``row_axis`` (+ optional bin sharding).

    Each device computes its strip's integral histogram, then the (b, w)
    bottom-boundary carries sweep down the mesh axis as an exclusive
    prefix — the WF-TiS column carry at ICI scale.

    Returns H (num_bins, h, w) sharded P(bin_axis, row_axis, None).
    """
    d_rows = mesh.shape[row_axis]
    h = image.shape[0]
    if h % d_rows:
        raise ValueError(f"height {h} not divisible by {d_rows} row shards")
    local_bins = num_bins
    if bin_axis is not None:
        nb_shards = mesh.shape[bin_axis]
        if num_bins % nb_shards:
            raise ValueError(f"{num_bins} bins not divisible by {nb_shards}")
        local_bins = num_bins // nb_shards

    def shard_fn(img_strip):
        idx = bin_indices(img_strip, num_bins, value_range)
        if bin_axis is not None:
            lo = lax.axis_index(bin_axis) * local_bins
            idx = jnp.where(
                (idx >= lo) & (idx < lo + local_bins), idx - lo, PAD_BIN
            )
        local_h = integral_histogram(
            idx, local_bins, method=method, backend=backend, value_range=None,
        )
        carry = local_h[:, -1, :]                            # (b_local, w)
        prefix = exclusive_axis_scan(carry, row_axis, d_rows, scan_impl)
        return local_h + prefix[:, None, :]

    in_spec = P(row_axis, None)
    out_spec = P(bin_axis, row_axis, None)
    fn = shard_map(
        shard_fn, mesh=mesh, in_specs=in_spec, out_specs=out_spec,
        check_vma=False,
    )
    return fn(image)


def iter_banded_sharded_ih(
    image,
    num_bins: int,
    mesh: Mesh,
    *,
    sharding: str = "bin",
    band_h: int | None = None,
    memory_budget_bytes: int | None = None,
    bin_axis: str = "model",
    row_axis: str = "data",
    method: str = "wf_tis",
    backend: str = "jnp",
    value_range: int = 256,
    scan_impl: str = "allgather",
    prefetch: int = 0,
):
    """Band streaming composed with the sharded computations: each band
    runs bin- or spatially-sharded across the mesh, and the same (b, w)
    bottom-row carry threads between bands on top of the intra-band
    device carries.

    This is the paper-§4.6 scale story squared: ``spatial_sharded_ih``
    spreads one frame's H across a mesh; banding additionally bounds how
    much of it is ever live per device, so the 32 GB workload streams
    through a mesh whose total memory is far smaller.  ``sharding="bin"``
    accepts (h, w) or (n, h, w); ``"spatial"`` is single-frame and rounds
    the band height to the row-shard count.  Yields ``BandH`` chunks whose
    ``H`` stays sharded (``carry`` inherits the sharding — zero extra
    collectives for the band composition, it is one elementwise add).
    Assemble host-side (``np.asarray`` per band) when a materialized H is
    actually wanted; that doubles as the D2H spill.

    Band slices are staged with the ``band_input_sharding`` placement
    (replicated for bin sharding, row strips for spatial), so staging
    overlaps the sharded compute exactly like the single-device path and
    the between-band carry rides the shard layout end to end — no host
    round-trip anywhere in the carry chain.  ``prefetch >= 1`` keeps that
    many sharded slices staged ahead.
    """
    from repro.core import bands

    if sharding not in ("bin", "spatial"):
        raise ValueError(f"unknown sharding {sharding!r} (bin|spatial)")
    h, w = image.shape[-2:]
    row_multiple = 1
    if sharding == "spatial":
        if image.ndim != 2:
            raise ValueError("spatial banding is single-frame: (h, w)")
        row_multiple = mesh.shape[row_axis]
        if h % row_multiple:
            raise ValueError(
                f"height {h} not divisible by {row_multiple} row shards"
            )
    num_frames = 1 if image.ndim == 2 else image.shape[0]
    plan = bands.plan_bands(
        h, w, num_bins,
        band_h=band_h, memory_budget_bytes=memory_budget_bytes,
        num_frames=num_frames, row_multiple=row_multiple,
    )

    def compute_fn(band_img, carry_in):
        if sharding == "bin":
            H_band = bin_sharded_ih(
                band_img, num_bins, mesh, bin_axis=bin_axis,
                method=method, backend=backend, value_range=value_range,
            )
        else:
            H_band = spatial_sharded_ih(
                band_img, num_bins, mesh, row_axis=row_axis,
                method=method, backend=backend, value_range=value_range,
                scan_impl=scan_impl,
            )
        # Band composition is an elementwise add: the carry carries
        # H_band's sharding, so no resharding or collective happens.
        return apply_carry(H_band, carry_in)

    staging = band_input_sharding(
        mesh, sharding, row_axis=row_axis, bin_axis=bin_axis,
        lead=image.ndim - 2,
    )
    return bands.iter_banded_ih(
        image, num_bins, plan=plan, compute_fn=compute_fn,
        device=staging, prefetch=prefetch,
    )


def distributed_region_query(H_sharded, rects, mesh, bin_axis="model"):
    """Region queries against a bin-sharded H: queries are local per bin
    shard; results concatenate over the bin axis (no collective needed —
    histograms over bins are embarrassingly parallel, paper §4.6).

    Thin dispatch over the unified H-representation protocol: ``ShardedH``
    (core/hsource.py) owns the shard_map fast path; this wrapper survives
    for callers that hold a raw sharded array.  Rank-polymorphic over
    frame batching like ``region_histogram``; returns
    (*H_lead, *rects_lead, b) with bins sharded over ``bin_axis``."""
    from repro.core.hsource import ShardedH

    return ShardedH(
        H_sharded, mesh, kind="bin", bin_axis=bin_axis
    ).region_histogram(rects)
