"""One asynchronous frame runtime behind every streaming loop in the repo.

Paper §4.4 overlaps (disk -> host), (host -> device), kernel execution
and (device -> host) across a frame sequence with two CUDA streams.  PRs
1-4 grew five independent host loops that each re-implemented a slice of
that overlap — ``pipeline.DoubleBufferedExecutor`` (dispatch-ahead +
microbatch), ``IntegralHistogram.map_frames`` / ``HistogramEngine
.map_frames`` (the same loop with planner-sized batches),
``bands.iter_banded_ih`` (a carry-threaded band loop with its own
prefetch), and ``FragmentTracker.track`` (a chunked carry loop over
tracker state).  This module is the one scheduler they are now thin
adapters over:

    FrameSource -> [microbatch] -> [H2D stage] -> [step] -> Sink
                        ^                ^           ^
                   fixed | adaptive   stage_ahead   depth-k in-flight
                                                    window + carry

  * **Bounded in-flight window** — the double buffer generalized to
    depth k: up to ``depth`` dispatches are enqueued before the oldest
    is retired (``depth=1`` degenerates to synchronous execution, the
    "no dual-buffering" baseline of Fig. 13).
  * **Microbatching** — ``microbatch`` frames are stacked per dispatch
    (the rank-polymorphic kernels accept (n, ...) stacks).  Sizes come
    from the planner (``ExecutionPlan.microbatch``); ``adaptive=True``
    retunes the size online from measured per-dispatch completion
    latency — the adaptive CUDA-stream batching of Koppaka et al.
    (arXiv:1011.0235) restated for XLA dispatch.
  * **Carry threading** — ``step(chunk, carry) -> (out, carry)``: the
    banded (b, w) bottom-row carry and the tracker's scan state are the
    same sequential dependency; the carry rides between dispatches as an
    async jax value, so dispatch-ahead still overlaps staging with
    compute.
  * **Device prefetch** — inputs are staged with ``jax.device_put``
    (async H2D); ``stage_ahead >= 1`` keeps that many chunks staged
    beyond the dispatch window (``bands.iter_banded_ih(prefetch=k)``).

Results are retired in order; ``block=True`` (default) blocks on the
oldest in-flight result at the window boundary — the D2H sync point that
gives backpressure and the latency measurements the adaptive controller
feeds on.  ``block=False`` hands back async arrays (band streaming,
where the consumer's ``np.asarray`` is the sync point).
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable, Iterable, Iterator

import jax
import numpy as np


# ---------------------------------------------------------------------------
# chunking (the one copy of what executor/tracker/map_frames each had)
# ---------------------------------------------------------------------------
def stack_chunks(
    frames: Iterable[np.ndarray], batch_size: int
) -> Iterator[np.ndarray]:
    """Group a frame stream into stacked (<= batch_size, ...) host arrays
    (ragged final chunk included)."""
    buf: list = []
    for frame in frames:
        # analysis: allow-host-sync(host-side frame staging before device dispatch, not a device readback)
        buf.append(np.asarray(frame))
        if len(buf) == batch_size:
            yield np.stack(buf)
            buf = []
    if buf:
        yield np.stack(buf)


def iter_chunks(frames, batch_size: int) -> Iterator:
    """Chunk a clip or stream: an array (n, ...) is sliced (device arrays
    stay on device, no per-frame host round-trip); any other iterable is
    stacked host-side via ``stack_chunks``."""
    if hasattr(frames, "shape") and hasattr(frames, "ndim"):
        for s in range(0, frames.shape[0], batch_size):
            yield frames[s : s + batch_size]
        return
    yield from stack_chunks(frames, batch_size)


def stage_stream(items: Iterable, size: int = 2, device=None) -> Iterator:
    """Stage host arrays onto the device ahead of consumption (async H2D
    ~ cudaMemcpyAsync).  Exactly ``size`` items are staged before the
    first yield and at most ``size`` are ever resident beyond the one in
    the consumer's hands.

    ``device`` is any ``jax.device_put`` placement: a single ``Device``
    (default: the first device) or a ``jax.sharding.Sharding`` — a
    ``NamedSharding`` lays each staged item out across its mesh, which is
    how the sharded band streams commit their slices to the layout their
    shard_map expects instead of bouncing through one device."""
    device = device if device is not None else jax.devices()[0]
    queue: collections.deque = collections.deque()
    for item in items:
        queue.append(jax.device_put(item, device))
        # yield once exactly `size` items are staged — `> size` would
        # hold size + 1 on device before the first yield
        if len(queue) >= size:
            yield queue.popleft()
    while queue:
        yield queue.popleft()


# ---------------------------------------------------------------------------
# adaptive microbatch controller
# ---------------------------------------------------------------------------
class AdaptiveMicrobatch:
    """Online microbatch tuner: hill-climb the size against measured
    throughput (frames per second of dispatch completion).

    Koppaka et al. pick the CUDA batch size online against measured
    transfer/compute rates; here the signal is the per-dispatch
    completion latency the runtime observes at its D2H sync point.  The
    controller holds a size for ``settle`` completed dispatches, records
    the best observed throughput at that size, then moves one
    multiplicative step (x2 / /2) in the current direction; a move that
    measures worse than the best size seen so far reverses direction
    once, then locks in the best size.  Deterministic given the observed
    latencies — unit-tested with scripted timings."""

    def __init__(self, initial: int, max_size: int = 64, settle: int = 2):
        if initial < 1 or max_size < 1:
            raise ValueError("batch sizes must be >= 1")
        self.size = min(initial, max_size)
        self.max_size = max_size
        self.settle = settle
        self._counts: dict[int, int] = {}
        self._throughput: dict[int, float] = {}
        self._direction = 2.0            # multiplicative step, up first
        self._reversed = False
        self.locked = False

    def _best(self) -> tuple[int, float]:
        return max(self._throughput.items(), key=lambda kv: kv[1])

    def observe(self, count: int, seconds: float,
                size: int | None = None) -> None:
        """Feed one completed dispatch (count frames in ``seconds``).

        ``size`` is the batch size the dispatch was BUILT with — with a
        depth-k in-flight window, dispatches retire after the controller
        may have already moved, so the sample must be keyed by the size
        that produced it, not the current one.  Defaults to the current
        size for direct (synchronous) use."""
        if size is None:
            size = self.size
        if self.locked or seconds <= 0.0:
            return
        thr = count / seconds
        self._throughput[size] = max(
            self._throughput.get(size, 0.0), thr
        )
        self._counts[size] = self._counts.get(size, 0) + 1
        # Decisions only fire on samples from the CURRENT size once it
        # has settled — lagged samples from earlier sizes (still in the
        # in-flight window when the size moved) are recorded above but
        # never steer.
        if size != self.size or self._counts[size] < self.settle:
            return
        best_size, best_thr = self._best()
        if self._throughput[self.size] < best_thr:
            # the last move made things worse: go back to the best size
            # and either try the other direction or stop searching
            if self._reversed:
                self.size = best_size
                self.locked = True
                return
            self._reversed = True
            self._direction = 1.0 / self._direction
            self.size = best_size
        nxt = int(self.size * self._direction)
        nxt = max(1, min(nxt, self.max_size))
        if nxt == self.size or nxt in self._throughput:
            self.size = self._best()[0]
            self.locked = True
        else:
            self.size = nxt


# ---------------------------------------------------------------------------
# runtime
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class DispatchResult:
    """One retired dispatch: ``out`` covers ``count`` source items."""

    index: int
    count: int
    out: Any
    carry: Any
    meta: Any = None
    latency_s: float | None = None      # dispatch -> retire (block=True)


@dataclasses.dataclass
class RuntimeStats:
    """What one ``run()`` did — filled as dispatches retire."""

    items: int = 0
    dispatches: int = 0
    batch_sizes: list = dataclasses.field(default_factory=list)
    latencies_s: list = dataclasses.field(default_factory=list)
    wall_s: float = 0.0

    @property
    def items_per_s(self) -> float:
        return self.items / self.wall_s if self.wall_s > 0 else 0.0


class FrameRuntime:
    """The one async streaming scheduler (module docstring has the map).

    Args:
      step: ``step(chunk, carry) -> (out, carry)``.  Stateless computes
        wrap as ``lambda chunk, c: (fn(chunk), c)`` (``stateless()``).
      depth: dispatches kept in flight (1 = synchronous).
      microbatch: frames stacked per dispatch; with ``adaptive=True``
        this is the starting size and the controller retunes it online.
      adaptive: retune the microbatch from measured completion latency.
      carry_in: initial carry (``None`` for stateless pipelines); the
        final carry lands in ``self.last_carry`` when the run drains.
      device: staging placement — a ``Device`` (default: first device)
        or a ``jax.sharding.Sharding``.  A ``NamedSharding`` commits
        each chunk to the mesh layout a shard_map'd ``step`` consumes,
        so sharded plans stage exactly like single-device ones.
      stage_inputs: ``jax.device_put`` each chunk before ``step``.
      stage_ahead: chunks staged beyond the dispatch window (device
        prefetch; 0 = stage just-in-time, which is still async H2D).
      block: block on each result as it retires (the D2H sync point).
        Required by ``adaptive`` (that is where latency is measured).
      clock: injectable time source (tests script it).
    """

    def __init__(
        self,
        step: Callable,
        *,
        depth: int = 2,
        microbatch: int = 1,
        adaptive: bool = False,
        max_microbatch: int = 64,
        carry_in=None,
        device=None,
        stage_inputs: bool = True,
        stage_ahead: int = 0,
        block: bool = True,
        clock: Callable[[], float] = time.perf_counter,
    ):
        if depth < 1:
            raise ValueError("depth must be >= 1")
        if microbatch < 1:
            raise ValueError("microbatch must be >= 1")
        if stage_ahead < 0:
            raise ValueError("stage_ahead must be >= 0")
        if adaptive and not block:
            raise ValueError(
                "adaptive microbatching needs block=True (latency is "
                "measured at the retire-time sync point)"
            )
        self.step = step
        self.depth = depth
        self.microbatch = microbatch
        self.adaptive = adaptive
        self.controller = (
            AdaptiveMicrobatch(microbatch, max_size=max_microbatch)
            if adaptive else None
        )
        self.carry_in = carry_in
        self.device = device if device is not None else jax.devices()[0]
        self.stage_inputs = stage_inputs
        self.stage_ahead = stage_ahead
        self.block = block
        self.clock = clock
        self.last_carry = carry_in
        self.last_stats = RuntimeStats()

    @staticmethod
    def stateless(fn: Callable) -> Callable:
        """Lift a carry-free compute into the step signature."""
        return lambda chunk, carry: (fn(chunk), carry)

    # -- source -> chunks ---------------------------------------------------
    def _chunk_size(self) -> int:
        return self.controller.size if self.controller else self.microbatch

    def _chunks(self, items: Iterable, batched: bool) -> Iterator:
        """(count, chunk, built_size) triples; size re-read per chunk so
        the adaptive controller's moves take effect mid-stream.
        ``built_size`` is the size the chunk was requested at (count can
        be smaller on the ragged tail) — the key the controller files
        the dispatch's latency sample under."""
        if not batched:
            for item in items:
                yield 1, item, 1
            return
        if hasattr(items, "shape") and hasattr(items, "ndim"):
            s = 0
            n = items.shape[0]
            while s < n:
                k = self._chunk_size()
                yield min(k, n - s), items[s : s + k], k
                s += k
            return
        it = iter(items)
        buf: list = []
        while True:
            k = self._chunk_size()
            while len(buf) < k:
                try:
                    # analysis: allow-host-sync(host-side microbatch stacking before staging, not a device readback)
                    buf.append(np.asarray(next(it)))
                except StopIteration:
                    if buf:
                        yield len(buf), np.stack(buf), k
                    return
            yield k, np.stack(buf), k
            buf = []

    def _staged(self, chunks: Iterator) -> Iterator:
        if not self.stage_inputs:
            yield from chunks
            return
        queue: collections.deque = collections.deque()
        # stage_ahead beyond the dispatch window: the deque holds staged
        # chunks the dispatch loop has not consumed yet
        for count, chunk, built in chunks:
            queue.append((count, jax.device_put(chunk, self.device), built))
            if len(queue) > self.stage_ahead:
                yield queue.popleft()
        while queue:
            yield queue.popleft()

    # -- the scheduler core -------------------------------------------------
    def run(
        self, items: Iterable, *, batched: bool | None = None,
        meta: Callable | None = None,
    ) -> Iterator[DispatchResult]:
        """Drive ``items`` through the pipeline; yield retired dispatches
        in order.

        ``batched=None`` infers: stack/slice into microbatches unless
        the runtime is fixed at ``microbatch == 1`` and not adaptive (in
        which case items pass through unstacked, preserving each item's
        own rank).  ``meta(index, count, chunk)`` optionally computes a
        per-dispatch tag carried onto the ``DispatchResult`` (band
        spans use this)."""
        if batched is None:
            batched = self.adaptive or self.microbatch > 1
        stats = RuntimeStats()
        self.last_stats = stats
        t_run = self.clock()
        inflight: collections.deque = collections.deque()
        carry = self.carry_in

        def retire(d):
            out = d.out
            if self.block:
                # analysis: allow-host-sync(retire-time sync IS the depth-k window contract; dispatch stays async)
                out = jax.block_until_ready(out)
                d.latency_s = self.clock() - d._t0
                stats.latencies_s.append(d.latency_s)
                if self.controller is not None:
                    # keyed by the size the dispatch was BUILT with: in a
                    # depth-k window the controller may have moved since
                    self.controller.observe(d.count, d.latency_s,
                                            size=d._built)
            d.out = out
            stats.items += d.count
            stats.dispatches += 1
            stats.batch_sizes.append(d.count)
            stats.wall_s = self.clock() - t_run
            return d

        for index, (count, chunk, built) in enumerate(
            self._staged(self._chunks(items, batched))
        ):
            tag = meta(index, count, chunk) if meta is not None else None
            t0 = self.clock()
            out, carry = self.step(chunk, carry)
            d = DispatchResult(index=index, count=count, out=out,
                               carry=carry, meta=tag)
            d._t0 = t0
            d._built = built
            inflight.append(d)
            if len(inflight) >= self.depth:
                yield retire(inflight.popleft())
        while inflight:
            yield retire(inflight.popleft())
        self.last_carry = carry

    # -- sinks --------------------------------------------------------------
    def map_frames(self, frames: Iterable) -> Iterator:
        """Yield one result per input frame, in order (the executor /
        map_frames sink: batched dispatches are unstacked into per-frame
        views of the already-materialized device array)."""
        batched = self.adaptive or self.microbatch > 1
        for d in self.run(frames, batched=batched):
            if batched:
                for i in range(d.out.shape[0]):
                    yield d.out[i]
            else:
                yield d.out

    def fold(self, frames: Iterable, *, batched: bool | None = None):
        """Collect every dispatch output and the final carry:
        ``(outs, last_carry)`` — the tracker's chunked-scan sink."""
        outs = [d.out for d in self.run(frames, batched=batched)]
        return outs, self.last_carry
