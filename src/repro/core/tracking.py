"""Fragments-based visual tracking on integral histograms.

The paper's motivating application (ref. [13], Adam et al. CVPR'06): a
target template is split into a grid of fragments; every frame, each
fragment votes for the target position by matching its histogram against
candidate windows — all candidate histograms come from the frame's
integral histogram in O(1) each, which is what makes exhaustive local
search real-time.

The tracker is batched along two axes:

  * **targets** — ``init`` accepts a single ``(4,)`` bbox or a ``(t, 4)``
    stack; multi-target state is vmapped through every step against the
    *shared* per-frame H (the H is computed once regardless of target
    count — the whole point of the integral histogram).
  * **frames** — ``track`` consumes a whole clip: frames are chunked,
    each chunk's integral histograms come from ONE batched
    ``integral_histogram`` dispatch (PR 1's ``(n, h, w)`` kernel path),
    and a ``lax.scan`` threads the tracker state through the chunk
    on-device.  Results are bit-exact with a per-frame ``step`` loop.

This is a deliberately compact but fully functional tracker used by
examples/video_analytics.py and the integration tests.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import distances
from repro.core.region_query import region_histogram
from repro.kernels.ops import integral_histogram


@dataclasses.dataclass(frozen=True)
class TrackerConfig:
    num_bins: int = 16
    fragments: tuple[int, int] = (2, 2)     # fragment grid over the template
    search_radius: int = 12                 # candidate offsets per axis
    method: str = "wf_tis"
    backend: str = "jnp"                    # "pallas" on TPU


def _clamp_bbox(bbox: jnp.ndarray, h: int, w: int) -> jnp.ndarray:
    """Clamp [r0, c0, r1, c1] (inclusive) fully inside an (h, w) frame.

    A bbox taller/wider than the frame collapses to the frame edge rather
    than escaping it (which used to poison the step clip bounds)."""
    r0 = jnp.clip(bbox[..., 0], 0, h - 1)
    c0 = jnp.clip(bbox[..., 1], 0, w - 1)
    r1 = jnp.clip(bbox[..., 2], r0, h - 1)
    c1 = jnp.clip(bbox[..., 3], c0, w - 1)
    return jnp.stack([r0, c0, r1, c1], axis=-1)


def _fragment_rects(bbox: jnp.ndarray, grid: tuple[int, int]) -> jnp.ndarray:
    """Split bbox [r0, c0, r1, c1] into a (gr*gc, 4) grid of fragments."""
    r0, c0, r1, c1 = bbox[0], bbox[1], bbox[2], bbox[3]
    gr, gc = grid
    hh = (r1 - r0 + 1) // gr
    ww = (c1 - c0 + 1) // gc
    rows = r0 + jnp.arange(gr) * hh
    cols = c0 + jnp.arange(gc) * ww
    rr, cc = jnp.meshgrid(rows, cols, indexing="ij")
    return jnp.stack(
        [rr, cc, rr + hh - 1, cc + ww - 1], axis=-1
    ).reshape(-1, 4)


class FragmentTracker:
    """Track template bbox(es) across frames via fragment histogram voting.

    State is a dict {"bbox", "ref_hists", "frag_offsets"}; every field
    grows a leading target axis when ``init`` is given ``(t, 4)`` bboxes.

    ``engine`` (a ``HistogramEngine``, core/engine.py) optionally supplies
    the H computation so the tracker shares one planned configuration
    with the rest of a pipeline; its bin count must match the config's.
    """

    def __init__(self, config: TrackerConfig = TrackerConfig(), engine=None):
        self.config = config
        if engine is not None and engine.num_bins != config.num_bins:
            raise ValueError(
                f"engine num_bins {engine.num_bins} != tracker "
                f"num_bins {config.num_bins}"
            )
        self._engine = engine
        self._step_engine = None    # lazy default engine for step_fused

    # -- H computation (shared by init/step/track) --------------------------
    def _compute_h(self, frames: jnp.ndarray) -> jnp.ndarray:
        if self._engine is not None:
            return self._engine.compute_dense(frames)
        cfg = self.config
        return integral_histogram(
            frames, cfg.num_bins, method=cfg.method, backend=cfg.backend
        )

    # -- public -------------------------------------------------------------
    def init(self, frame: jnp.ndarray, bbox) -> dict:
        """bbox: [r0, c0, r1, c1] inclusive — (4,) or (t, 4) for t targets.

        The bbox is clamped fully inside the frame (an out-of-frame or
        oversized template has no pixels to describe)."""
        cfg = self.config
        h, w = frame.shape[-2:]
        bbox = _clamp_bbox(jnp.asarray(bbox, jnp.int32), h, w)
        H = self._compute_h(frame)
        if bbox.ndim == 1:
            frag_rects = _fragment_rects(bbox, cfg.fragments)
            frag_offsets = frag_rects - bbox[None, :]
        else:
            frag_rects = jax.vmap(
                lambda b: _fragment_rects(b, cfg.fragments)
            )(bbox)                                          # (t, f, 4)
            frag_offsets = frag_rects - bbox[:, None, :]
        ref_hists = region_histogram(H, frag_rects)          # ([t,] f, b)
        return {"bbox": bbox, "ref_hists": ref_hists,
                "frag_offsets": frag_offsets}

    def step(self, state: dict, frame: jnp.ndarray) -> dict:
        """Advance one frame (computes this frame's H, then votes)."""
        return self.step_on_h(state, self._compute_h(frame))

    def step_fused(self, state: dict, frame) -> dict:
        """``step`` without ever building the frame's H.

        The vote's candidate-fragment rects are enumerable on the host
        (bbox, search radius and fragment offsets are concrete between
        frames), so the whole step is ONE engine request: a
        ``RegionQuery`` over every candidate fragment, whose corner-row
        union the planner sees up front — small search radii fuse
        (``representation == "fused"``: only those rows of H are ever
        computed), large ones fall back to the dense vote.  The rect
        construction mirrors ``_vote`` exactly, so the returned bbox is
        bit-identical to ``step``'s.

        Single-target only (a ``(t, 4)`` state's rects depend on traced
        per-target bboxes) — multi-target states delegate to ``step``.
        """
        if np.asarray(state["bbox"]).ndim != 1:
            return self.step(state, frame)
        cfg = self.config
        h, w = np.shape(frame)[-2:]
        bbox = np.asarray(state["bbox"], np.int64)
        rad = cfg.search_radius
        dr = np.arange(-rad, rad + 1)
        drr, dcc = np.meshgrid(dr, dr, indexing="ij")
        offsets = np.stack([drr, dcc, drr, dcc], axis=-1).reshape(-1, 4)
        cand = bbox[None, :] + offsets
        bh = int(bbox[2] - bbox[0])
        bw = int(bbox[3] - bbox[1])
        r0 = np.clip(cand[:, 0], 0, max(h - 1 - bh, 0))
        c0 = np.clip(cand[:, 1], 0, max(w - 1 - bw, 0))
        cand = np.stack([r0, c0, r0 + bh, c0 + bw], axis=-1)
        frag = cand[:, None, :] + np.asarray(state["frag_offsets"])

        from repro.core.engine import HistogramEngine, RegionQuery

        engine = self._engine
        if engine is None:
            engine = self._step_engine
            if engine is None:
                engine = self._step_engine = HistogramEngine(
                    num_bins=cfg.num_bins, method=cfg.method,
                    backend=cfg.backend,
                )
        out = engine.run(frame, [RegionQuery(frag)])
        hists = out.results[0]                               # (n, f, b)
        sims = distances.intersection(
            hists, jnp.asarray(state["ref_hists"])[None]
        )
        scores = jnp.median(sims, axis=-1)
        new_bbox = jnp.asarray(cand, jnp.int32)[jnp.argmax(scores)]
        return {"bbox": new_bbox, "ref_hists": state["ref_hists"],
                "frag_offsets": state["frag_offsets"]}

    def step_on_h(self, state: dict, H) -> dict:
        """Advance one frame given its precomputed H — the hook for
        pipelines that already stream integral histograms
        (``IntegralHistogram.map_frames`` / ``HistogramEngine``).  ``H``
        is a (b, h, w) array or any ``HSource`` (densified: the vote's
        candidate rects are traced, so corner-row compression does not
        apply)."""
        from repro.core.hsource import HSource

        if isinstance(H, HSource):
            H = H.dense()
        return self._step_on_h_jit(state, H)

    @functools.partial(jax.jit, static_argnums=(0,))
    def _step_on_h_jit(self, state: dict, H: jnp.ndarray) -> dict:
        return self._step_state(state, H)

    def track(self, state: dict, frames, *, batch_size: int | str = "auto",
              incremental: bool = False):
        """Track through a whole clip.

        Args:
          state: tracker state from ``init``.
          frames: (n, h, w) array or any iterable of (h, w) frames.
          batch_size: frames per batched H dispatch (the chunk that one
            ``lax.scan`` consumes on-device).  ``"auto"`` asks the
            planner (core/engine.py) to size the chunk from the
            per-frame H footprint, exactly like
            ``IntegralHistogram.map_frames``.  A ragged final chunk
            costs one extra compile.
          incremental: thread each frame's H off its predecessor's
            through the engine's video-delta path (core/delta.py): a
            host loop hands ``prev=(frame_t, source_t)`` to
            ``HistogramEngine.run`` so low-motion clips *update* the
            cached H instead of recomputing it — bit-exact, so the
            returned boxes match the batched path.  Ignores
            ``batch_size`` (the chain is inherently sequential).

        The clip loop is ``runtime.FrameRuntime`` with the tracker state
        as the carry threaded between chunk dispatches (an array clip is
        chunked by slicing — device arrays stay on device; an iterable is
        stacked host-side).

        Returns:
          (final_state, boxes) with boxes (n, [t,] 4) — the bbox *after*
          each frame's update, bit-exact vs a per-frame ``step`` loop.
        """
        import itertools

        from repro.core.runtime import FrameRuntime

        if batch_size != "auto" and (
            not isinstance(batch_size, int) or batch_size < 1
        ):
            raise ValueError(
                f'batch_size must be a positive int or "auto", '
                f"got {batch_size!r}")
        if incremental:
            return self._track_incremental(state, frames)

        def empty():
            return state, jnp.zeros((0,) + state["bbox"].shape, jnp.int32)

        if hasattr(frames, "shape"):
            if frames.ndim != 3:
                raise ValueError(
                    f"track expects an (n, h, w) clip, got {frames.shape}; "
                    "use step() for a single frame")
            if frames.shape[0] == 0:
                return empty()
            hw = frames.shape[-2:]
        else:
            it = iter(frames)
            try:
                first = np.asarray(next(it))
            except StopIteration:
                return empty()
            hw = first.shape[-2:]
            frames = itertools.chain([first], it)
        if batch_size == "auto":
            from repro.core import engine as _engine

            cfg = self.config
            batch_size = _engine.plan(_engine.WorkloadSpec(
                height=hw[0], width=hw[1], num_bins=cfg.num_bins,
                num_frames=None, method=cfg.method, backend=cfg.backend,
            )).microbatch

        def step(chunk, st):
            st, chunk_boxes = self._track_chunk(st, jnp.asarray(chunk))
            return chunk_boxes, st

        # stage_inputs=False: a device-resident clip is chunked by
        # slicing and must stay on ITS device — device_put would pin
        # every chunk to devices()[0].
        runtime = FrameRuntime(step, depth=2, microbatch=batch_size,
                               carry_in=state, stage_inputs=False)
        boxes, state = runtime.fold(frames, batched=True)
        if not boxes:
            return empty()
        return state, jnp.concatenate(boxes, axis=0)

    def _track_incremental(self, state: dict, frames):
        """The video-delta clip loop: each frame's H is offered its
        predecessor's ``(frame, source)`` pair, so the engine updates
        dirty bands in place when motion is low (``track``'s
        ``incremental=True``).  Sequential by construction — the H of
        frame t seeds frame t+1."""
        from repro.core.engine import HistogramEngine

        engine = self._engine
        if engine is None:
            engine = self._step_engine
            if engine is None:
                cfg = self.config
                engine = self._step_engine = HistogramEngine(
                    num_bins=cfg.num_bins, method=cfg.method,
                    backend=cfg.backend,
                )
        boxes = []
        prev = None
        for frame in frames:
            out = engine.run(frame, prev=prev)
            state = self.step_on_h(state, out.source)
            boxes.append(state["bbox"])
            prev = (frame, out.source)
        if not boxes:
            return state, jnp.zeros((0,) + state["bbox"].shape, jnp.int32)
        return state, jnp.stack(boxes, axis=0)

    # -- internals ----------------------------------------------------------
    @functools.partial(jax.jit, static_argnums=(0,))
    def _track_chunk(self, state: dict, frames: jnp.ndarray):
        Hs = self._compute_h(frames)                 # (k, b, h, w), 1 dispatch

        def body(st, H):
            st = self._step_state(st, H)
            return st, st["bbox"]

        return lax.scan(body, state, Hs)

    def _step_state(self, state: dict, H: jnp.ndarray) -> dict:
        if state["bbox"].ndim == 1:
            new_bbox = self._vote(H, state["bbox"], state["ref_hists"],
                                  state["frag_offsets"])
        else:
            new_bbox = jax.vmap(
                lambda b, r, o: self._vote(H, b, r, o)
            )(state["bbox"], state["ref_hists"], state["frag_offsets"])
        return {"bbox": new_bbox, "ref_hists": state["ref_hists"],
                "frag_offsets": state["frag_offsets"]}

    def _vote(self, H, bbox, ref_hists, frag_offsets) -> jnp.ndarray:
        """Single-target candidate search on one frame's H."""
        cfg = self.config
        h, w = H.shape[-2:]
        rad = cfg.search_radius
        dr = jnp.arange(-rad, rad + 1)
        dc = jnp.arange(-rad, rad + 1)
        drr, dcc = jnp.meshgrid(dr, dc, indexing="ij")
        offsets = jnp.stack([drr, dcc, drr, dcc], axis=-1).reshape(-1, 4)

        cand = bbox[None, :] + offsets                       # (n_cand, 4)
        # clamp candidates fully inside the frame; the upper clip bound is
        # floored at 0 so a template as large as the frame pins to the
        # origin instead of producing negative rects
        bh = bbox[2] - bbox[0]
        bw = bbox[3] - bbox[1]
        r0 = jnp.clip(cand[:, 0], 0, jnp.maximum(h - 1 - bh, 0))
        c0 = jnp.clip(cand[:, 1], 0, jnp.maximum(w - 1 - bw, 0))
        cand = jnp.stack([r0, c0, r0 + bh, c0 + bw], axis=-1)

        # score every candidate by median fragment similarity (robust vote)
        frag = cand[:, None, :] + frag_offsets[None, :, :]   # (n, f, 4)
        hists = region_histogram(H, frag)                    # (n, f, b)
        sims = distances.intersection(hists, ref_hists[None])
        scores = jnp.median(sims, axis=-1)                   # (n,)
        return cand[jnp.argmax(scores)]
