"""Fragments-based visual tracking on integral histograms.

The paper's motivating application (ref. [13], Adam et al. CVPR'06): a
target template is split into a grid of fragments; every frame, each
fragment votes for the target position by matching its histogram against
candidate windows — all candidate histograms come from the frame's
integral histogram in O(1) each, which is what makes exhaustive local
search real-time.

This is a deliberately compact but fully functional tracker used by
examples/video_analytics.py and the integration tests.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import distances
from repro.core.region_query import region_histogram
from repro.kernels.ops import integral_histogram


@dataclasses.dataclass(frozen=True)
class TrackerConfig:
    num_bins: int = 16
    fragments: tuple[int, int] = (2, 2)     # fragment grid over the template
    search_radius: int = 12                 # candidate offsets per axis
    method: str = "wf_tis"
    backend: str = "jnp"                    # "pallas" on TPU


def _fragment_rects(bbox: jnp.ndarray, grid: tuple[int, int]) -> jnp.ndarray:
    """Split bbox [r0, c0, r1, c1] into a (gr*gc, 4) grid of fragments."""
    r0, c0, r1, c1 = bbox[0], bbox[1], bbox[2], bbox[3]
    gr, gc = grid
    hh = (r1 - r0 + 1) // gr
    ww = (c1 - c0 + 1) // gc
    rows = r0 + jnp.arange(gr) * hh
    cols = c0 + jnp.arange(gc) * ww
    rr, cc = jnp.meshgrid(rows, cols, indexing="ij")
    return jnp.stack(
        [rr, cc, rr + hh - 1, cc + ww - 1], axis=-1
    ).reshape(-1, 4)


class FragmentTracker:
    """Track a template bbox across frames via fragment histogram voting."""

    def __init__(self, config: TrackerConfig = TrackerConfig()):
        self.config = config

    def init(self, frame: jnp.ndarray, bbox) -> dict:
        """bbox: [r0, c0, r1, c1] inclusive."""
        cfg = self.config
        bbox = jnp.asarray(bbox, jnp.int32)
        H = integral_histogram(
            frame, cfg.num_bins, method=cfg.method, backend=cfg.backend
        )
        frag_rects = _fragment_rects(bbox, cfg.fragments)
        ref_hists = region_histogram(H, frag_rects)
        return {"bbox": bbox, "ref_hists": ref_hists,
                "frag_offsets": frag_rects - bbox[None, :]}

    @functools.partial(jax.jit, static_argnums=(0,))
    def step(self, state: dict, frame: jnp.ndarray) -> dict:
        cfg = self.config
        H = integral_histogram(
            frame, cfg.num_bins, method=cfg.method, backend=cfg.backend
        )
        h, w = frame.shape
        bbox = state["bbox"]
        rad = cfg.search_radius
        dr = jnp.arange(-rad, rad + 1)
        dc = jnp.arange(-rad, rad + 1)
        drr, dcc = jnp.meshgrid(dr, dc, indexing="ij")
        offsets = jnp.stack([drr, dcc, drr, dcc], axis=-1).reshape(-1, 4)

        cand = bbox[None, :] + offsets                       # (n_cand, 4)
        # clamp candidates fully inside the frame
        bh = bbox[2] - bbox[0]
        bw = bbox[3] - bbox[1]
        r0 = jnp.clip(cand[:, 0], 0, h - 1 - bh)
        c0 = jnp.clip(cand[:, 1], 0, w - 1 - bw)
        cand = jnp.stack([r0, c0, r0 + bh, c0 + bw], axis=-1)

        # score every candidate by median fragment similarity (robust vote)
        frag = cand[:, None, :] + state["frag_offsets"][None, :, :]  # (n,f,4)
        hists = region_histogram(H, frag)                    # (n, f, b)
        sims = distances.intersection(hists, state["ref_hists"][None])
        scores = jnp.median(sims, axis=-1)                   # (n,)
        best = jnp.argmax(scores)
        new_bbox = cand[best]
        return {"bbox": new_bbox, "ref_hists": state["ref_hists"],
                "frag_offsets": state["frag_offsets"]}
