"""Assigned-architecture registry: one module per arch, exact configs.

``get_config("llama3-8b")`` returns the full published config;
``smoke_config(...)`` returns a reduced same-family config for CPU tests
(small depth/width/vocab — the full configs are only ever lowered via
the dry-run with ShapeDtypeStructs, never allocated).
"""

from __future__ import annotations

import dataclasses
import importlib

from repro.config import ModelConfig, SHAPES, ShapeConfig, cell_is_runnable

ARCH_IDS = (
    "llama4-scout-17b-a16e",
    "kimi-k2-1t-a32b",
    "qwen2.5-3b",
    "qwen3-4b",
    "llama3-8b",
    "qwen2-1.5b",
    "llava-next-mistral-7b",
    "seamless-m4t-large-v2",
    "mamba2-130m",
    "recurrentgemma-9b",
)


def _module_name(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "_")


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_module_name(arch_id)}")
    return mod.CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def smoke_config(arch_id: str) -> ModelConfig:
    """Reduced same-family config: runs a real forward/train step on CPU."""
    cfg = get_config(arch_id)
    r = dict(
        num_layers=max(2, min(4, cfg.num_layers // 12)),
        d_model=128,
        vocab_size=512,
        head_dim=32,
        flash_min_seq=64,            # exercise the chunked-attention path
        attn_block_kv=32,
        remat="dots",
    )
    if cfg.num_heads:
        r["num_heads"] = 4
        r["num_kv_heads"] = min(2, cfg.num_kv_heads)
    if cfg.d_ff:
        r["d_ff"] = 256
    if cfg.is_moe:
        r.update(num_experts=4,
                 num_experts_per_token=min(2, cfg.num_experts_per_token),
                 expert_d_ff=64,
                 num_shared_experts=min(1, cfg.num_shared_experts),
                 first_k_dense=min(1, cfg.first_k_dense),
                 num_layers=3)
    if cfg.family == "ssm":
        r.update(ssm_state=16, ssm_chunk=16, ssm_head_dim=16)
    if cfg.family == "hybrid":
        r.update(rnn_width=128, rnn_scan_chunk=16, num_layers=5,
                 sliding_window=32)
    if cfg.sliding_window and cfg.family != "hybrid":
        r["sliding_window"] = 32
    if cfg.is_encoder_decoder:
        r.update(num_encoder_layers=2, num_decoder_layers=2, num_layers=2)
    if cfg.num_prefix_embeds:
        r["num_prefix_embeds"] = 8
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **r)


__all__ = ["ARCH_IDS", "get_config", "all_configs", "smoke_config",
           "SHAPES", "ShapeConfig", "cell_is_runnable", "ModelConfig"]
