"""LLaVA-NeXT (Mistral-7B backbone): VLM with anyres patch tiling.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.  The vision tower
is a STUB per the assignment: input_specs deliver 576 precomputed patch
embeddings per image as a sequence prefix."""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    modality="vision",
    num_prefix_embeds=576,
    rope_theta=1000000.0,
)
