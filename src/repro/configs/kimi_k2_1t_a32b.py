"""Kimi-K2: trillion-parameter MoE (paper-table). [arXiv:2501.kimi2; unverified]
61L d_model=7168 64H (GQA kv=8 per assignment) expert_d_ff=2048
vocab=163840, 384 routed experts top-8 + 1 shared, first layer dense."""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=18432,                 # the single dense layer (DeepSeek-V3 style)
    vocab_size=163840,
    num_experts=384,
    num_experts_per_token=8,
    expert_d_ff=2048,
    num_shared_experts=1,
    first_k_dense=1,
    rope_theta=50000.0,
)
