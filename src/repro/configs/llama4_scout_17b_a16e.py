"""Llama-4-Scout-17B-16E: early-fusion MoE decoder LM.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
48L d_model=5120 40H (GQA kv=8) expert_d_ff=8192 vocab=202048, 16 routed
experts top-1 + 1 shared expert (source config)."""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    num_experts=16,
    num_experts_per_token=1,
    expert_d_ff=8192,
    num_shared_experts=1,
    rope_theta=500000.0,
)
