"""Mamba2-130M: attention-free SSD (state-space duality).
[arXiv:2405.21060; unverified]
24L d_model=768 vocab=50280, ssm_state=128, expand=2, head_dim=64.
The SSD chunked scan is the direct 1-D analogue of the paper's WF-TiS
tiled scan (DESIGN.md par.4)."""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    ssm_groups=1,
    conv_kernel=4,
    tie_embeddings=True,
)
