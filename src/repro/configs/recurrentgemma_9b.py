"""RecurrentGemma-9B (Griffin): RG-LRU + local attention, 1 attn : 2 rec.
[arXiv:2402.19427; unverified]
38L d_model=4096 16H (GQA kv=1 = MQA, head_dim=256) d_ff=12288
vocab=256000, sliding window 2048, rnn width 4096."""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    sliding_window=2048,
    block_pattern=("rec", "rec", "attn"),
    rnn_width=4096,
    rnn_scan_chunk=256,
    conv_kernel=4,
    scale_embeddings=True,
    logits_softcap=30.0,
)
