"""SeamlessM4T-large-v2: multimodal encoder-decoder backbone.
[arXiv:2308.11596; hf]
24L (encoder) + 24L (decoder) d_model=1024 16H (kv=16, i.e. MHA,
head_dim=64) d_ff=8192 vocab=256206, LayerNorm.  The speech frontend is a
STUB per the assignment: input_specs deliver precomputed frame embeddings
(B, S_src, d_model) to the encoder."""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    use_layer_norm=True,
    is_encoder_decoder=True,
    num_encoder_layers=24,
    num_decoder_layers=24,
    modality="audio",
    tie_embeddings=True,
)
