"""repro: integral-histogram video-analytics framework on TPU.

Reproduction + extension of Poostchi et al., "Fast Integral Histogram
Computations on GPU for Real-Time Video Analytics" (2017), rebuilt
TPU-native in JAX/Pallas with a multi-pod distribution runtime and an
assigned 10-architecture LM model zoo.
"""

__version__ = "1.0.0"
