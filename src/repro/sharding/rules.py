"""Logical-axis sharding rules (MaxText-style) for the model zoo.

Mesh axes (v5e): ``("data", "model")`` single-pod, ``("pod", "data",
"model")`` multi-pod.  Logical activation/parameter axes map to mesh axes:

  batch    -> ("pod", "data")     activations: pure DP
  fsdp     -> ("data",)           parameters: ZeRO-3 shard of a non-TP dim
  tp       -> ("model",)          parameters: tensor-parallel dim
  experts  -> ("model",)          MoE expert-parallel dim

Head-count quirk: TP over attention heads requires heads % |model| == 0
(true for llama3/qwen3/seamless/griffin, false for llama4-scout's 40 and
qwen2-1.5b's 12).  ``attn_tp_dim`` picks heads when divisible, else falls
back to sharding head_dim (always 128, divisible by 16) — DESIGN.md §5.

``constrain`` is a no-op outside a sharding_context, so model code runs
unmodified on a single CPU device (smoke tests) and sharded under jit.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    batch_axes: tuple = ("pod", "data")
    fsdp_axes: tuple = ("data",)
    tp_axes: tuple = ("model",)
    expert_axes: tuple = ("model",)
    shard_heads: bool = True     # False -> head_dim fallback for attention
    # Decode KV-cache layout: "heads" (baseline: heads, else head_dim, on
    # the model axis) or "seq" (flash-decode: sequence dim on the model
    # axis — partial softmax per shard, small psum combines; see §Perf).
    decode_cache_layout: str = "heads"

    def present(self, mesh: Mesh, axes: tuple) -> tuple:
        return tuple(a for a in axes if a in mesh.axis_names)


_TLS = threading.local()


@dataclasses.dataclass
class _Ctx:
    mesh: Mesh
    rules: ShardingRules


def current_context() -> Optional[_Ctx]:
    return getattr(_TLS, "ctx", None)


@contextlib.contextmanager
def sharding_context(mesh: Mesh, rules: ShardingRules = ShardingRules()):
    prev = current_context()
    _TLS.ctx = _Ctx(mesh, rules)
    try:
        yield
    finally:
        _TLS.ctx = prev


def _axis_size(mesh: Mesh, axes: tuple) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def constrain(x: jax.Array, *logical) -> jax.Array:
    """with_sharding_constraint by logical axis names; identity if no ctx.

    ``logical`` entries: "batch" | "tp" | "fsdp" | "experts" | None.
    Axes whose size does not divide the mesh extent are left unsharded.
    """
    ctx = current_context()
    if ctx is None:
        return x
    mesh, rules = ctx.mesh, ctx.rules
    name_map = {
        "batch": rules.present(mesh, rules.batch_axes),
        "fsdp": rules.present(mesh, rules.fsdp_axes),
        "tp": rules.present(mesh, rules.tp_axes),
        "experts": rules.present(mesh, rules.expert_axes),
    }
    spec = []
    for dim, lg in enumerate(logical):
        axes = name_map.get(lg) if lg else None
        if axes and x.shape[dim] % _axis_size(mesh, axes) == 0:
            spec.append(axes if len(axes) > 1 else axes[0])
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec))
    )


# ---------------------------------------------------------------------------
# Decode-cache shardings (baseline layout)
# ---------------------------------------------------------------------------
def cache_shardings(cache_shape, mesh: Mesh,
                    rules: ShardingRules = ShardingRules()):
    """Decode-state shardings (baseline layout).

    KV caches (L, B, S, H, hd): batch over DP axes; heads over `model`
    when divisible, else head_dim over `model` (the GQA fallback — e.g.
    llama3's kv=8 on a 16-way model axis).  The sequence dim is NOT
    sharded in the baseline; the flash-decode hillclimb (§Perf) moves the
    shard there.  SSM/RG-LRU states shard batch + channel dims.
    """
    batch = rules.present(mesh, rules.batch_axes)
    tp = rules.present(mesh, rules.tp_axes)
    b_n = _axis_size(mesh, batch) if batch else 1
    tp_n = _axis_size(mesh, tp) if tp else 1
    b_ax = batch if len(batch) > 1 else (batch[0] if batch else None)
    tp_ax = tp if len(tp) > 1 else (tp[0] if tp else None)

    def ok(n, d):
        return d > 1 and n % d == 0

    def visit(path_parts, node):
        if isinstance(node, dict):
            return {k: visit(path_parts + (k,), v) for k, v in node.items()}
        leaf = path_parts[-1]
        shape = node.shape
        spec = [None] * len(shape)
        if len(shape) == 0:                      # scalars ("len")
            return NamedSharding(mesh, P())
        if leaf in ("k", "v", "cross_k", "cross_v") and len(shape) == 5:
            # (L, B, S, H, hd)
            if ok(shape[1], b_n):
                spec[1] = b_ax
            if rules.decode_cache_layout == "seq" and ok(shape[2], tp_n):
                spec[2] = tp_ax        # flash-decode: shard the sequence
            elif ok(shape[3], tp_n):
                spec[3] = tp_ax
            elif ok(shape[4], tp_n):
                spec[4] = tp_ax
        elif leaf == "pos" and len(shape) == 3:   # (L, B, W)
            if ok(shape[1], b_n):
                spec[1] = b_ax
        elif leaf == "h" and len(shape) == 5:     # SSD state (L,B,H,P,N)
            if ok(shape[1], b_n):
                spec[1] = b_ax
            if ok(shape[2], tp_n):
                spec[2] = tp_ax
        elif leaf == "h" and len(shape) == 3:     # RG-LRU state (L,B,w)
            if ok(shape[1], b_n):
                spec[1] = b_ax
            if ok(shape[2], tp_n):
                spec[2] = tp_ax
        elif leaf == "conv" and len(shape) == 4:  # (L, B, K-1, C)
            if ok(shape[1], b_n):
                spec[1] = b_ax
            if ok(shape[3], tp_n):
                spec[3] = tp_ax
        return NamedSharding(mesh, P(*spec))

    return visit((), cache_shape)


# ---------------------------------------------------------------------------
# Parameter shardings: map param-tree paths to PartitionSpecs.
# ---------------------------------------------------------------------------

def _spec_for(path: str, shape: tuple, mesh: Mesh, rules: ShardingRules) -> P:
    """Choose a spec for one parameter.

    Convention (see models/*.py init functions):
      stacked layer dim (leading, name contains 'layers') is never sharded;
      TP goes on the 'wide' dim (ff / heads / experts / vocab);
      FSDP goes on the d_model ('embed') dim when divisible.
    """
    tp = rules.present(mesh, rules.tp_axes)
    fsdp = rules.present(mesh, rules.fsdp_axes)
    ep = rules.present(mesh, rules.expert_axes)
    tp_n = _axis_size(mesh, tp) if tp else 1
    fsdp_n = _axis_size(mesh, fsdp) if fsdp else 1
    ep_n = _axis_size(mesh, ep) if ep else 1

    def ok(dim_size, n):
        return n > 1 and dim_size % n == 0

    leaf = path.split("/")[-1]
    spec = [None] * len(shape)
    stacked = path.startswith("layers") or "/layers/" in path or "blocks" in path

    def dim0() -> int:
        return 1 if stacked else 0

    if leaf in ("embed", "unembed", "lm_head"):
        # (vocab, d) or (d, vocab): TP on vocab, FSDP on d_model
        vdim = 0 if shape[0] > shape[-1] else len(shape) - 1
        ddim = len(shape) - 1 - vdim if len(shape) == 2 else None
        if ok(shape[vdim], tp_n):
            spec[vdim] = tp if len(tp) > 1 else tp[0]
        if ddim is not None and ok(shape[ddim], fsdp_n):
            spec[ddim] = fsdp if len(fsdp) > 1 else fsdp[0]
    elif leaf.startswith("we_") or leaf == "router":
        # MoE: we_* (L, E, d, f)/(L, E, f, d) -> experts on E, FSDP on d
        if leaf == "router":
            d_dim = dim0()
            if ok(shape[d_dim], fsdp_n):
                spec[d_dim] = fsdp if len(fsdp) > 1 else fsdp[0]
        else:
            e_dim = dim0()
            if ok(shape[e_dim], ep_n):
                spec[e_dim] = ep if len(ep) > 1 else ep[0]
            # FSDP on whichever of the two trailing dims == d_model-like (larger)
            d_dim = e_dim + 1 if shape[e_dim + 1] >= shape[e_dim + 2] else e_dim + 2
            if ok(shape[d_dim], fsdp_n):
                spec[d_dim] = fsdp if len(fsdp) > 1 else fsdp[0]
    elif leaf in ("wq", "wk", "wv", "wo", "w_qkv"):
        # (L, d, H, hd) or (L, H, hd, d): TP on heads if divisible else hd
        hd_dim = len(shape) - 2 if leaf != "wo" else dim0() + 1
        h_dim = hd_dim - 1 if leaf != "wo" else dim0()
        d_dim = len(shape) - 1 if leaf == "wo" else dim0()
        if rules.shard_heads and ok(shape[h_dim], tp_n):
            spec[h_dim] = tp if len(tp) > 1 else tp[0]
        elif ok(shape[hd_dim], tp_n):
            spec[hd_dim] = tp if len(tp) > 1 else tp[0]
        if ok(shape[d_dim], fsdp_n):
            spec[d_dim] = fsdp if len(fsdp) > 1 else fsdp[0]
    elif leaf in ("w_gate", "w_up", "w_in", "w_branch_x", "w_branch_gate",
                  "w_xbc_dt", "in_proj"):
        # (L, d, f): TP on f, FSDP on d
        if ok(shape[-1], tp_n):
            spec[-1] = tp if len(tp) > 1 else tp[0]
        if ok(shape[-2], fsdp_n):
            spec[-2] = fsdp if len(fsdp) > 1 else fsdp[0]
    elif leaf in ("w_down", "w_out", "out_proj"):
        # (L, f, d): TP on f, FSDP on d
        if ok(shape[-2], tp_n):
            spec[-2] = tp if len(tp) > 1 else tp[0]
        if ok(shape[-1], fsdp_n):
            spec[-1] = fsdp if len(fsdp) > 1 else fsdp[0]
    # 1-D (norms, biases, gates) and anything unmatched stays replicated.
    return P(*spec)


def param_shardings(params_shape, mesh: Mesh,
                    rules: ShardingRules = ShardingRules()):
    """Tree of NamedShardings matching a tree of param ShapeDtypeStructs."""

    def visit(path_parts, node):
        if isinstance(node, dict):
            return {k: visit(path_parts + (k,), v) for k, v in node.items()}
        path = "/".join(path_parts)
        spec = _spec_for(path, node.shape, mesh, rules)
        return NamedSharding(mesh, spec)

    return visit((), params_shape)
