from repro.sharding.rules import (
    ShardingRules,
    cache_shardings,
    constrain,
    param_shardings,
    sharding_context,
    current_context,
)

__all__ = [
    "ShardingRules", "cache_shardings", "constrain", "param_shardings",
    "sharding_context", "current_context",
]
